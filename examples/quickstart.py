"""Quickstart: train a reduced LLaMA-3-family model for 30 steps on CPU,
then generate from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config, smoke_variant
from repro.core.sharding import ShardingCtx
from repro.data import Prefetcher, stream_for
from repro.models import transformer
from repro.optim import AdamW, warmup_cosine
from repro.serve import generate
from repro.train import Trainer, TrainerConfig, make_train_step


def main():
    cfg = smoke_variant(get_config("llama3-8b"))
    print(f"arch: {cfg.name}  layers={cfg.num_layers} d={cfg.d_model} "
          f"params={sum(x.size for x in jax.tree.leaves(transformer.init_params(cfg, jax.random.PRNGKey(0)))):,}")

    ctx = ShardingCtx()                       # single device; mesh-free
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(weight_decay=0.01)
    step = make_train_step(
        lambda p, b: transformer.lm_loss(p, cfg, ctx, b), opt,
        warmup_cosine(3e-3, 5, 30))

    data = Prefetcher(stream_for(cfg, batch=8, seq=64))
    trainer = Trainer(step, TrainerConfig(total_steps=30, log_every=5))
    params, _, hist = trainer.fit(params, opt.init(params), data)
    data.close()
    assert hist[-1]["loss"] < hist[0]["loss"]

    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    out = generate(params, cfg, ctx, prompt, 16, temperature=0.0)
    print("generated:", out[0].tolist())


if __name__ == "__main__":
    main()
