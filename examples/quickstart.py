"""Quickstart: train a reduced LLaMA-3-family model for 30 steps on CPU,
then generate from it — three lines from spec to training via ``repro.api``.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.api import RunSpec, compile_run
from repro.serve import generate


def main():
    spec = RunSpec(arch="llama3-8b", smoke=True, steps=30, batch=8, seq=64,
                   lr=3e-3, warmup_steps=5, weight_decay=0.01, log_every=5)
    run = compile_run(spec)
    n_params = sum(x.size for x in jax.tree.leaves(run.params))
    print(f"arch: {run.cfg.name}  layers={run.cfg.num_layers} "
          f"d={run.cfg.d_model} params={n_params:,}")

    hist = run.fit()
    run.close()
    assert hist[-1]["loss"] < hist[0]["loss"]

    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                run.cfg.vocab_size)
    out = generate(run.params, run.cfg, run.ctx, prompt, 16, temperature=0.0)
    print("generated:", out[0].tolist())


if __name__ == "__main__":
    main()
