"""Hybrid-parallelism demo — the paper's core idea, end to end, on 8
simulated devices.

1. Uses the §3 balance equations to pick the optimal group count G for the
   CD-DNN layers (model parallel within a group, data parallel across).
2. Trains the CD-DNN with the EXPLICIT part-reduce/part-broadcast
   distributed optimizer (optim/dist.py) on a (G, N/G) mesh and verifies
   the loss curve is identical to serial SGD — the paper's Fig-5 property.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/hybrid_parallelism_demo.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

import repro.jaxcompat  # noqa: F401  (backfills AxisType & co on jax 0.4.x)
from jax.sharding import AxisType

from repro.configs import get_config, smoke_variant, XEON_E5_2697V3
from repro.core import balance
from repro.core.sharding import ShardingCtx, ShardingRules
from repro.data import stream_for
from repro.models import dnn
from repro.optim import MomentumSGD
from repro.optim.dist import make_distributed_update

N_NODES = 8
MINIBATCH = 32


def main():
    cfg = get_config("cd-dnn")
    # --- 1. paper §3.3: pick G per layer ---
    print("paper §3.3 optimal G per CD-DNN layer (N=8, minibatch=32):")
    dims = [(cfg.input_dim, cfg.hidden_dim)] \
        + [(cfg.hidden_dim, cfg.hidden_dim)] * (cfg.num_hidden - 1) \
        + [(cfg.hidden_dim, cfg.output_dim)]
    for i, (fin, fout) in enumerate(dims):
        g = balance.optimal_group_count(N_NODES, MINIBATCH, fout)
        mp = balance.model_parallel_preferred(
            __import__("repro.configs.base", fromlist=["ConvLayerSpec"])
            .ConvLayerSpec("fc", ifm=fin, ofm=fout, kernel=1, out_hw=1),
            in_hw=1, minibatch=MINIBATCH)
        print(f"  layer {i}: {fin:5d}->{fout:5d}  G*={g}  "
              f"model-parallel preferred: {mp}")

    # --- 2. explicit part-reduce / part-broadcast training ---
    small = smoke_variant(cfg)
    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    print(f"\nmesh: {dict(mesh.shape)}  (G=4 data-parallel groups x "
          f"2-way model parallel)")
    params = dnn.init_params(small, jax.random.PRNGKey(0))
    opt = MomentumSGD(momentum=0.9)
    init_fn, update_fn = make_distributed_update(opt, mesh,
                                                 data_axes=("data",))
    serial_state = opt.init(params)
    serial_params = params
    with jax.set_mesh(mesh):
        dist_state = init_fn(params)
        dist_params = params
        stream = stream_for(small, MINIBATCH, 0, seed=1)
        grad_fn = jax.jit(jax.value_and_grad(
            lambda p, b: dnn.loss_fn(p, small, b)))
        upd = jax.jit(update_fn)
        print("step   serial-loss  dist-loss   max|Δparam|")
        for step in range(10):
            batch = jax.tree.map(jnp.asarray, next(stream))
            l_s, g_s = grad_fn(serial_params, batch)
            serial_params, serial_state = opt.update(
                g_s, serial_state, serial_params, 0.05)
            l_d, g_d = grad_fn(dist_params, batch)
            dist_params, dist_state = upd(dist_params, g_d, dist_state, 0.05)
            delta = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
                jax.tree.leaves(serial_params), jax.tree.leaves(dist_params)))
            print(f"{step:4d}  {float(l_s):10.4f} {float(l_d):10.4f}"
                  f"   {delta:.2e}")
        assert delta < 1e-4, "distributed must track serial bitwise-tightly"
    print("\nsynchronous-SGD identity verified: the paper's part-reduce/"
          "part-broadcast update matches serial SGD (Fig 5 property).")


if __name__ == "__main__":
    main()
