"""End-to-end driver (deliverable b): train a ~100M-parameter LM for a few
hundred steps with the full substrate — declarative ``RunSpec`` assembly,
data pipeline with background prefetch, AdamW + warmup-cosine, periodic
checkpointing, resume.

Default model: ``llama-100m`` (100.7M params, llama3-family blocks;
``--arch xlstm-125m`` trains the assigned SSM config instead).

    PYTHONPATH=src python examples/train_lm_100m.py --steps 300
"""
import argparse
import os

import jax

from repro.api import RunSpec, compile_run
from repro.checkpoint import save


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    ap.add_argument("--arch", default="llama-100m")
    args = ap.parse_args(argv)

    spec = RunSpec(arch=args.arch, steps=args.steps, batch=args.batch,
                   seq=args.seq, lr=args.lr, weight_decay=0.1,
                   log_every=10, ckpt_dir=args.ckpt_dir,
                   ckpt_every=max(args.steps // 3, 50))
    run = compile_run(spec)
    n = sum(x.size for x in jax.tree.leaves(run.params))
    print(f"training {run.cfg.name}: {n / 1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    # Run.fit auto-resumes from the latest ckpt_dir checkpoint: restored
    # trees land back on the run's shardings and the seeded data stream is
    # fast-forwarded, so the trajectory continues exactly where it stopped
    hist = run.fit()
    run.close()
    if not hist:
        # resumed past --steps (or the source ran dry before any log):
        # nothing trained, so don't stamp a new checkpoint at args.steps
        # or clobber the recorded loss history with an empty file
        print("nothing to train; checkpoint and history left as-is")
        return hist
    if hist[-1]["step"] == args.steps:
        # completed: capture the end state (the final step always logs, so
        # this label is the step the params really reached)
        save(args.ckpt_dir, args.steps, params=run.params,
             opt_state=run.opt_state)
    else:
        # stopped short (source ran dry): params are AHEAD of the last
        # logged step — don't overwrite a consistent periodic checkpoint
        # with a mislabeled one
        print(f"stopped at step {hist[-1]['step']} < {args.steps}; "
              "keeping periodic checkpoints only")
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    # append on resume: hist only covers steps after the restored
    # checkpoint, and mode "w" would wipe the pre-kill rows
    path = os.path.join(args.ckpt_dir, "history.csv")
    resumed = hist[0]["step"] > 1 and os.path.exists(path)
    with open(path, "a" if resumed else "w") as f:
        if not resumed:
            f.write("step,loss\n")
        for h in hist:
            f.write(f"{h['step']},{h['loss']}\n")
    return hist


if __name__ == "__main__":
    main()
