"""End-to-end driver (deliverable b): train a ~100M-parameter LM for a few
hundred steps with the full substrate — declarative ``RunSpec`` assembly,
data pipeline with background prefetch, AdamW + warmup-cosine, periodic
checkpointing, resume.

Default model: ``llama-100m`` (100.7M params, llama3-family blocks;
``--arch xlstm-125m`` trains the assigned SSM config instead).

    PYTHONPATH=src python examples/train_lm_100m.py --steps 300
"""
import argparse
import os

import jax

from repro.api import RunSpec, compile_run
from repro.checkpoint import latest_step, restore, save


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    ap.add_argument("--arch", default="llama-100m")
    args = ap.parse_args(argv)

    spec = RunSpec(arch=args.arch, steps=args.steps, batch=args.batch,
                   seq=args.seq, lr=args.lr, weight_decay=0.1,
                   log_every=10, ckpt_dir=args.ckpt_dir,
                   ckpt_every=max(args.steps // 3, 50))
    run = compile_run(spec)
    n = sum(x.size for x in jax.tree.leaves(run.params))
    print(f"training {run.cfg.name}: {n / 1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    start = 0
    if (s := latest_step(args.ckpt_dir)):
        out, start = restore(args.ckpt_dir, s, params=run.params,
                             opt_state=run.opt_state)
        run.params, run.opt_state = out["params"], out["opt_state"]
        print(f"resumed from step {start}")

    hist = run.fit(start_step=start)
    run.close()
    save(args.ckpt_dir, args.steps, params=run.params,
         opt_state=run.opt_state)
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    with open(os.path.join(args.ckpt_dir, "history.csv"), "w") as f:
        f.write("step,loss\n")
        for h in hist:
            f.write(f"{h['step']},{h['loss']}\n")
    return hist


if __name__ == "__main__":
    main()
