"""End-to-end driver (deliverable b): train a ~100M-parameter LM for a few
hundred steps with the full substrate — config system, data pipeline with
background prefetch, AdamW + warmup-cosine, periodic checkpointing, resume.

Default model: ``llama-100m`` (100.7M params, llama3-family blocks;
``--arch xlstm-125m`` trains the assigned SSM config instead).

    PYTHONPATH=src python examples/train_lm_100m.py --steps 300
"""
import argparse
import os

import jax

from repro.checkpoint import latest_step, restore, save
from repro.configs import get_config
from repro.core.sharding import ShardingCtx
from repro.data import Prefetcher, stream_for
from repro.models import transformer
from repro.optim import AdamW, warmup_cosine
from repro.train import Trainer, TrainerConfig, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    ap.add_argument("--arch", default="llama-100m")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    ctx = ShardingCtx()
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"training {cfg.name}: {n / 1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    opt = AdamW(weight_decay=0.1)
    opt_state = opt.init(params)
    start = 0
    if (s := latest_step(args.ckpt_dir)):
        out, start = restore(args.ckpt_dir, s, params=params,
                             opt_state=opt_state)
        params, opt_state = out["params"], out["opt_state"]
        print(f"resumed from step {start}")

    step = make_train_step(
        lambda p, b: transformer.lm_loss(p, cfg, ctx, b), opt,
        warmup_cosine(args.lr, args.steps // 20, args.steps))
    data = Prefetcher(stream_for(cfg, args.batch, args.seq), depth=2)
    trainer = Trainer(step, TrainerConfig(
        total_steps=args.steps, log_every=10,
        ckpt_every=max(args.steps // 3, 50), ckpt_dir=args.ckpt_dir))
    params, opt_state, hist = trainer.fit(params, opt_state, data,
                                          start_step=start)
    data.close()
    save(args.ckpt_dir, args.steps, params=params, opt_state=opt_state)
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    with open(os.path.join(args.ckpt_dir, "history.csv"), "w") as f:
        f.write("step,loss\n")
        for h in hist:
            f.write(f"{h['step']},{h['loss']}\n")
    return hist


if __name__ == "__main__":
    main()
