"""Batched serving example: prefill a batch of prompts, then decode
incrementally with ring-buffered KV caches (the decode_32k/long_500k path).

    PYTHONPATH=src python examples/serve_batched.py --arch gemma2-2b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core.sharding import ShardingCtx
from repro.models import transformer
from repro.serve import decode_step, prefill


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=48)
    args = ap.parse_args(argv)

    cfg = smoke_variant(get_config(args.arch))
    ctx = ShardingCtx()
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    t0 = time.perf_counter()
    logits, caches = jax.jit(
        lambda p, t: prefill(p, cfg, ctx, t,
                             capacity=args.prompt_len + args.new_tokens)
    )(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {args.batch} x {args.prompt_len} tokens in "
          f"{t_prefill * 1e3:.1f} ms "
          f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")

    step = jax.jit(lambda p, t, pos, c: decode_step(p, cfg, ctx, t, pos, c))
    cur = jnp.argmax(logits, -1)[:, None]
    out = [cur]
    t0 = time.perf_counter()
    for i in range(1, args.new_tokens):
        logits, caches = step(params, cur,
                              jnp.asarray(args.prompt_len + i - 1), caches)
        cur = jnp.argmax(logits, -1)[:, None]
        out.append(cur)
    jax.block_until_ready(cur)
    t_dec = time.perf_counter() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"decode: {args.batch} x {args.new_tokens - 1} steps in "
          f"{t_dec:.2f} s "
          f"({args.batch * (args.new_tokens - 1) / t_dec:.0f} tok/s)")
    print("sample:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
