"""Continuous-batching serving example, through the ServeSpec seam.

A mixed-length prompt batch is submitted to a :class:`repro.api.Server`;
the scheduler packs requests into paged-KV decode slots in flight, so a
short request finishing frees its slot (and pages) for the next queued
prompt immediately — no waiting for the longest request in a wave.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma2-2b
"""
import argparse
import time

import numpy as np

from repro.api import ServeSpec, compile_serve


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=48)
    ap.add_argument("--scheduler", default="continuous",
                    choices=["continuous", "static"])
    args = ap.parse_args(argv)

    spec = ServeSpec(arch=args.arch, smoke=True, max_batch=args.max_batch,
                     page_size=16, num_pages=128,
                     max_prompt=args.prompt_len,
                     max_new_tokens=args.new_tokens,
                     scheduler=args.scheduler)
    server = compile_serve(spec)

    # heavy-tail-ish mix: mostly short prompts/outputs, a few long ones
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        L = int(rng.integers(4, args.prompt_len + 1))
        new = args.new_tokens if i % 4 == 0 else max(args.new_tokens // 6, 1)
        server.submit(rng.integers(1, server.cfg.vocab_size, size=L), new)

    t0 = time.perf_counter()
    done = server.drain()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in done)
    lat = sorted(r.latency for r in done)
    print(f"{spec.scheduler}: {len(done)} requests, {n_tok} tokens in "
          f"{dt:.2f}s ({n_tok / dt:.0f} tok/s incl. compile)")
    print(f"latency p50={lat[len(lat) // 2] * 1e3:.0f} ms "
          f"max={lat[-1] * 1e3:.0f} ms  "
          f"scheduler steps={server.stats['steps']}  "
          f"preemptions={server.stats['preemptions']}")
    print("sample:", done[0].output[:16].tolist())


if __name__ == "__main__":
    main()
