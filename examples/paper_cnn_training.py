"""The paper's own workload: VGG-A training with momentum SGD (reduced size
for CPU), assembled through ``repro.api`` — the family adapter picks the
CNN loss/stream and the paper's optimizer; ``--use-pallas`` swaps the
forward convs onto the Pallas direct-conv kernel.

    PYTHONPATH=src python examples/paper_cnn_training.py [--use-pallas]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.api import RunSpec, compile_run
from repro.models import cnn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--use-pallas", action="store_true",
                    help="route forward convs through the Pallas kernel")
    args = ap.parse_args(argv)

    spec = RunSpec(arch="vgg-a", smoke=True, steps=args.steps,
                   batch=args.batch, lr=5e-3, schedule="constant",
                   log_every=10)
    run = compile_run(spec)          # family default optimizer: momentum SGD

    if args.use_pallas:
        # override the compiled loss with the Pallas-forward variant; the
        # rest of the assembly (optimizer, data, trainer) is untouched
        def pallas_loss(p, b):
            logits = cnn.forward(p, run.cfg, b["images"], use_pallas=True)
            lf = logits.astype(jnp.float32)
            nll = jax.nn.logsumexp(lf, -1) - jnp.take_along_axis(
                lf, b["labels"][:, None], axis=-1)[:, 0]
            return nll.mean()
        from repro.train import make_train_step
        run.train_step = make_train_step(pallas_loss, run.optimizer,
                                         run.lr_schedule)

    hist = run.fit()
    run.close()
    print(f"VGG-A(smoke) loss {hist[0]['loss']:.3f} -> "
          f"{hist[-1]['loss']:.3f} (pallas={args.use_pallas})")


if __name__ == "__main__":
    main()
