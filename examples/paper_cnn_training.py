"""The paper's own workload: VGG-A training with momentum SGD (reduced size
for CPU), with the Pallas direct-conv kernel selectable for the forward.

    PYTHONPATH=src python examples/paper_cnn_training.py [--use-pallas]
"""
import argparse

import jax

from repro.configs import get_config, smoke_variant
from repro.data import Prefetcher, stream_for
from repro.models import cnn
from repro.optim import MomentumSGD
from repro.optim.schedule import constant
from repro.train import Trainer, TrainerConfig, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--use-pallas", action="store_true",
                    help="route forward convs through the Pallas kernel")
    args = ap.parse_args(argv)

    cfg = smoke_variant(get_config("vgg-a"))
    params = cnn.init_params(cfg, jax.random.PRNGKey(0))
    opt = MomentumSGD(momentum=0.9)      # the paper's optimizer, unchanged

    def loss(p, b):
        logits = cnn.forward(p, cfg, b["images"],
                             use_pallas=args.use_pallas)
        import jax.numpy as jnp
        lf = logits.astype(jnp.float32)
        nll = jax.nn.logsumexp(lf, -1) - jnp.take_along_axis(
            lf, b["labels"][:, None], axis=-1)[:, 0]
        return nll.mean()

    step = make_train_step(loss, opt, constant(5e-3))
    data = Prefetcher(stream_for(cfg, args.batch, 0))
    trainer = Trainer(step, TrainerConfig(total_steps=args.steps,
                                          log_every=10))
    params, _, hist = trainer.fit(params, opt.init(params), data)
    data.close()
    print(f"VGG-A(smoke) loss {hist[0]['loss']:.3f} -> "
          f"{hist[-1]['loss']:.3f} (pallas={args.use_pallas})")


if __name__ == "__main__":
    main()
