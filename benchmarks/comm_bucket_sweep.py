"""Bucket-size sweep for the gradient communication subsystem (repro.comm).

For the paper's CNN workloads this sweeps the fusion-buffer size over the
§3.2 latency+bucket model (core.balance): per step, the collective count
drops from O(#tensors) — one part-reduce/part-broadcast pair per tensor, the
seed schedule — to O(total_bytes / bucket_bytes), and the predicted gradient
round-trip time bottoms out near the closed-form optimum
``optimal_bucket_bytes`` = sqrt(B * SWlat * BW * G).  The hierarchical rows
compare one flat 128-member ring against the two-level in-pod + cross-pod
composition on the same tree.

Collective counts come from the REAL planner (repro.comm.plan_buckets over
the actual weight-tensor shapes), so they match what the bucketed
``make_distributed_update`` would issue; only the times are model-predicted.

The ``overlap_*`` rows report the predicted EXPOSED communication per step:
with the monolithic schedule every transfer is exposed (overlap off), while
the §3.1 bubble schedule (``CommConfig.overlap`` / ``--overlap``) hides each
bucket's reduce under the backprop remaining below its trigger layer —
``core.balance.bucket_bubble_schedule`` over the same real plan, with the
bucket→layer readiness metadata of ``repro.comm.overlap``.

Every predicted time is per COLLECTIVE BACKEND (``--backend {lax,
pallas-ring}``): the ``core.balance.RING_BACKEND_MODELS`` constants shift
the latency/bandwidth terms per implementation.  ``measured_rows`` times
the real executable schedule — the same ``FlatSchedule`` + backend the
bucketed update drives — on a forced-8-device host mesh (subprocess, like
tests/test_distributed.py) and pairs each wall-clock row with the model's
prediction for the same plan.  Host-mesh CPU wall clock is not ICI time —
the comparable quantities are the bucket-size TREND and the lax-vs-ring
ratio, not absolute seconds (pallas-ring runs its hop kernels in interpret
mode off-TPU, so its host numbers are pessimistic).
"""
from __future__ import annotations

import math
import os
import re
import subprocess
import sys
import textwrap

import jax

from repro.comm.bucketer import WIRE_FORMATS, plan_buckets
from repro.comm.overlap import exposed_comm
from repro.configs import XEON_E5_2666V3_10GBE as GBE, XEON_E5_2698V3_FDR as FDR, get_config
from repro.core.balance import (
    SIZE_F32,
    bucketed_allreduce_time,
    collective_count,
    compressed_allreduce_time,
    conv_comp_flops,
    fc_comp_flops,
    hierarchical_allreduce_time,
    optimal_bucket_bytes,
    ring_collective_time,
    wire_reduce_bytes,
)

MIB = 2**20
SWEEP_MIB = (0.25, 1.0, 4.0, 16.0, 32.0)
G = 64           # the paper's 256-minibatch / 4-per-node operating point
MB_NODE = 4      # data points per node at that operating point
G_PODS, G_IN = 8, 16   # two-level composition of 128 nodes

MEASURED_MIB = (0.25, 4.0)
MEASURED_DEVICES = 8
MEASURED_FORMATS = ("fp32", "int8", "topk")   # bf16 is a dense dtype cast —
#                                               shape-identical to fp32 on a
#                                               host mesh, nothing to measure
TOPK_RATIO = 0.05


def grad_tree(net: str):
    """Weight + bias leaves of a paper CNN — the family adapter's param
    specs, i.e. exactly the tree (and tree order) the real bucketed
    ``make_distributed_update`` plans over.  ``core.params.Spec`` is
    shape-only, so plan_buckets runs without materializing VGG-A.
    Returns (leaves, leaf_layer): per flat leaf, the forward layer index it
    belongs to (parsed from the spec names, e.g. ``conv3_w`` -> 3) — the
    readiness metadata the §3.1 overlap schedule needs."""
    from repro.api import adapter_for
    cfg = get_config(net)
    flat = jax.tree_util.tree_flatten_with_path(
        adapter_for(cfg).param_specs(cfg))[0]
    leaves = [leaf for _, leaf in flat]
    leaf_layer = [int(re.search(r"\d+", jax.tree_util.keystr(p)).group())
                  for p, _ in flat]
    return leaves, leaf_layer


def layer_comps(net: str):
    """Per forward layer, FLOPs per node per iteration (3 passes) at the
    G=64 operating point; pool layers contribute ~0."""
    cfg = get_config(net)
    comps = []
    for lyr in cfg.layers:
        if lyr.kind == "conv":
            comps.append(conv_comp_flops(lyr, MB_NODE))
        elif lyr.kind == "fc":
            comps.append(fc_comp_flops(lyr.ifm, lyr.ofm, MB_NODE))
        else:
            comps.append(0.0)
    return comps


def _size(leaf) -> int:
    return math.prod(leaf.shape)


def rows(backend: str = "lax"):
    out = []
    for net in ("vgg-a", "overfeat-fast"):
        leaves, leaf_layer = grad_tree(net)
        comps = layer_comps(net)
        total = sum(_size(lyr) for lyr in leaves) * SIZE_F32
        n_tensors = len(leaves)
        pre = f"comm/{net}/{backend}"
        out.append((f"{pre}/n_tensors", n_tensors, ""))
        out.append((f"{pre}/grad_MiB", total / MIB, ""))
        # the serialization granularity of each schedule is its largest
        # single message: the biggest tensor for per-tensor, the biggest
        # fusion buffer for bucketed plans
        max_leaf = max(_size(lyr) for lyr in leaves) * SIZE_F32
        for hw, tag in ((FDR, "FDR"), (GBE, "10GbE")):
            # per-tensor baseline: the seed schedule's collective count
            t0 = bucketed_allreduce_time(total, n_tensors, 0, G, hw,
                                         fill_bytes=max_leaf, backend=backend)
            out.append((f"{pre}/{tag}/per_tensor_ms", t0 * 1e3,
                        f"n_coll={n_tensors};fill_MiB={max_leaf / MIB:.1f}"))
            for mib in SWEEP_MIB:
                plan = plan_buckets(leaves, G, int(mib * MIB))
                n_model = collective_count(total, n_tensors, mib * MIB)
                fill = max(b.size for b in plan.buckets) * SIZE_F32
                # time uses the REAL plan's count and largest buffer (the
                # planner never splits a tensor, so it can issue far fewer
                # collectives than the closed-form ceil(total/bucket) —
                # the `model=` column shows that law)
                t = bucketed_allreduce_time(total, n_tensors, mib * MIB,
                                            G, hw,
                                            n_coll=plan.n_collectives,
                                            fill_bytes=fill, backend=backend)
                out.append((f"{pre}/{tag}/bucket_{mib}MiB_ms", t * 1e3,
                            f"n_coll={plan.n_collectives};model={n_model}"))
                # §3.1 overlap: exposed-comm with the bubble schedule over
                # the SAME real plan vs. the monolithic (all-exposed) path
                comm_times = [ring_collective_time(
                    b.padded_size * SIZE_F32, G, hw, backend=backend)
                    for b in plan.buckets]
                off, on, _ = exposed_comm(plan, comm_times, comps, hw,
                                          leaf_layer=leaf_layer,
                                          efficiency=0.75)
                hidden = 100.0 * (1.0 - on / off) if off > 0 else 0.0
                out.append((
                    f"{pre}/{tag}/overlap_{mib}MiB_exposed_ms",
                    on * 1e3,
                    f"off={off * 1e3:.3f}ms;hidden={hidden:.0f}%"))
            # closed-form optimum (splittable-tensor model — the planner
            # rows above carry the real unsplittable-tensor counts)
            b_star = optimal_bucket_bytes(total, G, hw)
            t_star = bucketed_allreduce_time(total, n_tensors, b_star, G, hw,
                                             backend=backend)
            out.append((f"{pre}/{tag}/opt_bucket_MiB", b_star / MIB,
                        f"closed_form_ms={t_star * 1e3:.3f}"))
        # hierarchical vs flat at 128 nodes (8 pods x 16), 4 MiB buckets;
        # the backend drives the flat ring / the in-pod stage, the
        # cross-pod hop stays lax (make_schedule's default pairing)
        plan4 = plan_buckets(leaves, G_PODS * G_IN, 4 * MIB)
        fill4 = max(b.size for b in plan4.buckets) * SIZE_F32
        t_flat = bucketed_allreduce_time(total, n_tensors, 4 * MIB,
                                         G_PODS * G_IN, FDR,
                                         n_coll=plan4.n_collectives,
                                         fill_bytes=fill4, backend=backend)
        t_hier = hierarchical_allreduce_time(total, n_tensors, 4 * MIB,
                                             G_IN, G_PODS, FDR,
                                             pod_bw=4 * FDR.link_bw,
                                             n_coll=plan4.n_collectives,
                                             fill_bytes=fill4,
                                             backend=backend)
        out.append((f"{pre}/hier128_flat_ms", t_flat * 1e3,
                    f"ring={G_PODS * G_IN}"))
        out.append((f"{pre}/hier128_two_level_ms", t_hier * 1e3,
                    f"in_pod={G_IN};cross_pod={G_PODS}"))
    return out


def wire_rows(backend: str = "lax"):
    """Per wire format (``CommConfig.wire_format``): the format-optimal
    bucket, the predicted roundtrip at it, the reduce-side bytes on the
    wire (the broadcast side always stays dense fp32 — weights), and the
    predicted crossover: the smallest sweep bucket at which the format's
    roundtrip beats fp32's AT THE SAME BUCKET.  In the §3.2 wire-only model
    a compressed format wins at every bucket (only the bandwidth term
    shrinks), so the predicted crossover is the sweep floor — the measured
    rows record where the quantize/select compute actually pays for itself
    on a real schedule."""
    out = []
    for net in ("vgg-a", "overfeat-fast"):
        leaves, _ = grad_tree(net)
        total = sum(_size(lyr) for lyr in leaves) * SIZE_F32
        n_tensors = len(leaves)
        for hw, tag in ((FDR, "FDR"), (GBE, "10GbE")):
            pre = f"comm/{net}/{backend}/{tag}"
            for fmt in WIRE_FORMATS:
                b_star = optimal_bucket_bytes(total, G, hw, wire_format=fmt,
                                              topk_ratio=TOPK_RATIO)
                plan = plan_buckets(leaves, G, int(b_star))
                t = compressed_allreduce_time(
                    total, n_tensors, b_star, G, hw, wire_format=fmt,
                    topk_ratio=TOPK_RATIO, n_coll=plan.n_collectives,
                    backend=backend)
                rbytes = wire_reduce_bytes(total, G, plan.n_collectives,
                                           fmt, TOPK_RATIO)
                out.append((f"{pre}/wire_{fmt}_ms", t * 1e3,
                            f"opt_bucket_MiB={b_star / MIB:.2f};"
                            f"n_coll={plan.n_collectives}"))
                out.append((f"{pre}/wire_{fmt}_reduce_MiB", rbytes / MIB,
                            f"factor_vs_fp32={rbytes / total:.4f}"))
                cross = -1.0
                for mib in SWEEP_MIB:
                    p = plan_buckets(leaves, G, int(mib * MIB))
                    t_fmt = compressed_allreduce_time(
                        total, n_tensors, mib * MIB, G, hw, wire_format=fmt,
                        topk_ratio=TOPK_RATIO, n_coll=p.n_collectives,
                        backend=backend)
                    t_fp32 = compressed_allreduce_time(
                        total, n_tensors, mib * MIB, G, hw,
                        n_coll=p.n_collectives, backend=backend)
                    if t_fmt <= t_fp32:
                        cross = mib
                        break
                out.append((f"{pre}/wire_{fmt}_crossover_MiB", cross,
                            "smallest sweep bucket beating fp32 "
                            "(predicted; -1 = never)"))
    return out


# ---------------------------------------------------------------------------
# measured: the real executable schedule on a forced host mesh
# ---------------------------------------------------------------------------
_MEASURE_SNIPPET = """
    import time

    import jax
    import jax.numpy as jnp
    from jax.sharding import AxisType, PartitionSpec as P

    from repro.api import adapter_for
    from repro.comm import make_schedule, pack_bucket, plan_buckets
    from repro.configs import get_config, smoke_variant

    BACKEND = {backend!r}
    G = {devices}
    cfg = smoke_variant(get_config("vgg-a"))
    params = adapter_for(cfg).init(cfg, jax.random.PRNGKey(0))
    flat = tuple(jax.tree.leaves(params))
    mesh = jax.make_mesh((G,), ("data",), axis_types=(AxisType.Auto,))

    for fmt in {fmts}:
        sched = make_schedule("data", backend=BACKEND, wire_format=fmt)
        for mib in {mibs}:
            plan = plan_buckets(params, G, int(mib * 2**20))

            def roundtrip(leaves):
                bufs = [pack_bucket(leaves, b) for b in plan.buckets]
                return [sched.broadcast(sched.reduce(buf) / G)
                        for buf in bufs]

            specs = jax.tree.map(lambda _: P(), flat)
            fn = jax.jit(jax.shard_map(roundtrip, mesh=mesh,
                                       in_specs=(specs,),
                                       out_specs=P(), check_vma=False))
            with jax.set_mesh(mesh):
                jax.block_until_ready(fn(flat))          # compile
                best = float("inf")
                for _ in range(3):
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn(flat))
                    best = min(best, time.perf_counter() - t0)
            print(f"MEASURED fmt={{fmt}} mib={{mib}} ms={{best * 1e3:.4f}} "
                  f"n_coll={{plan.n_collectives}} "
                  f"bytes={{plan.total_padded * 4}}")
"""


def measured_rows(backend: str = "lax", devices: int = MEASURED_DEVICES):
    """Wall-clock the real ``FlatSchedule(backend)`` bucket round-trip over
    the vgg-a SMOKE tree on ``devices`` forced host devices (subprocess so
    the forced device count never leaks into the caller), per wire format,
    paired with the §3.2 model's prediction for the same plan in the
    derived column.  Adds per-format measured CROSSOVER rows: the smallest
    measured bucket where the compressed roundtrip actually beats fp32
    (-1 = never — on a host mesh the shared-memory 'wire' is nearly free,
    so the quantize/select compute usually dominates; on real links the
    bandwidth win flips it, which is exactly what the crossover row
    tracks)."""
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        PYTHONPATH=os.pathsep.join(
            p for p in (os.path.join(os.path.dirname(__file__), "..", "src"),
                        os.environ.get("PYTHONPATH")) if p))
    code = "import repro.jaxcompat\n" + textwrap.dedent(
        _MEASURE_SNIPPET.format(backend=backend, devices=devices,
                                mibs=MEASURED_MIB, fmts=MEASURED_FORMATS))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(f"measure subprocess failed:\n{proc.stderr[-2000:]}")
    out = []
    ms_by = {}
    for line in proc.stdout.splitlines():
        m = re.match(r"MEASURED fmt=(\w+) mib=([\d.]+) ms=([\d.]+) "
                     r"n_coll=(\d+) bytes=(\d+)", line)
        if not m:
            continue
        fmt, mib, ms, n_coll, nbytes = (m.group(1), float(m.group(2)),
                                        float(m.group(3)), int(m.group(4)),
                                        int(m.group(5)))
        pred = compressed_allreduce_time(
            nbytes, n_coll, mib * MIB, devices, FDR, wire_format=fmt,
            topk_ratio=TOPK_RATIO, n_coll=n_coll, backend=backend)
        ms_by[(fmt, mib)] = ms
        out.append((f"comm/vgg-a-smoke/{backend}/measured_{fmt}_{mib}MiB_ms",
                    ms,
                    f"predicted_FDR_ms={pred * 1e3:.4f};n_coll={n_coll};"
                    f"G={devices}"))
    for fmt in MEASURED_FORMATS:
        if fmt == "fp32":
            continue
        cross = next((mib for mib in MEASURED_MIB
                      if (fmt, mib) in ms_by and ("fp32", mib) in ms_by
                      and ms_by[(fmt, mib)] <= ms_by[("fp32", mib)]), -1.0)
        out.append((f"comm/vgg-a-smoke/{backend}/measured_crossover_"
                    f"{fmt}_MiB", float(cross),
                    "smallest measured bucket beating fp32 (-1 = never; "
                    "host-mesh wall clock, advisory)"))
    return out


def report(backends, measured: bool = True) -> dict:
    """The persisted BENCH_comm.json payload: every predicted and measured
    row per backend, plus the regression gates CI asserts.

    The gates sit on the PREDICTED side only — the §3.2 model is
    deterministic, so ``bucketed faster than per-tensor`` and ``two-level
    faster than one flat 128-ring`` must hold on every run; the measured
    host-mesh wall clocks are recorded for trend inspection but not hard-
    gated (CPU wall clock at smoke scale is runner-noise-bound, and the
    bucketing win is a latency-term effect the forced host mesh does not
    reproduce)."""
    out = {"benchmark": "comm_bucket_sweep",
           "predicted": {}, "measured": {}, "gates": {}}
    speedups, hiers, reductions = {}, {}, {}
    for backend in backends:
        pred = {}
        for name, v, derived in rows(backend) + wire_rows(backend):
            pred[name] = {"value": v, "derived": derived}
        out["predicted"][backend] = pred
        for net in ("vgg-a", "overfeat-fast"):
            pre = f"comm/{net}/{backend}"
            for tag in ("FDR", "10GbE"):
                t0 = pred[f"{pre}/{tag}/per_tensor_ms"]["value"]
                tb = pred[f"{pre}/{tag}/bucket_4.0MiB_ms"]["value"]
                speedups[f"{net}/{tag}/{backend}"] = t0 / tb
            hiers[f"{net}/{backend}"] = (
                pred[f"{pre}/hier128_flat_ms"]["value"]
                / pred[f"{pre}/hier128_two_level_ms"]["value"])
            # the acceptance gate counts REDUCE-side wire bytes at each
            # format's own optimal bucket (the broadcast side is identical
            # dense fp32 for every format, so it cancels)
            reductions[f"{net}/{backend}"] = (
                pred[f"{pre}/FDR/wire_fp32_reduce_MiB"]["value"]
                / pred[f"{pre}/FDR/wire_int8_reduce_MiB"]["value"])
        if measured:
            out["measured"][backend] = {
                name: {"value": v, "derived": derived}
                for name, v, derived in measured_rows(backend)}
    out["gates"] = {
        "predicted_bucketed_speedup": speedups,
        "predicted_hier128_speedup": hiers,
        "predicted_int8_bytes_reduction": reductions,
        "min_predicted_bucketed_speedup": min(speedups.values()),
        "min_predicted_hier128_speedup": min(hiers.values()),
        "min_predicted_int8_bytes_reduction": min(reductions.values()),
    }
    return out


def main(argv=None):
    import argparse
    import json
    import os.path

    from repro.comm import COLLECTIVE_BACKENDS
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="lax",
                    choices=list(COLLECTIVE_BACKENDS))
    ap.add_argument("--no-measured", action="store_true",
                    help="skip the host-mesh wall-clock section "
                         "(model-predicted rows only)")
    ap.add_argument("--out", default=None,
                    help="also sweep EVERY backend and persist the full "
                         "predicted-vs-measured report + regression gates "
                         "as JSON (CI: benchmarks/BENCH_comm.json)")
    args = ap.parse_args(argv)
    print(f"{'metric':48s} {'value':>12s}  derived")
    all_rows = rows(args.backend) + wire_rows(args.backend)
    if not args.no_measured:
        all_rows += measured_rows(args.backend)
    for name, v, derived in all_rows:
        print(f"{name:48s} {v:12.4f}  {derived}")
    if args.out:
        rep = report(list(COLLECTIVE_BACKENDS),
                     measured=not args.no_measured)
        out = args.out if os.path.isabs(args.out) else os.path.join(
            os.path.dirname(os.path.abspath(__file__)), args.out)
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump(rep, f, indent=2)
            f.write("\n")
        print(f"# wrote {out}  "
              f"(min bucketed speedup "
              f"{rep['gates']['min_predicted_bucketed_speedup']:.2f}x, "
              f"min hier128 speedup "
              f"{rep['gates']['min_predicted_hier128_speedup']:.2f}x, "
              f"min int8 bytes reduction "
              f"{rep['gates']['min_predicted_int8_bytes_reduction']:.2f}x)")
    return all_rows


if __name__ == "__main__":
    main()
