"""Bucket-size sweep for the gradient communication subsystem (repro.comm).

For the paper's CNN workloads this sweeps the fusion-buffer size over the
§3.2 latency+bucket model (core.balance): per step, the collective count
drops from O(#tensors) — one part-reduce/part-broadcast pair per tensor, the
seed schedule — to O(total_bytes / bucket_bytes), and the predicted gradient
round-trip time bottoms out near the closed-form optimum
``optimal_bucket_bytes`` = sqrt(B * SWlat * BW * G).  The hierarchical rows
compare one flat 128-member ring against the two-level in-pod + cross-pod
composition on the same tree.

Collective counts come from the REAL planner (repro.comm.plan_buckets over
the actual weight-tensor shapes), so they match what the bucketed
``make_distributed_update`` would issue; only the times are model-predicted.

The ``overlap_*`` rows report the predicted EXPOSED communication per step:
with the monolithic schedule every transfer is exposed (overlap off), while
the §3.1 bubble schedule (``CommConfig.overlap`` / ``--overlap``) hides each
bucket's reduce under the backprop remaining below its trigger layer —
``core.balance.bucket_bubble_schedule`` over the same real plan, with the
bucket→layer readiness metadata of ``repro.comm.overlap``.
"""
from __future__ import annotations

import math
import re

import jax

from repro.comm.bucketer import plan_buckets
from repro.comm.overlap import exposed_comm
from repro.configs import (
    get_config, XEON_E5_2698V3_FDR as FDR, XEON_E5_2666V3_10GBE as GBE,
)
from repro.core.balance import (
    SIZE_F32, bucketed_allreduce_time, collective_count, conv_comp_flops,
    fc_comp_flops, hierarchical_allreduce_time, optimal_bucket_bytes,
    ring_collective_time,
)

MIB = 2**20
SWEEP_MIB = (0.25, 1.0, 4.0, 16.0, 32.0)
G = 64           # the paper's 256-minibatch / 4-per-node operating point
MB_NODE = 4      # data points per node at that operating point
G_PODS, G_IN = 8, 16   # two-level composition of 128 nodes


def grad_tree(net: str):
    """Weight + bias leaves of a paper CNN — the family adapter's param
    specs, i.e. exactly the tree (and tree order) the real bucketed
    ``make_distributed_update`` plans over.  ``core.params.Spec`` is
    shape-only, so plan_buckets runs without materializing VGG-A.
    Returns (leaves, leaf_layer): per flat leaf, the forward layer index it
    belongs to (parsed from the spec names, e.g. ``conv3_w`` -> 3) — the
    readiness metadata the §3.1 overlap schedule needs."""
    from repro.api import adapter_for
    cfg = get_config(net)
    flat = jax.tree_util.tree_flatten_with_path(
        adapter_for(cfg).param_specs(cfg))[0]
    leaves = [leaf for _, leaf in flat]
    leaf_layer = [int(re.search(r"\d+", jax.tree_util.keystr(p)).group())
                  for p, _ in flat]
    return leaves, leaf_layer


def layer_comps(net: str):
    """Per forward layer, FLOPs per node per iteration (3 passes) at the
    G=64 operating point; pool layers contribute ~0."""
    cfg = get_config(net)
    comps = []
    for lyr in cfg.layers:
        if lyr.kind == "conv":
            comps.append(conv_comp_flops(lyr, MB_NODE))
        elif lyr.kind == "fc":
            comps.append(fc_comp_flops(lyr.ifm, lyr.ofm, MB_NODE))
        else:
            comps.append(0.0)
    return comps


def _size(leaf) -> int:
    return math.prod(leaf.shape)


def rows():
    out = []
    for net in ("vgg-a", "overfeat-fast"):
        leaves, leaf_layer = grad_tree(net)
        comps = layer_comps(net)
        total = sum(_size(lyr) for lyr in leaves) * SIZE_F32
        n_tensors = len(leaves)
        out.append((f"comm/{net}/n_tensors", n_tensors, ""))
        out.append((f"comm/{net}/grad_MiB", total / MIB, ""))
        # the serialization granularity of each schedule is its largest
        # single message: the biggest tensor for per-tensor, the biggest
        # fusion buffer for bucketed plans
        max_leaf = max(_size(lyr) for lyr in leaves) * SIZE_F32
        for hw, tag in ((FDR, "FDR"), (GBE, "10GbE")):
            # per-tensor baseline: the seed schedule's collective count
            t0 = bucketed_allreduce_time(total, n_tensors, 0, G, hw,
                                         fill_bytes=max_leaf)
            out.append((f"comm/{net}/{tag}/per_tensor_ms", t0 * 1e3,
                        f"n_coll={n_tensors};fill_MiB={max_leaf / MIB:.1f}"))
            for mib in SWEEP_MIB:
                plan = plan_buckets(leaves, G, int(mib * MIB))
                n_model = collective_count(total, n_tensors, mib * MIB)
                fill = max(b.size for b in plan.buckets) * SIZE_F32
                # time uses the REAL plan's count and largest buffer (the
                # planner never splits a tensor, so it can issue far fewer
                # collectives than the closed-form ceil(total/bucket) —
                # the `model=` column shows that law)
                t = bucketed_allreduce_time(total, n_tensors, mib * MIB,
                                            G, hw,
                                            n_coll=plan.n_collectives,
                                            fill_bytes=fill)
                out.append((f"comm/{net}/{tag}/bucket_{mib}MiB_ms", t * 1e3,
                            f"n_coll={plan.n_collectives};model={n_model}"))
                # §3.1 overlap: exposed-comm with the bubble schedule over
                # the SAME real plan vs. the monolithic (all-exposed) path
                comm_times = [ring_collective_time(
                    b.padded_size * SIZE_F32, G, hw) for b in plan.buckets]
                off, on, _ = exposed_comm(plan, comm_times, comps, hw,
                                          leaf_layer=leaf_layer,
                                          efficiency=0.75)
                hidden = 100.0 * (1.0 - on / off) if off > 0 else 0.0
                out.append((
                    f"comm/{net}/{tag}/overlap_{mib}MiB_exposed_ms",
                    on * 1e3,
                    f"off={off * 1e3:.3f}ms;hidden={hidden:.0f}%"))
            # closed-form optimum (splittable-tensor model — the planner
            # rows above carry the real unsplittable-tensor counts)
            b_star = optimal_bucket_bytes(total, G, hw)
            t_star = bucketed_allreduce_time(total, n_tensors, b_star, G, hw)
            out.append((f"comm/{net}/{tag}/opt_bucket_MiB", b_star / MIB,
                        f"closed_form_ms={t_star * 1e3:.3f}"))
        # hierarchical vs flat at 128 nodes (8 pods x 16), 4 MiB buckets
        plan4 = plan_buckets(leaves, G_PODS * G_IN, 4 * MIB)
        fill4 = max(b.size for b in plan4.buckets) * SIZE_F32
        t_flat = bucketed_allreduce_time(total, n_tensors, 4 * MIB,
                                         G_PODS * G_IN, FDR,
                                         n_coll=plan4.n_collectives,
                                         fill_bytes=fill4)
        t_hier = hierarchical_allreduce_time(total, n_tensors, 4 * MIB,
                                             G_IN, G_PODS, FDR,
                                             pod_bw=4 * FDR.link_bw,
                                             n_coll=plan4.n_collectives,
                                             fill_bytes=fill4)
        out.append((f"comm/{net}/hier128_flat_ms", t_flat * 1e3,
                    f"ring={G_PODS * G_IN}"))
        out.append((f"comm/{net}/hier128_two_level_ms", t_hier * 1e3,
                    f"in_pod={G_IN};cross_pod={G_PODS}"))
    return out


def main():
    print(f"{'metric':48s} {'value':>12s}  derived")
    for name, v, derived in rows():
        print(f"{name:48s} {v:12.4f}  {derived}")


if __name__ == "__main__":
    main()
