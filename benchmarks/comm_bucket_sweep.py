"""Bucket-size sweep for the gradient communication subsystem (repro.comm).

For the paper's CNN workloads this sweeps the fusion-buffer size over the
§3.2 latency+bucket model (core.balance): per step, the collective count
drops from O(#tensors) — one part-reduce/part-broadcast pair per tensor, the
seed schedule — to O(total_bytes / bucket_bytes), and the predicted gradient
round-trip time bottoms out near the closed-form optimum
``optimal_bucket_bytes`` = sqrt(B * SWlat * BW * G).  The hierarchical rows
compare one flat 128-member ring against the two-level in-pod + cross-pod
composition on the same tree.

Collective counts come from the REAL planner (repro.comm.plan_buckets over
the actual weight-tensor shapes), so they match what the bucketed
``make_distributed_update`` would issue; only the times are model-predicted.
"""
from __future__ import annotations

import math

import jax

from repro.comm.bucketer import plan_buckets
from repro.configs import (
    get_config, XEON_E5_2698V3_FDR as FDR, XEON_E5_2666V3_10GBE as GBE,
)
from repro.core.balance import (
    SIZE_F32, bucketed_allreduce_time, collective_count,
    hierarchical_allreduce_time, optimal_bucket_bytes,
)

MIB = 2**20
SWEEP_MIB = (0.25, 1.0, 4.0, 16.0, 32.0)
G = 64           # the paper's 256-minibatch / 4-per-node operating point
G_PODS, G_IN = 8, 16   # two-level composition of 128 nodes


def grad_tree(net: str):
    """Weight + bias leaves of a paper CNN — the family adapter's param
    specs, i.e. exactly the tree (and tree order) the real bucketed
    ``make_distributed_update`` plans over.  ``core.params.Spec`` is
    shape-only, so plan_buckets runs without materializing VGG-A."""
    from repro.api import adapter_for
    cfg = get_config(net)
    return jax.tree.leaves(adapter_for(cfg).param_specs(cfg))


def _size(leaf) -> int:
    return math.prod(leaf.shape)


def rows():
    out = []
    for net in ("vgg-a", "overfeat-fast"):
        leaves = grad_tree(net)
        total = sum(_size(l) for l in leaves) * SIZE_F32
        n_tensors = len(leaves)
        out.append((f"comm/{net}/n_tensors", n_tensors, ""))
        out.append((f"comm/{net}/grad_MiB", total / MIB, ""))
        # the serialization granularity of each schedule is its largest
        # single message: the biggest tensor for per-tensor, the biggest
        # fusion buffer for bucketed plans
        max_leaf = max(_size(l) for l in leaves) * SIZE_F32
        for hw, tag in ((FDR, "FDR"), (GBE, "10GbE")):
            # per-tensor baseline: the seed schedule's collective count
            t0 = bucketed_allreduce_time(total, n_tensors, 0, G, hw,
                                         fill_bytes=max_leaf)
            out.append((f"comm/{net}/{tag}/per_tensor_ms", t0 * 1e3,
                        f"n_coll={n_tensors};fill_MiB={max_leaf / MIB:.1f}"))
            for mib in SWEEP_MIB:
                plan = plan_buckets(leaves, G, int(mib * MIB))
                n_model = collective_count(total, n_tensors, mib * MIB)
                fill = max(b.size for b in plan.buckets) * SIZE_F32
                # time uses the REAL plan's count and largest buffer (the
                # planner never splits a tensor, so it can issue far fewer
                # collectives than the closed-form ceil(total/bucket) —
                # the `model=` column shows that law)
                t = bucketed_allreduce_time(total, n_tensors, mib * MIB,
                                            G, hw,
                                            n_coll=plan.n_collectives,
                                            fill_bytes=fill)
                out.append((f"comm/{net}/{tag}/bucket_{mib}MiB_ms", t * 1e3,
                            f"n_coll={plan.n_collectives};model={n_model}"))
            # closed-form optimum (splittable-tensor model — the planner
            # rows above carry the real unsplittable-tensor counts)
            b_star = optimal_bucket_bytes(total, G, hw)
            t_star = bucketed_allreduce_time(total, n_tensors, b_star, G, hw)
            out.append((f"comm/{net}/{tag}/opt_bucket_MiB", b_star / MIB,
                        f"closed_form_ms={t_star * 1e3:.3f}"))
        # hierarchical vs flat at 128 nodes (8 pods x 16), 4 MiB buckets
        plan4 = plan_buckets(leaves, G_PODS * G_IN, 4 * MIB)
        fill4 = max(b.size for b in plan4.buckets) * SIZE_F32
        t_flat = bucketed_allreduce_time(total, n_tensors, 4 * MIB,
                                         G_PODS * G_IN, FDR,
                                         n_coll=plan4.n_collectives,
                                         fill_bytes=fill4)
        t_hier = hierarchical_allreduce_time(total, n_tensors, 4 * MIB,
                                             G_IN, G_PODS, FDR,
                                             pod_bw=4 * FDR.link_bw,
                                             n_coll=plan4.n_collectives,
                                             fill_bytes=fill4)
        out.append((f"comm/{net}/hier128_flat_ms", t_flat * 1e3,
                    f"ring={G_PODS * G_IN}"))
        out.append((f"comm/{net}/hier128_two_level_ms", t_hier * 1e3,
                    f"in_pod={G_IN};cross_pod={G_PODS}"))
    return out


def main():
    print(f"{'metric':48s} {'value':>12s}  derived")
    for name, v, derived in rows():
        print(f"{name:48s} {v:12.4f}  {derived}")


if __name__ == "__main__":
    main()
