"""Benchmark regression gate: fresh BENCH_*.json vs the committed baselines.

CI regenerates each benchmark report into a scratch dir and this script
compares it against the baseline committed under ``benchmarks/``, one
tolerance rule per metric class:

* **deterministic** metrics (the closed-form §3.2/§3.3 model predictions,
  request/token counts of a seeded workload) must match the baseline to a
  tight relative band or exactly — a drift here means the MODEL changed,
  not the machine;
* **gate** metrics are hard floors/booleans (bucketing must win, the
  128-node hierarchical speedup must hold, every kernel must match its
  oracle) — these replace the inline asserts that used to live in ci.yml;
* **wall-clock** metrics (measured collective times, kernel µs, serve
  latencies/throughput) are advisory: shared CI runners are far too noisy
  to gate on, so out-of-band values print a warning but never fail.

    PYTHONPATH=src:. python benchmarks/check_regression.py \\
        --fresh-dir /tmp/bench            # baselines default to benchmarks/

Exits nonzero on any regression (tight-band violation, gate failure, or a
baselined metric missing from the fresh report).
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import sys
from pathlib import Path

#: per-file rule tables: first pattern (fnmatch on the flattened dotted
#: path) that matches a metric wins.  Rule kinds:
#:   ("rel", tol)       |fresh-base| <= tol*max(|base|,1e-12)   -> else FAIL
#:   ("equal",)         fresh == base                           -> else FAIL
#:   ("floor", x)       fresh > x (baseline not consulted)      -> else FAIL
#:   ("advisory", r)    warn when fresh/base leaves [1/r, r]    -> never FAIL
#:   ("ignore",)        not compared
RULES = {
    "BENCH_comm.json": [
        ("gates.min_predicted_bucketed_speedup", ("floor", 1.0)),
        ("gates.min_predicted_hier128_speedup", ("floor", 3.0)),
        # int8 must cut reduce-side bytes-on-wire by >= 3.5x vs fp32 at
        # each format's own optimal bucket (4x payload minus the
        # per-message scale overhead)
        ("gates.min_predicted_int8_bytes_reduction", ("floor", 3.5)),
        ("gates.*", ("rel", 0.01)),
        ("predicted.*.value", ("rel", 0.01)),
        ("measured.*.value", ("advisory", 8.0)),
        ("*", ("ignore",)),
    ],
    "BENCH_fig5.json": [
        # compressed-wire convergence: the seeded smoke curves must stay
        # inside their relative-gap tolerances (int8 1%, topk 5%)
        ("gates.int8_within_tol", ("equal",)),
        ("gates.topk_within_tol", ("equal",)),
        ("rows.*.value", ("rel", 0.05)),
        ("*", ("ignore",)),
    ],
    "BENCH_kernels.json": [
        ("gates.all_ok", ("equal",)),
        ("gates.n_kernels", ("floor", 3.0)),      # >= 4 kernels covered
        ("rows.*.us", ("advisory", 8.0)),
        ("*", ("ignore",)),
    ],
    "BENCH_serve.json": [
        ("continuous_speedup", ("floor", 1.0)),
        ("policies.*.requests", ("equal",)),      # seeded workload: exact
        ("policies.*.output_tokens", ("equal",)),
        ("policies.*.tokens_per_s", ("advisory", 8.0)),
        ("policies.*.latency_*", ("advisory", 8.0)),
        ("policies.*.ttft_*", ("advisory", 8.0)),
        ("*", ("ignore",)),
    ],
}

#: fresh report sections that must be non-empty (a benchmark that silently
#: skipped its measurement pass must not sail through the gate)
REQUIRED_PREFIXES = {"BENCH_comm.json": ["measured."]}


def flatten(obj, prefix="") -> dict:
    """Nested dict -> {dotted.path: scalar} over numbers/bools/strings."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(flatten(v, f"{prefix}{i}."))
    else:
        out[prefix[:-1]] = obj
    return out


def rule_for(fname: str, path: str):
    for pat, rule in RULES[fname]:
        if fnmatch.fnmatch(path, pat):
            return rule
    return ("ignore",)


def check_file(fname: str, fresh_dir: Path, base_dir: Path):
    """Returns (failures, warnings) message lists for one report."""
    fails, warns = [], []
    fpath, bpath = fresh_dir / fname, base_dir / fname
    if not fpath.exists():
        return [f"{fname}: fresh report missing ({fpath})"], []
    if not bpath.exists():
        return [f"{fname}: committed baseline missing ({bpath})"], []
    fresh = flatten(json.loads(fpath.read_text()))
    base = flatten(json.loads(bpath.read_text()))

    for prefix in REQUIRED_PREFIXES.get(fname, []):
        if not any(p.startswith(prefix) for p in fresh):
            fails.append(f"{fname}: fresh report has no '{prefix}*' "
                         "metrics — the measurement pass did not run")

    for path in sorted(set(base) | set(fresh)):
        kind, *arg = rule_for(fname, path)
        if kind == "ignore":
            continue
        f, b = fresh.get(path), base.get(path)
        tag = f"{fname}: {path}"
        if f is None:
            (warns if kind == "advisory" else fails).append(
                f"{tag} present in baseline but missing from fresh report")
            continue
        if kind == "floor":
            if not (isinstance(f, (int, float)) and f > arg[0]):
                fails.append(f"{tag} = {f!r} violates hard floor > {arg[0]}")
            continue
        if b is None:
            continue   # new metric: baseline to be regenerated, not a fail
        if kind == "equal":
            if f != b:
                fails.append(f"{tag} = {f!r} != baseline {b!r} (exact)")
        elif kind == "rel":
            tol = arg[0]
            if abs(f - b) > tol * max(abs(b), 1e-12):
                fails.append(f"{tag} = {f!r} drifted from baseline {b!r} "
                             f"(> {tol:.0%} relative band)")
        elif kind == "advisory":
            r = arg[0]
            lo, hi = min(abs(b) / r, abs(b) * r), max(abs(b) / r, abs(b) * r)
            if not (lo <= abs(f) <= hi):
                warns.append(f"{tag} = {f!r} vs baseline {b!r} outside the "
                             f"{r}x advisory band (wall-clock; not gating)")
    return fails, warns


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh-dir", required=True,
                    help="directory holding the just-regenerated "
                         "BENCH_*.json reports")
    ap.add_argument("--baseline-dir",
                    default=str(Path(__file__).resolve().parent),
                    help="directory of the committed baselines "
                         "(default: benchmarks/)")
    ap.add_argument("--files", nargs="*", default=sorted(RULES),
                    help="which reports to compare (default: all known)")
    args = ap.parse_args(argv)

    fresh_dir, base_dir = Path(args.fresh_dir), Path(args.baseline_dir)
    all_fails, all_warns = [], []
    for fname in args.files:
        if fname not in RULES:
            ap.error(f"no rule table for {fname!r} (known: {sorted(RULES)})")
        fails, warns = check_file(fname, fresh_dir, base_dir)
        all_fails += fails
        all_warns += warns
        n_checked = sum(1 for p in flatten(
            json.loads((base_dir / fname).read_text()))
            if rule_for(fname, p)[0] != "ignore") \
            if (base_dir / fname).exists() else 0
        print(f"[check_regression] {fname}: {n_checked} baselined metrics, "
              f"{len(fails)} regressions, {len(warns)} advisories")
    for w in all_warns:
        print(f"[check_regression] WARN  {w}")
    for f in all_fails:
        print(f"[check_regression] FAIL  {f}")
    if all_fails:
        print(f"[check_regression] REGRESSION: {len(all_fails)} failure(s)")
        return 1
    print("[check_regression] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
