"""Paper Table 1 — 'Theoretical Scaling of Data Parallelism'.

Reproduces: required comp-to-comms per platform, the per-network algorithmic
ratios (§3.1: OverFeat-FAST 208, VGG-A 1456), minimum data points per node
and the implied node counts for a 256-minibatch run.  Computed from
``core.balance`` — the paper's equations — and printed next to the paper's
reported values."""
from __future__ import annotations

import math

from repro.configs import XEON_E5_2666V3_10GBE as GBE, XEON_E5_2698V3_FDR as FDR, get_config
from repro.core import balance
from repro.core.balance import (
    SIZE_F32,
    LayerBalance,
    conv_comp_flops,
    data_parallel_comm_bytes,
    max_data_parallel_nodes,
    optimal_bucket_bytes,
)

PAPER = {
    ("comp_to_comms", "FDR"): 336, ("comp_to_comms", "10GbE"): 1336,
    ("ratio", "overfeat-fast"): 208, ("ratio", "vgg-a"): 1456,
    ("min_points", "overfeat-fast", "FDR"): 2,
    ("min_points", "overfeat-fast", "10GbE"): 3,
    ("min_points", "vgg-a", "FDR"): 1,
    ("min_points", "vgg-a", "10GbE"): 1,
}


def rows():
    out = []
    out.append(("table1/comp_to_comms_FDR",
                FDR.peak_flops / FDR.link_bw, PAPER[("comp_to_comms", "FDR")]))
    out.append(("table1/comp_to_comms_10GbE",
                GBE.peak_flops / GBE.link_bw,
                PAPER[("comp_to_comms", "10GbE")]))
    for net in ("overfeat-fast", "vgg-a"):
        cfg = get_config(net)
        r = balance.aggregate_comp_comm_ratio(cfg.conv_layers())
        out.append((f"table1/comp_comm_ratio_{net}", r,
                    PAPER[("ratio", net)]))
        layers = [LayerBalance(str(i), conv_comp_flops(lyr, 1),
                               data_parallel_comm_bytes(lyr))
                  for i, lyr in enumerate(cfg.conv_layers())]
        grad_bytes = SIZE_F32 * sum(
            lyr.ifm * lyr.ofm * max(lyr.kernel, 1) ** 2
            for lyr in cfg.layers if lyr.kind in ("conv", "fc"))
        for hw, tag in ((FDR, "FDR"), (GBE, "10GbE")):
            n = max_data_parallel_nodes(layers, hw, 256)
            min_pts = max(1, math.ceil(256 / max(n, 1)))
            out.append((f"table1/min_points_{net}_{tag}", min_pts,
                        PAPER[("min_points", net, tag)]))
            out.append((f"table1/max_nodes_{net}_{tag}", n, 256 / PAPER[
                ("min_points", net, tag)]))
            # §3.2 latency+bucket extension: the fusion-buffer size that
            # balances SWlat against pipeline fill at the Table-1 node count
            b = optimal_bucket_bytes(grad_bytes, max(1, round(n)), hw)
            out.append((f"table1/opt_bucket_MiB_{net}_{tag}", b / 2**20,
                        float("nan")))
    return out


def main():
    print(f"{'metric':45s} {'computed':>12s} {'paper':>10s}")
    for name, computed, paper in rows():
        print(f"{name:45s} {computed:12.1f} {paper:10.1f}")


if __name__ == "__main__":
    main()
