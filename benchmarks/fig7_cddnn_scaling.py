"""Paper Fig. 7 / §5.4 — CD-DNN (7x2048 FC ASR net) hybrid-parallel scaling.

Paper: 4600 frames/s on one Xeon E5-2697v3 node (4x best prior CPU), 13K
frames/s on 4 nodes (> 3-card K20x), 29.5K frames/s on 16 nodes — i.e. 6.5x
at 16 nodes.  All-FC networks are the worst case for data parallelism
(§3.2), so this exercises the hybrid path with optimal G per layer."""
from __future__ import annotations

from repro.configs import XEON_E5_2697V3, get_config
from repro.core import balance

MB = 1024          # typical ASR minibatch (paper §3.2 mentions >5120 too)
PAPER = {1: 4600.0, 4: 13000.0, 16: 29500.0}


def rows():
    cfg = get_config("cd-dnn")
    out = []
    r1 = balance.dnn_hybrid_scaling(cfg.input_dim, cfg.hidden_dim,
                                    cfg.num_hidden, cfg.output_dim,
                                    MB, 1, XEON_E5_2697V3)
    # frames/s = MB / step_time
    f1 = MB / r1["step_time"]
    out.append(("fig7/cddnn_1node_frames_s", f1, PAPER[1]))
    for n in (2, 4, 8, 16):
        rn = balance.dnn_hybrid_scaling(cfg.input_dim, cfg.hidden_dim,
                                        cfg.num_hidden, cfg.output_dim,
                                        MB, n, XEON_E5_2697V3)
        fn = MB / rn["step_time"]
        paper = PAPER.get(n)
        out.append((f"fig7/cddnn_{n}node_frames_s", fn, paper))
        out.append((f"fig7/cddnn_{n}node_speedup", rn["speedup"],
                    paper / PAPER[1] if paper else None))
    return out


def main():
    print(f"{'metric':45s} {'model':>12s} {'paper':>10s}")
    for name, v, paper in rows():
        p = f"{paper:10.1f}" if paper is not None else "         -"
        print(f"{name:45s} {v:12.1f} {p}")


if __name__ == "__main__":
    main()
