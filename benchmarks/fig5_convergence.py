"""Paper Fig. 5 — convergence identity of distributed synchronous SGD.

The paper's claim: because nothing about the algorithm changes (no
hyperparameters, no compression, no asynchrony), the 32-node and 64-node
training curves OVERLAP the serial curve exactly.  We verify the mechanism:
training a reduced VGG-A with the same global batch split into 1, 2 and 4
synchronous 'nodes' (gradient-accumulation shards, the single-host
equivalent of data parallelism) yields identical loss trajectories."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.data import stream_for
from repro.models import cnn
from repro.optim import MomentumSGD

GLOBAL_BATCH = 16
STEPS = 8


def train_curve(num_nodes: int, seed: int = 0):
    cfg = smoke_variant(get_config("vgg-a"))
    params = cnn.init_params(cfg, jax.random.PRNGKey(seed))
    opt = MomentumSGD(momentum=0.9)
    state = opt.init(params)
    stream = stream_for(cfg, GLOBAL_BATCH, 0, seed=seed)
    losses = []

    @jax.jit
    def grad_on(params, batch):
        return jax.value_and_grad(
            lambda p: cnn.loss_fn(p, cfg, batch))(params)

    for _ in range(STEPS):
        batch = jax.tree.map(jnp.asarray, next(stream))
        shard = GLOBAL_BATCH // num_nodes
        loss_sum, grads = 0.0, None
        for i in range(num_nodes):   # synchronous nodes: grads averaged
            sub = jax.tree.map(lambda t: t[i * shard:(i + 1) * shard], batch)
            lv, g = grad_on(params, sub)
            loss_sum += float(lv) / num_nodes
            grads = g if grads is None else jax.tree.map(
                lambda a, b: a + b, grads, g)
        grads = jax.tree.map(lambda g: g / num_nodes, grads)
        params, state = opt.update(grads, state, params, 5e-3)
        losses.append(loss_sum)
    return np.array(losses)


def rows():
    c1 = train_curve(1)
    c2 = train_curve(2)
    c4 = train_curve(4)
    out = [("fig5/final_loss_serial", float(c1[-1]), None),
           ("fig5/final_loss_2node", float(c2[-1]), float(c1[-1])),
           ("fig5/final_loss_4node", float(c4[-1]), float(c1[-1])),
           ("fig5/max_curve_divergence_2node",
            float(np.max(np.abs(c1 - c2))), 0.0),
           ("fig5/max_curve_divergence_4node",
            float(np.max(np.abs(c1 - c4))), 0.0)]
    return out


def main():
    print(f"{'metric':45s} {'value':>12s} {'paper/ref':>10s}")
    for name, v, paper in rows():
        p = f"{paper:10.4f}" if paper is not None else "         -"
        print(f"{name:45s} {v:12.6f} {p}")


if __name__ == "__main__":
    main()
