"""Paper Fig. 5 — convergence identity of distributed synchronous SGD.

The paper's claim: because nothing about the algorithm changes (no
hyperparameters, no compression, no asynchrony), the 32-node and 64-node
training curves OVERLAP the serial curve exactly.  We verify the mechanism:
training a reduced VGG-A with the same global batch split into 1, 2 and 4
synchronous 'nodes' (gradient-accumulation shards, the single-host
equivalent of data parallelism) yields identical loss trajectories.

The PARALLEL_MODES extension rides the same harness: the sync / stale-sync
/ gossip rows train the same net under the three consistency models' exact
node-level gradient math (full mean / one-step-old mean / rotating
GossipGraD pair mean — mirroring ``optim.dist`` + ``comm.backends.gossip``)
and report the final losses next to each mode's per-step wire-cost
prediction from ``core.balance`` — the convergence-vs-wire-time trade in
one table.

The compressed-wire rows (``CommConfig.wire_format``) do the same for the
lossy encodings: the int8 curve simulates the ring's per-hop
quantize / fp32-accumulate / re-quantize chain per chunk (the exact math
of ``kernels.ring.ring_hop_int8`` via the ``kernels.ref`` oracles), the
topk curve carries each node's error-feedback residual across steps and
re-selects per hop (mirroring ``optim.dist.make_topk_ef_update`` +
``comm.backends.pallas_ring``).  ``--out`` persists the rows and the
within-tolerance convergence gates as BENCH_fig5.json for the CI
regression gate."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.comm.backends.pallas_ring import topk_chunk_k
from repro.configs import XEON_E5_2698V3_FDR, get_config, smoke_variant
from repro.core import balance
from repro.data import stream_for
from repro.kernels import ref as kref
from repro.models import cnn
from repro.optim import MomentumSGD, linear_scale_warmup

GLOBAL_BATCH = 16
STEPS = 8

INT8_TOL = 0.01   # acceptance: int8 final loss within 1% of fp32
TOPK_TOL = 0.05
# ratio for the GATED topk curve.  At the train-path default (0.05) the
# 8-step smoke gap is ~44% — the error-feedback residual closes it over
# LONG horizons, eight steps only bounds it (measured dose-response:
# ratio 0.05 -> 0.44, 0.10 -> 0.10, 0.25 -> 0.035); 0.25 is the densest
# ratio where topk still pays on the wire (2x fewer bytes than fp32, see
# core.balance.wire_reduce_factor) AND converges inside TOPK_TOL here
TOPK_RATIO = 0.25

# linear-scaling validation operating point (Goyal et al. recipe as wired
# into RunSpec via --schedule linear-scale-warmup): everything seeded, so
# these curves are bit-deterministic run to run
LSW_BASE_LR = 2e-3
LSW_STEPS = 40        # base-batch steps; the 2x batch runs LSW_STEPS/2
LSW_SCALE = 2
LSW_WARMUP = 5


def train_curve(num_nodes: int, seed: int = 0):
    cfg = smoke_variant(get_config("vgg-a"))
    params = cnn.init_params(cfg, jax.random.PRNGKey(seed))
    opt = MomentumSGD(momentum=0.9)
    state = opt.init(params)
    stream = stream_for(cfg, GLOBAL_BATCH, 0, seed=seed)
    losses = []

    @jax.jit
    def grad_on(params, batch):
        return jax.value_and_grad(
            lambda p: cnn.loss_fn(p, cfg, batch))(params)

    for _ in range(STEPS):
        batch = jax.tree.map(jnp.asarray, next(stream))
        shard = GLOBAL_BATCH // num_nodes
        loss_sum, grads = 0.0, None
        for i in range(num_nodes):   # synchronous nodes: grads averaged
            sub = jax.tree.map(lambda t: t[i * shard:(i + 1) * shard], batch)
            lv, g = grad_on(params, sub)
            loss_sum += float(lv) / num_nodes
            grads = g if grads is None else jax.tree.map(
                lambda a, b: a + b, grads, g)
        grads = jax.tree.map(lambda g: g / num_nodes, grads)
        params, state = opt.update(grads, state, params, 5e-3)
        losses.append(loss_sum)
    return np.array(losses)


def _mix_grads(mode: str, node_grads, carried, step: int):
    """One step of each consistency model's gradient math, at node level.

    ``node_grads`` is the per-node gradient-tree list; returns (tree the
    optimizer applies, carried state for the next step).  The math mirrors
    the device implementations exactly: sync is the full mean
    (``optim.dist.UpdatePlan.reduce``); stale-sync applies LAST step's mean
    and carries this step's (``make_stale_sync_update`` — step 0 applies
    its own); gossip flattens the trees to one fusion buffer and takes, for
    strip i, the pair mean of nodes i and (i - s) % N with the GossipGraD
    shift s = 1 + step % (N-1) (``comm.backends.gossip`` + the strip
    all-gather reassembly)."""
    n = len(node_grads)
    mean = jax.tree.map(lambda *g: sum(g) / n, *node_grads)
    if mode == "sync":
        return mean, None
    if mode == "stale":
        return (mean if carried is None else carried), mean
    assert mode == "gossip"
    leaves = [jax.tree.leaves(g) for g in node_grads]
    flats, shapes = [], [leaf.shape for leaf in leaves[0]]
    for ls in leaves:
        v = np.concatenate([np.asarray(leaf).ravel() for leaf in ls])
        pad = (-v.size) % n
        if pad:
            v = np.concatenate([v, np.zeros(pad, v.dtype)])
        flats.append(v.reshape(n, -1))     # node's buffer as n chunks
    s = 1 + step % (n - 1)
    strips = [(flats[i][i] + flats[(i - s) % n][i]) / 2.0 for i in range(n)]
    buf, out, off = np.concatenate(strips), [], 0
    for shp in shapes:
        size = int(np.prod(shp))
        out.append(jnp.asarray(buf[off:off + size].reshape(shp)))
        off += size
    treedef = jax.tree.structure(node_grads[0])
    return jax.tree.unflatten(treedef, out), None


def train_curve_mode(mode: str, num_nodes: int = 4, seed: int = 0):
    """``train_curve`` generalized over the consistency model: "sync"
    reproduces ``train_curve(num_nodes)`` exactly; "stale" and "gossip"
    swap in their gradient math via :func:`_mix_grads`."""
    cfg = smoke_variant(get_config("vgg-a"))
    params = cnn.init_params(cfg, jax.random.PRNGKey(seed))
    opt = MomentumSGD(momentum=0.9)
    state = opt.init(params)
    stream = stream_for(cfg, GLOBAL_BATCH, 0, seed=seed)
    losses, carried = [], None

    @jax.jit
    def grad_on(params, batch):
        return jax.value_and_grad(
            lambda p: cnn.loss_fn(p, cfg, batch))(params)

    for step in range(STEPS):
        batch = jax.tree.map(jnp.asarray, next(stream))
        shard = GLOBAL_BATCH // num_nodes
        loss_sum, node_grads = 0.0, []
        for i in range(num_nodes):
            sub = jax.tree.map(lambda t: t[i * shard:(i + 1) * shard], batch)
            lv, g = grad_on(params, sub)
            loss_sum += float(lv) / num_nodes
            node_grads.append(g)
        grads, carried = _mix_grads(mode, node_grads, carried, step)
        params, state = opt.update(grads, state, params, 5e-3)
        losses.append(loss_sum)
    return np.array(losses)


def parallel_mode_rows(num_nodes: int = 4):
    """The three-way consistency-model comparison: final smoke-VGG-A loss
    per mode plus each mode's predicted per-step wire seconds on the
    paper's FDR hardware (``core.balance``) — sync pays the full ring
    round-trip, gossip one partner exchange + the gather, stale-sync the
    sync bytes but hidden behind a whole step of compute."""
    cfg = smoke_variant(get_config("vgg-a"))
    params = cnn.init_params(cfg, jax.random.PRNGKey(0))
    total_bytes = sum(leaf.size * 4 for leaf in jax.tree.leaves(params))
    n_tensors = len(jax.tree.leaves(params))
    hw = XEON_E5_2698V3_FDR
    bucket = 4 * 2 ** 20
    t_sync = balance.bucketed_allreduce_time(total_bytes, n_tensors, bucket,
                                             num_nodes, hw)
    t_gossip = balance.gossip_exchange_time(total_bytes, n_tensors, bucket,
                                            num_nodes, hw)
    c_sync = train_curve_mode("sync", num_nodes)
    c_stale = train_curve_mode("stale", num_nodes)
    c_gossip = train_curve_mode("gossip", num_nodes)
    return [
        ("fig5/mode_final_loss_sync", float(c_sync[-1]), None),
        ("fig5/mode_final_loss_stale", float(c_stale[-1]),
         float(c_sync[-1])),
        ("fig5/mode_final_loss_gossip", float(c_gossip[-1]),
         float(c_sync[-1])),
        ("fig5/mode_wire_s_per_step_sync", t_sync, None),
        # stale-sync sends the sync bytes but a full step of compute hides
        # them; report the wire time it must hide (exposure is
        # stale_sync_exposed_time(t_sync, compute) -> 0 for these nets)
        ("fig5/mode_wire_s_per_step_stale_hidden", t_sync, t_sync),
        ("fig5/mode_wire_s_per_step_gossip", t_gossip, t_sync),
    ]


def _flatten_pad(g, n: int):
    """Gradient tree -> (n, m) chunked fusion buffer (zero-padded to a
    multiple of n — the bucketer's padding contract)."""
    v = jnp.concatenate([leaf.ravel().astype(jnp.float32)
                         for leaf in jax.tree.leaves(g)])
    pad = (-v.size) % n
    if pad:
        v = jnp.concatenate([v, jnp.zeros((pad,), jnp.float32)])
    return v.reshape(n, -1)


def _unflatten(buf, template):
    out, off = [], 0
    for leaf in jax.tree.leaves(template):
        out.append(buf[off:off + leaf.size].reshape(leaf.shape))
        off += leaf.size
    return jax.tree.unflatten(jax.tree.structure(template), out)


def _ring_reduce_compressed(fmt: str, flats, ratio: float):
    """The compressed ring reduce-scatter at node level: ``flats`` is the
    per-node list of (n, m) chunked buffers; chunk c starts at node c+1,
    hops the ring accumulating each node's contribution, and lands on its
    owner c — int8 dequantizes / fp32-accumulates / re-quantizes per hop
    (``kernels.ref.ring_hop_int8_ref``), topk re-selects its k wire
    entries per hop except the last (``ring_hop_topk_ref``; the owner
    keeps the dense accumulator).  Returns the dense concatenated sum."""
    n = len(flats)
    m = flats[0].shape[1]
    strips = []
    for c in range(n):
        start = (c + 1) % n
        if fmt == "int8":
            q, s = kref.int8_quantize_ref(flats[start][c])
            for j in range(2, n + 1):
                q, s = kref.ring_hop_int8_ref(flats[(c + j) % n], q, s, c)
            strips.append(kref.int8_dequantize_ref(q, s))
        else:
            assert fmt == "topk"
            k = topk_chunk_k(m, ratio)
            vals, idx = kref.topk_select_ref(flats[start][c], k)
            dense = kref.topk_scatter_ref(vals, idx, m)
            for j in range(2, n + 1):
                dense = kref.ring_hop_topk_ref(flats[(c + j) % n],
                                               vals, idx, c)
                if j < n:
                    vals, idx = kref.topk_select_ref(dense, k)
            strips.append(dense)
    return jnp.concatenate(strips)


def train_curve_wire(fmt: str, num_nodes: int = 4, seed: int = 0,
                     ratio: float = TOPK_RATIO):
    """``train_curve`` with the compressed-wire gradient path: per step the
    node gradients go through the node-level compressed ring of
    :func:`_ring_reduce_compressed`; topk first adds each node's carried
    error-feedback residual, keeps the bucket-level top k
    (``topk_mask_ref``, floor = num_nodes like ``make_topk_ef_update``)
    and carries the remainder to the next step."""
    cfg = smoke_variant(get_config("vgg-a"))
    params = cnn.init_params(cfg, jax.random.PRNGKey(seed))
    opt = MomentumSGD(momentum=0.9)
    state = opt.init(params)
    stream = stream_for(cfg, GLOBAL_BATCH, 0, seed=seed)
    losses, residuals = [], None

    @jax.jit
    def grad_on(params, batch):
        return jax.value_and_grad(
            lambda p: cnn.loss_fn(p, cfg, batch))(params)

    for _ in range(STEPS):
        batch = jax.tree.map(jnp.asarray, next(stream))
        shard = GLOBAL_BATCH // num_nodes
        loss_sum, node_grads = 0.0, []
        for i in range(num_nodes):
            sub = jax.tree.map(lambda t: t[i * shard:(i + 1) * shard], batch)
            lv, g = grad_on(params, sub)
            loss_sum += float(lv) / num_nodes
            node_grads.append(g)
        bufs = [_flatten_pad(g, num_nodes) for g in node_grads]
        if fmt == "topk":
            kb = topk_chunk_k(bufs[0].size, ratio, floor=num_nodes)
            kept = []
            new_res = []
            for i, b in enumerate(bufs):
                flat = b.reshape(-1)
                if residuals is not None:
                    flat = flat + residuals[i]
                keep = kref.topk_mask_ref(flat, kb)
                new_res.append(flat - keep)
                kept.append(keep.reshape(num_nodes, -1))
            residuals, bufs = new_res, kept
        total = _ring_reduce_compressed(fmt, bufs, ratio) / num_nodes
        grads = _unflatten(total, node_grads[0])
        params, state = opt.update(grads, state, params, 5e-3)
        losses.append(loss_sum)
    return np.array(losses)


def wire_format_rows(num_nodes: int = 4):
    """Compressed-wire convergence vs the fp32 reference: the acceptance
    gate is the relative final-loss gap (int8 within 1%, topk within its
    looser band) — persisted as booleans in BENCH_fig5.json's gates."""
    c_fp32 = train_curve_mode("sync", num_nodes)
    c_int8 = train_curve_wire("int8", num_nodes)
    c_topk = train_curve_wire("topk", num_nodes)
    f = float(c_fp32[-1])
    gap_int8 = abs(float(c_int8[-1]) - f) / abs(f)
    gap_topk = abs(float(c_topk[-1]) - f) / abs(f)
    return [
        ("fig5/wire_final_loss_fp32", f, None),
        ("fig5/wire_final_loss_int8", float(c_int8[-1]), f),
        ("fig5/wire_final_loss_topk", float(c_topk[-1]), f),
        ("fig5/wire_rel_gap_int8", gap_int8, INT8_TOL),
        ("fig5/wire_rel_gap_topk", gap_topk, TOPK_TOL),
    ]


def train_curve_sched(batch: int, steps: int, lr_fn, seed: int = 0):
    """Single-node trajectory under an arbitrary per-step LR schedule —
    the harness for the linear-scaling rows."""
    cfg = smoke_variant(get_config("vgg-a"))
    params = cnn.init_params(cfg, jax.random.PRNGKey(seed))
    opt = MomentumSGD(momentum=0.9)
    state = opt.init(params)
    stream = stream_for(cfg, batch, 0, seed=seed)

    @jax.jit
    def grad_on(params, batch):
        return jax.value_and_grad(
            lambda p: cnn.loss_fn(p, cfg, batch))(params)

    losses = []
    for step in range(steps):
        batch_ = jax.tree.map(jnp.asarray, next(stream))
        lv, g = grad_on(params, batch_)
        params, state = opt.update(g, state, params, float(lr_fn(step)))
        losses.append(float(lv))
    return np.array(losses)


def linear_scaling_rows():
    """Goyal et al. linear-scaling validation (the ``--schedule
    linear-scale-warmup`` recipe): at EQUAL samples seen, doubling the
    global batch with warmed-up 2x LR must land closer to the base-batch
    trajectory than the same doubled batch at the unscaled LR.  All three
    runs are seeded and single-host, so the comparison is deterministic;
    the final row is the gate (< 1 means the recipe closed part of the
    large-batch gap)."""
    sched = linear_scale_warmup(LSW_BASE_LR, LSW_SCALE, LSW_WARMUP,
                                LSW_STEPS // LSW_SCALE, final_frac=1.0)
    base = train_curve_sched(GLOBAL_BATCH, LSW_STEPS,
                             lambda s: LSW_BASE_LR)
    scaled = train_curve_sched(GLOBAL_BATCH * LSW_SCALE,
                               LSW_STEPS // LSW_SCALE, sched)
    unscaled = train_curve_sched(GLOBAL_BATCH * LSW_SCALE,
                                 LSW_STEPS // LSW_SCALE,
                                 lambda s: LSW_BASE_LR)
    gap_lsw = abs(float(scaled[-1]) - float(base[-1]))
    gap_plain = abs(float(unscaled[-1]) - float(base[-1]))
    return [
        ("fig5/lsw_lr_start", float(sched(0)), LSW_BASE_LR),
        ("fig5/lsw_lr_peak", float(sched(LSW_WARMUP)),
         LSW_BASE_LR * LSW_SCALE),
        ("fig5/lsw_final_loss_base_batch", float(base[-1]), None),
        ("fig5/lsw_final_loss_2x_batch_scaled", float(scaled[-1]),
         float(base[-1])),
        ("fig5/lsw_final_loss_2x_batch_unscaled", float(unscaled[-1]),
         float(base[-1])),
        ("fig5/lsw_gap_ratio_vs_unscaled", gap_lsw / gap_plain, 1.0),
    ]


def rows():
    c1 = train_curve(1)
    c2 = train_curve(2)
    c4 = train_curve(4)
    out = [("fig5/final_loss_serial", float(c1[-1]), None),
           ("fig5/final_loss_2node", float(c2[-1]), float(c1[-1])),
           ("fig5/final_loss_4node", float(c4[-1]), float(c1[-1])),
           ("fig5/max_curve_divergence_2node",
            float(np.max(np.abs(c1 - c2))), 0.0),
           ("fig5/max_curve_divergence_4node",
            float(np.max(np.abs(c1 - c4))), 0.0)]
    return out + linear_scaling_rows() + parallel_mode_rows() \
        + wire_format_rows()


def report() -> dict:
    """The persisted BENCH_fig5.json payload: every row plus the
    compressed-wire convergence gates CI asserts."""
    rws = rows()
    d = {name: {"value": v, "ref": ref} for name, v, ref in rws}
    return {
        "benchmark": "fig5_convergence",
        "rows": d,
        "gates": {
            "int8_within_tol":
                d["fig5/wire_rel_gap_int8"]["value"] <= INT8_TOL,
            "topk_within_tol":
                d["fig5/wire_rel_gap_topk"]["value"] <= TOPK_TOL,
        },
    }


def main(argv=None):
    import argparse
    import json
    import os.path

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="persist the rows + convergence gates as JSON "
                         "(CI: benchmarks/BENCH_fig5.json)")
    args = ap.parse_args(argv)
    rep = report()
    print(f"{'metric':45s} {'value':>12s} {'paper/ref':>10s}")
    for name, row in rep["rows"].items():
        ref = row["ref"]
        p = f"{ref:10.4f}" if ref is not None else "         -"
        print(f"{name:45s} {row['value']:12.6f} {p}")
    if args.out:
        out = args.out if os.path.isabs(args.out) else os.path.join(
            os.path.dirname(os.path.abspath(__file__)), args.out)
        with open(out, "w") as f:
            json.dump(rep, f, indent=2)
            f.write("\n")
        print(f"# wrote {out}  (int8_within_tol="
              f"{rep['gates']['int8_within_tol']}, topk_within_tol="
              f"{rep['gates']['topk_within_tol']})")


if __name__ == "__main__":
    main()
