"""Paper Fig. 3 — single-node throughput vs minibatch size.

The paper's claim: PCL-DNN throughput is nearly minibatch-insensitive
(VGG-A: ~95 img/s scoring / ~30 training across MB 16..256).  We check the
*property* on this container by measuring reduced VGG-A/OverFeat throughput
at MB {4, 8, 16, 32} on CPU (throughput per image should be flat once the
device is saturated), and report the analytic Xeon-projection for the full
networks next to the paper's numbers."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import XEON_E5_2698V3_FDR, get_config, smoke_variant
from repro.core import balance
from repro.models import cnn


def measured_rows(minibatches=(4, 8, 16, 32), train: bool = True):
    out = []
    for net in ("vgg-a", "overfeat-fast"):
        cfg = smoke_variant(get_config(net))
        params = cnn.init_params(cfg, jax.random.PRNGKey(0))
        thr = {}
        for mb in minibatches:
            x = jnp.ones((mb, cfg.image_size, cfg.image_size, 3))
            y = jnp.zeros((mb,), jnp.int32)
            if train:
                f = jax.jit(jax.grad(
                    lambda p: cnn.loss_fn(p, cfg, {"images": x, "labels": y})))
            else:
                f = jax.jit(lambda p: cnn.forward(p, cfg, x))
            jax.block_until_ready(f(params))
            t0 = time.perf_counter()
            n = 3
            for _ in range(n):
                jax.block_until_ready(f(params))
            dt = (time.perf_counter() - t0) / n
            thr[mb] = mb / dt
        flat = min(thr.values()) / max(thr.values())
        for mb, v in thr.items():
            out.append((f"fig3/measured_{net}_mb{mb}_img_s", v, None))
        out.append((f"fig3/measured_{net}_flatness", flat, 1.0))
    return out


def analytic_rows():
    """Project full-network Xeon throughput: FLOPs / (peak * efficiency).
    Paper: VGG-A ~30 img/s training, ~95 scoring; OverFeat ~90 / ~315."""
    hw = XEON_E5_2698V3_FDR
    out = []
    paper = {("vgg-a", "train"): 30.0, ("vgg-a", "score"): 95.0,
             ("overfeat-fast", "train"): 90.0,
             ("overfeat-fast", "score"): 315.0}
    for net in ("vgg-a", "overfeat-fast"):
        cfg = get_config(net)
        conv = sum(balance.conv_comp_flops(lyr, 1) for lyr in cfg.conv_layers())
        fc = sum(balance.fc_comp_flops(lyr.ifm, lyr.ofm, 1)
                 for lyr in cfg.fc_layers())
        full = conv + fc                      # 3 passes (train)
        score = full / 3.0                    # forward only
        # paper-reported single-node efficiencies: ~90% conv, 70% FC
        eff = 0.8
        out.append((f"fig3/analytic_{net}_train_img_s",
                    hw.peak_flops * eff / full, paper[(net, "train")]))
        out.append((f"fig3/analytic_{net}_score_img_s",
                    hw.peak_flops * eff / score, paper[(net, "score")]))
    return out


def main():
    print(f"{'metric':45s} {'value':>12s} {'paper':>10s}")
    for name, v, paper in analytic_rows() + measured_rows():
        p = f"{paper:10.2f}" if paper is not None else "         -"
        print(f"{name:45s} {v:12.2f} {p}")


if __name__ == "__main__":
    main()
