"""Kernel microbenchmarks (paper §2 single-node efficiency layer).

On this CPU container the Pallas kernels run in interpret mode, so wall
times are NOT TPU-indicative; what we report per kernel is (a) interpret-
mode us/call for regression tracking, (b) the blocking solver's predicted
B/F and VMEM footprint — the §2.2 quantities the kernel tiles were chosen
by — and (c) allclose-vs-oracle as a pass bit."""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.comm.bucketer import pack_bucket, plan_buckets, unpack_buckets
from repro.core.blocking import solve_conv_blocking, solve_gemm_blocking
from repro.kernels import ref
from repro.kernels.blocked_matmul import blocked_matmul
from repro.kernels.conv2d import conv2d_nhwc
from repro.kernels.flash_attention import flash_attention

RNG = np.random.default_rng(0)


def _t(fn, *args, n=3):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6, out


def rows():
    out = []
    # GEMM: the paper's FC/block-SGEMM case
    a = jnp.asarray(RNG.normal(size=(256, 512)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(512, 1024)), jnp.float32)
    blk = solve_gemm_blocking(256, 1024, 512)
    f = jax.jit(lambda a, b: blocked_matmul(a, b, interpret=True))
    us, got = _t(f, a, b)
    ok = np.allclose(got, ref.matmul_ref(a, b), rtol=2e-4, atol=2e-4)
    out.append(("kernel/blocked_matmul_256x1024x512", us,
                f"bf={blk.bf_ratio:.4f};vmem={blk.bytes_per_block};ok={ok}"))

    # conv: the paper's OverFeat C5 case study (reduced channels for CPU)
    x = jnp.asarray(RNG.normal(size=(1, 14, 14, 64)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(3, 3, 64, 128)), jnp.float32)
    cblk = solve_conv_blocking(1, 64, 128, 12, 3, cache_bytes=8 * 2**20)
    f = jax.jit(lambda x, w: conv2d_nhwc(x, w, stride=1, padding=0,
                                         interpret=True))
    us, got = _t(f, x, w)
    ok = np.allclose(got, ref.conv2d_ref(x, w, 1, 0), rtol=1e-4, atol=1e-4)
    out.append(("kernel/conv2d_c5like_64-128", us,
                f"bf={cblk.bf_ratio:.4f};ok={ok}"))

    # flash attention: gemma2-style local window + softcap
    q = jnp.asarray(RNG.normal(size=(1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 256, 2, 64)), jnp.float32)
    f = jax.jit(lambda q, k, v: flash_attention(
        q, k, v, causal=True, window=128, logit_softcap=50.0,
        interpret=True))
    us, got = _t(f, q, k, v)
    ok = np.allclose(got, ref.attention_ref(q, k, v, causal=True, window=128,
                                            logit_softcap=50.0),
                     rtol=3e-4, atol=3e-4)
    out.append(("kernel/flash_attn_swa_softcap_256", us, f"ok={ok}"))

    # comm bucketer: pack->unpack round-trip overhead on a VGG-ish gradient
    # tree (many small conv/bias leaves + one big fc leaf); the fusion cost
    # the bucketed part-reduce adds to the hot path
    tree = [jnp.asarray(RNG.normal(size=s), jnp.float32)
            for s in [(3, 3, 64, 64), (64,), (3, 3, 64, 128), (128,),
                      (3, 3, 128, 256), (256,), (512, 4096), (4096,)]]
    plan = plan_buckets(tree, group=8, bucket_bytes=2**20)
    f = jax.jit(lambda t: unpack_buckets(
        [pack_bucket(t, b) for b in plan.buckets], plan))
    us, got = _t(f, tree)
    ok = all(np.allclose(a, b) for a, b in zip(got, tree))
    out.append(("kernel/comm_bucket_pack_unpack", us,
                f"n_coll={plan.n_collectives};leaves={plan.n_leaves};ok={ok}"))
    return out


def report(all_rows):
    """The persisted JSON shape (BENCH_comm.json family): per-row
    interpret-mode us + derived blocking/validation string, with the
    oracle pass bits aggregated into the regression gate.  Interpret-mode
    wall time is NOT TPU-indicative, so the gate is correctness-only —
    ``all_ok`` goes false the moment any kernel drifts from its oracle."""
    per_kernel = {name: {"us": round(us, 1), "derived": derived}
                  for name, us, derived in all_rows}
    return {
        "benchmark": "kernels_micro",
        "rows": per_kernel,
        "gates": {
            "n_kernels": len(all_rows),
            "all_ok": all("ok=True" in derived
                          for _, _, derived in all_rows),
        },
    }


def main(argv=None):
    import argparse
    import json
    import os.path

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="persist the per-kernel report + oracle gate as "
                         "JSON (CI: benchmarks/BENCH_kernels.json)")
    args = ap.parse_args(argv)
    all_rows = rows()
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")
    if args.out:
        rep = report(all_rows)
        out = args.out if os.path.isabs(args.out) else os.path.join(
            os.path.dirname(os.path.abspath(__file__)), args.out)
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump(rep, f, indent=2)
            f.write("\n")
        print(f"# wrote {out}  (kernels={rep['gates']['n_kernels']}, "
              f"all_ok={rep['gates']['all_ok']})")
    return all_rows


if __name__ == "__main__":
    main()
