"""Paper Fig. 6 — scaling on AWS EC2 (c4.x8large, 10 GbE, virtualized).

The paper reports 16-node speedups of 11.9x (OverFeat) and 14.2x (VGG-A),
throughputs 1027 / 397 img/s.  Balance model evaluated with the 10 GbE
platform constants (plus the paper's ~35% SR-IOV network improvement)."""
from __future__ import annotations

from dataclasses import replace

from repro.configs import XEON_E5_2666V3_10GBE, get_config
from repro.core import balance

# 'enhanced networking' (SR-IOV + dedicated interrupt core): the paper cites
# 30%-40% better network performance vs the raw 10 GbE figure.
AWS = replace(XEON_E5_2666V3_10GBE, link_bw=XEON_E5_2666V3_10GBE.link_bw
              * 1.35, sw_latency=20e-6)

PAPER = {"overfeat-fast": (11.9, 1027.0), "vgg-a": (14.2, 397.0)}


def rows():
    out = []
    for net, (paper_speedup, paper_imgs) in PAPER.items():
        cfg = get_config(net)
        one = balance.network_balance(cfg.conv_layers(), cfg.fc_layers(),
                                      256, 1, AWS, compute_eff=0.5)
        n16 = balance.network_balance(cfg.conv_layers(), cfg.fc_layers(),
                                      256, 16, AWS, compute_eff=0.5)
        sp = one["step_time"] / n16["step_time"]
        out.append((f"fig6/{net}_speedup_16n", sp, paper_speedup))
        # anchor throughput at the measured single-node rate implied by the
        # paper (paper_imgs / paper_speedup)
        single = paper_imgs / paper_speedup
        out.append((f"fig6/{net}_imgs_per_s_16n", single * sp, paper_imgs))
        out.append((f"fig6/{net}_vgg_scales_better",
                    float(net == "vgg-a"), None))
    # the paper's qualitative claim: VGG-A scales better than OverFeat on
    # Ethernet due to higher flops-per-network-byte
    return out


def main():
    print(f"{'metric':45s} {'model':>10s} {'paper':>10s}")
    for name, v, paper in rows():
        p = f"{paper:10.2f}" if paper is not None else "         -"
        print(f"{name:45s} {v:10.2f} {p}")


if __name__ == "__main__":
    main()
