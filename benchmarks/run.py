"""Benchmark aggregator — one section per paper table/figure.

Prints ``name,value,derived`` CSV rows.  ``derived`` carries the paper's
reported number (when one exists) so reproduction vs paper is visible in
one place; the roofline section summarizes the dry-run table (deliverable g).
"""
from __future__ import annotations

import sys
import traceback


def _emit(name, value, derived=""):
    if isinstance(value, float):
        value = f"{value:.4f}"
    print(f"{name},{value},{derived}", flush=True)


def _section(title):
    print(f"# --- {title} ---", flush=True)


def main() -> None:
    failures = 0

    _section("Table 1: data-parallel balance (paper vs computed)")
    try:
        from benchmarks import table1_balance
        for name, computed, paper in table1_balance.rows():
            _emit(name, float(computed), f"paper={paper}")
    except Exception:
        traceback.print_exc()
        failures += 1

    _section("Fig 3: single-node throughput & minibatch insensitivity")
    try:
        from benchmarks import fig3_single_node
        for name, v, paper in fig3_single_node.analytic_rows():
            _emit(name, float(v), f"paper={paper}")
        for name, v, paper in fig3_single_node.measured_rows():
            _emit(name, float(v), "" if paper is None else f"ref={paper}")
    except Exception:
        traceback.print_exc()
        failures += 1

    _section("Fig 4: VGG-A scaling on Cori (balance model)")
    try:
        from benchmarks import fig4_vgg_scaling
        for name, v, paper, extra in fig4_vgg_scaling.rows():
            _emit(name, float(v), f"paper={paper};{extra}")
    except Exception:
        traceback.print_exc()
        failures += 1

    _section("Fig 5: synchronous-SGD convergence identity")
    try:
        from benchmarks import fig5_convergence
        for name, v, paper in fig5_convergence.rows():
            _emit(name, float(v), "" if paper is None else f"ref={paper}")
    except Exception:
        traceback.print_exc()
        failures += 1

    _section("Fig 6: AWS 10GbE scaling (balance model)")
    try:
        from benchmarks import fig6_aws_scaling
        for name, v, paper in fig6_aws_scaling.rows():
            _emit(name, float(v), "" if paper is None else f"paper={paper}")
    except Exception:
        traceback.print_exc()
        failures += 1

    _section("Fig 7: CD-DNN hybrid-parallel scaling")
    try:
        from benchmarks import fig7_cddnn_scaling
        for name, v, paper in fig7_cddnn_scaling.rows():
            _emit(name, float(v), "" if paper is None else f"paper={paper}")
    except Exception:
        traceback.print_exc()
        failures += 1

    _section("Comm: bucket-size sweep (§3.2 latency model + repro.comm plan)")
    try:
        from benchmarks import comm_bucket_sweep
        from repro.comm import COLLECTIVE_BACKENDS
        for backend in COLLECTIVE_BACKENDS:
            for name, v, derived in comm_bucket_sweep.rows(backend):
                _emit(name, float(v), derived)
    except Exception:
        traceback.print_exc()
        failures += 1

    _section("Kernels: §2 single-node layer (interpret mode)")
    try:
        from benchmarks import kernels_micro
        for name, us, derived in kernels_micro.rows():
            _emit(name, float(us), derived)
    except Exception:
        traceback.print_exc()
        failures += 1

    _section("Roofline: dry-run aggregate (deliverable g)")
    try:
        from benchmarks import roofline_report
        rows = roofline_report.load_rows()
        if rows:
            s = roofline_report.summary(rows)
            _emit("roofline/pairs_total", s["total"])
            _emit("roofline/pairs_ok", s["ok"])
            _emit("roofline/pairs_failed", s["failed"])
            for dom, cnt in sorted(s["dominant_counts"].items()):
                _emit(f"roofline/dominant_{dom}", cnt)
        else:
            _emit("roofline/pairs_total", 0,
                  "run python -m repro.launch.dryrun --all first")
    except Exception:
        traceback.print_exc()
        failures += 1

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
