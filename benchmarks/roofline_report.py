"""Aggregate experiments/dryrun JSONs into the §Dry-run / §Roofline tables.

``python -m benchmarks.roofline_report [--markdown]`` — also used by
EXPERIMENTS.md generation."""
from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_rows(directory: str = DRYRUN_DIR):
    rows = []
    for f in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9,
                             r["mesh"]))
    return rows


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def table(rows, markdown=False):
    hdr = ["arch", "shape", "mesh", "compute", "memory", "collective",
           "dominant", "MF/HLO", "MFU", "mem/dev"]
    lines = []
    if markdown:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    else:
        lines.append("  ".join(f"{h:>10s}" for h in hdr))
    for r in rows:
        if r.get("status") != "ok":
            cells = [r["arch"], r["shape"], r["mesh"], "ERROR",
                     r.get("error", "")[:40], "", "", "", "", ""]
        else:
            cells = [r["arch"], r["shape"], r["mesh"],
                     fmt_s(r["compute_s"]), fmt_s(r["memory_s"]),
                     fmt_s(r["collective_s"]), r["dominant"],
                     f"{r['useful_ratio']:.2f}", f"{r['mfu'] * 100:.1f}%",
                     f"{r['mem_per_dev_gb']:.1f}G"]
        if markdown:
            lines.append("| " + " | ".join(str(c) for c in cells) + " |")
        else:
            lines.append("  ".join(f"{str(c):>10s}" for c in cells))
    return "\n".join(lines)


def summary(rows):
    ok = [r for r in rows if r.get("status") == "ok"]
    bad = [r for r in rows if r.get("status") != "ok"]
    doms = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    return dict(total=len(rows), ok=len(ok), failed=len(bad),
                dominant_counts=doms,
                worst_mfu=sorted((r["mfu"], r["arch"], r["shape"], r["mesh"])
                                 for r in ok if r["shape"] == "train_4k")[:3])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load_rows()
    print(table(rows, markdown=args.markdown))
    print()
    print(json.dumps(summary(rows), indent=1, default=str))


if __name__ == "__main__":
    main()
