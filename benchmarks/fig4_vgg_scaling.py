"""Paper Fig. 4 — VGG-A scaling on Cori (Xeon E5-2698v3, Aries).

The paper reports: 90x speedup at 128 nodes for minibatch 512 (70%
efficiency, 2510 img/s) and 82% efficiency at 64 nodes for minibatch 256.
We evaluate the §3 balance model (conv data-parallel with overlap bubbles +
FC hybrid with optimal G) at the paper's node counts and print model vs
paper.  Single-node training throughput anchor: ~30 img/s (paper Fig. 3)."""
from __future__ import annotations

from repro.configs import XEON_E5_2698V3_FDR, get_config
from repro.configs.base import HardwareConfig
from repro.core import balance

# Cori Aries: higher injection bandwidth than FDR IB
CORI = HardwareConfig(
    name="cori-aries",
    peak_flops=XEON_E5_2698V3_FDR.peak_flops,
    mem_bw=XEON_E5_2698V3_FDR.mem_bw,
    link_bw=10e9,                  # ~10 GB/s Aries injection per node
    sw_latency=3e-6,
    cache_bytes=XEON_E5_2698V3_FDR.cache_bytes,
)

PAPER_POINTS = {
    # nodes: (minibatch, paper_speedup or efficiency)
    (128, 512): ("speedup", 90.0),
    (64, 256): ("efficiency", 0.82),
    (32, 256): ("efficiency", 0.90),   # read off the near-linear region
}


def model_speedup(minibatch: int, nodes: int, compute_eff: float = 0.55):
    cfg = get_config("vgg-a")
    one = balance.network_balance(cfg.conv_layers(), cfg.fc_layers(),
                                  minibatch, 1, CORI, compute_eff)
    n = balance.network_balance(cfg.conv_layers(), cfg.fc_layers(),
                                minibatch, nodes, CORI, compute_eff)
    return one["step_time"] / n["step_time"], n


def rows():
    out = []
    for (nodes, mb), (kind, paper_val) in sorted(PAPER_POINTS.items()):
        sp, n = model_speedup(mb, nodes)
        eff = sp / nodes
        val = sp if kind == "speedup" else eff
        out.append((f"fig4/vgg_mb{mb}_n{nodes}_{kind}", val, paper_val,
                    dict(G_fc=n["G_fc"], model_eff=round(eff, 3))))
    # throughput at the paper's headline point (anchored at 30 img/s/node)
    sp, _ = model_speedup(512, 128)
    out.append(("fig4/vgg_mb512_n128_imgs_per_s", 30.0 * sp, 2510.0, {}))
    return out


def main():
    print(f"{'point':40s} {'model':>10s} {'paper':>10s}  extra")
    for name, val, paper, extra in rows():
        print(f"{name:40s} {val:10.2f} {paper:10.2f}  {extra}")


if __name__ == "__main__":
    main()
