"""Serving load benchmark: static vs continuous batching at EQUAL cache
budget (deliverable for ROADMAP item 1 / BENCH_serve.json baseline).

An open-loop Poisson arrival process drives a heavy-tail request mix
(mostly short decodes, a fat tail of long ones — the regime where a static
wave idles its short requests' slots behind the longest member) against two
servers that differ ONLY in ``ServeSpec.scheduler``.  Reported per policy:
request p50/p99 latency (submit -> finish, queueing included) and decode
throughput.  Continuous batching must WIN throughput — that is the claim
this benchmark pins, and the JSON it writes is the repo's first persisted
perf baseline.

    PYTHONPATH=src python benchmarks/serve_load.py            # CI-sized
    PYTHONPATH=src python benchmarks/serve_load.py --requests 64 --rate 20

Writes ``BENCH_serve.json`` (``--out``) with the full metric set, machine
readable, and prints the aggregator's ``name,value,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.api import ServeSpec, compile_serve


def heavy_tail_workload(rng, n, max_prompt, max_new, rate):
    """(arrival_s, prompt, max_new) triples: Poisson arrivals (exponential
    gaps at ``rate`` req/s), ~1/5 of requests take the full decode budget,
    the rest a short one — the length mix that separates the schedulers."""
    reqs, t = [], 0.0
    for _ in range(n):
        t += rng.exponential(1.0 / rate)
        L = int(rng.integers(2, max_prompt + 1))
        new = max_new if rng.random() < 0.2 else max(max_new // 8, 1)
        prompt = rng.integers(1, 512, size=L).astype(np.int32)
        reqs.append((t, prompt, new))
    return reqs


def run_policy(policy, spec_kw, workload, warm_lengths):
    spec = ServeSpec(scheduler=policy, **spec_kw)
    server = compile_serve(spec)

    # warm every executable (decode + each prefill bucket) OUTSIDE the
    # timed window — this measures scheduling, not XLA compile time
    for L in warm_lengths:
        server.submit(np.ones(L, np.int32), 1)
    server.drain()
    warm_stats = dict(server.stats)
    server.reset_latency_stats()   # warmup requests must not pollute p50/p99

    done = []
    pending = list(workload)
    t0 = time.perf_counter()
    while pending or server.pending or server.active:
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            _, prompt, new = pending.pop(0)
            server.submit(prompt, new)
        if server.pending or server.active:
            done.extend(server.step())
        elif pending:
            time.sleep(min(pending[0][0] - now, 0.01))
    elapsed = time.perf_counter() - t0

    # per-request latency comes from the server's own telemetry histograms
    # (TTFT + e2e, the same aggregates Server.latency_stats serves in
    # production) — the benchmark no longer re-derives percentiles itself
    lat = server.latency_stats()
    n_tok = int(sum(len(r.tokens) for r in done))
    steps = server.stats["steps"] - warm_stats["steps"]
    decoded = server.stats["decode_tokens"] - warm_stats["decode_tokens"]
    return {
        "scheduler": policy,
        "requests": len(done),
        "elapsed_s": round(elapsed, 4),
        "output_tokens": n_tok,
        "tokens_per_s": round(n_tok / elapsed, 2),
        "latency_p50_s": round(lat["e2e_p50_s"], 4),
        "latency_p99_s": round(lat["e2e_p99_s"], 4),
        "ttft_p50_s": round(lat["ttft_p50_s"], 4),
        "ttft_p99_s": round(lat["ttft_p99_s"], 4),
        "scheduler_steps": steps,
        "decode_slot_tokens": decoded,
        "slot_utilization": round(decoded / max(steps * spec.max_batch, 1),
                                  4),
        "preemptions": server.stats["preemptions"] - warm_stats["preemptions"],
    }


def run(args):
    spec_kw = dict(arch=args.arch, smoke=True, max_batch=args.max_batch,
                   page_size=args.page_size, num_pages=args.num_pages,
                   max_prompt=args.max_prompt, max_new_tokens=args.max_new,
                   prefill_bucket=args.max_prompt)  # one bucket: fair warmup
    rng = np.random.default_rng(args.seed)
    workload = heavy_tail_workload(rng, args.requests, args.max_prompt,
                                   args.max_new, args.rate)
    warm = [2, args.max_prompt]
    results = {p: run_policy(p, spec_kw, workload, warm)
               for p in ("static", "continuous")}
    return {
        "benchmark": "serve_load",
        "arch": args.arch,
        "spec": {k: v for k, v in spec_kw.items()},
        "workload": {"requests": args.requests, "rate_per_s": args.rate,
                     "seed": args.seed, "mix": "heavy-tail (20% full-budget "
                     "decodes, rest short)"},
        "policies": results,
        "continuous_speedup": round(
            results["continuous"]["tokens_per_s"]
            / results["static"]["tokens_per_s"], 3),
    }


def rows(report):
    """Aggregator rows (benchmarks/run.py CSV convention)."""
    out = []
    for p, r in report["policies"].items():
        out.append((f"serve/{p}/tokens_per_s", r["tokens_per_s"], ""))
        out.append((f"serve/{p}/latency_p50_s", r["latency_p50_s"], ""))
        out.append((f"serve/{p}/latency_p99_s", r["latency_p99_s"], ""))
        out.append((f"serve/{p}/ttft_p50_s", r["ttft_p50_s"], ""))
        out.append((f"serve/{p}/slot_utilization", r["slot_utilization"], ""))
    out.append(("serve/continuous_speedup", report["continuous_speedup"],
                "continuous/static tokens_per_s, >1 expected"))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--num-pages", type=int, default=64)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent
                                         / "BENCH_serve.json"))
    args = ap.parse_args(argv)

    report = run(args)
    for name, value, derived in rows(report):
        print(f"{name},{value},{derived}", flush=True)
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
