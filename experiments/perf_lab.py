"""§Perf hillclimb driver: lowers config variants for the three selected
(arch x shape x mesh) pairs and records hypothesis -> before -> after rows.

    PYTHONPATH=src python experiments/perf_lab.py --pair qwen2-moe --variant V1
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import dryrun  # noqa: E402  (sets XLA_FLAGS first)
from repro.configs import get_config  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "perf")

PAIRS = {
    "qwen2-moe": ("qwen2-moe-a2.7b", "train_4k", False),
    "mixtral": ("mixtral-8x22b", "train_4k", True),
    "llama3": ("llama3-8b", "train_4k", False),
    "gemma2-decode": ("gemma2-2b", "decode_32k", False),
}

VARIANTS = {
    # ---- qwen2-moe train_4k 16x16 (worst roofline fraction) ----
    ("qwen2-moe", "V1"): dict(moe_down_rs=True),
    ("qwen2-moe", "V2"): dict(moe_expert_pad=4),
    ("qwen2-moe", "V3"): dict(moe_expert_pad=4, loss_chunk=8),
    ("qwen2-moe", "V4"): dict(moe_expert_pad=4, loss_chunk=8,
                              remat="block_dots"),
    # V5 = V1 + explicit sharding constraints on the un-dispatch gather
    # (code change in moe.py; same knobs as V1)
    ("qwen2-moe", "V5"): dict(moe_down_rs=True),
    ("qwen2-moe", "V6"): dict(moe_down_rs=True, loss_chunk=8),
    ("qwen2-moe", "V7"): dict(moe_expert_pad=4),
    # ---- mixtral train_4k 2x16x16 (most collective-bound absolute) ----
    ("mixtral", "M1"): dict(moe_down_rs=True),
    ("mixtral", "M2"): dict(remat="block_dots"),
    ("mixtral", "M3"): dict(moe_down_rs=True, remat="block_dots"),
    ("mixtral", "M4"): dict(moe_expert_pad=8),
    ("mixtral", "M5"): dict(moe_expert_pad=8, remat="block_dots"),
    # ---- llama3 train_4k 16x16 (paper-representative dense) ----
    ("llama3", "L1"): dict(loss_chunk=8),
    ("llama3", "L2"): dict(remat="block_dots"),
    ("llama3", "L3"): dict(loss_chunk=8, remat="block_dots"),
    ("llama3", "L4"): dict(seq_shard_carry=True),
    ("llama3", "L5"): dict(seq_shard_carry=True, loss_chunk=8),
    # ---- bonus: gemma2 decode_32k (most collective-bound ratio) ----
    ("gemma2-decode", "D1"): dict(),  # code change: sharded_decode_attention
}


def run(pair: str, variant: str, force: bool = False) -> dict:
    os.makedirs(OUT, exist_ok=True)
    arch, shape, multi = PAIRS[pair]
    fname = os.path.join(OUT, f"{pair}__{variant}.json")
    if os.path.exists(fname) and not force:
        return json.load(open(fname))
    cfg = get_config(arch)
    if variant != "V0":
        cfg = cfg.replace(**VARIANTS[(pair, variant)])
    print(f"[perf] {pair} {variant}: {VARIANTS.get((pair, variant), {})}",
          flush=True)
    row = dryrun.lower_pair(arch, shape, multi, cfg_override=cfg,
                            verbose=True)
    row["variant"] = variant
    row["knobs"] = VARIANTS.get((pair, variant), {})
    with open(fname, "w") as f:
        json.dump(row, f, indent=1, default=str)
    print(f"[perf] {pair} {variant}: comp={row['compute_s']:.2f}s "
          f"mem={row['memory_s']:.2f}s coll={row['collective_s']:.2f}s "
          f"dom={row['dominant']} mfu={row['mfu'] * 100:.1f}%", flush=True)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, choices=list(PAIRS))
    ap.add_argument("--variant", required=True)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    run(args.pair, args.variant, args.force)


if __name__ == "__main__":
    main()
