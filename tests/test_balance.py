"""Paper §3 balance equations — executable-documentation tests.

Each test pins an equation to either its closed form, a long-form
re-derivation, or the paper's own reported numbers."""

import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import XEON_E5_2666V3_10GBE as GBE, XEON_E5_2698V3_FDR as FDR, get_config
from repro.configs.base import ConvLayerSpec
from repro.core import balance
from repro.core.balance import LayerBalance


def _conv(ifm, ofm, k, out_hw, stride=1):
    return ConvLayerSpec("conv", ifm=ifm, ofm=ofm, kernel=k, stride=stride,
                         out_hw=out_hw)


# ---------------------------------------------------------------------------
# §3.1 closed form == long form
# ---------------------------------------------------------------------------
@given(ifm=st.integers(1, 512), ofm=st.integers(1, 1024),
       k=st.sampled_from([1, 3, 5, 7, 11]), out=st.integers(1, 64),
       mb=st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_comp_comm_closed_form(ifm, ofm, k, out, mb):
    """comp/comm == 1.5*out_w*out_h*MB_node — independent of ifm/ofm/k."""
    lyr = _conv(ifm, ofm, k, out)
    comp = balance.conv_comp_flops(lyr, mb)
    comm = balance.data_parallel_comm_bytes(lyr, overlap=1.0)
    assert comp / comm == pytest.approx(
        balance.data_parallel_comp_comm_ratio(lyr, mb), rel=1e-9)


def test_table1_platform_ratios():
    """Paper Table 1: required comp-to-comms 336 (FDR) / 1336 (10GbE)."""
    assert FDR.peak_flops / FDR.link_bw == pytest.approx(336, rel=0.01)
    assert GBE.peak_flops / GBE.link_bw == pytest.approx(1336, rel=0.01)


def test_network_comp_comm_ratios_vs_paper():
    """Paper §3.1: 'algorithmic computation-to-communication ratio [of]
    convolutional layers of OverFeat-FAST and VGG-A are 208, and 1456'.
    Our re-derivation from the layer tables lands within ~25% (the paper
    does not give its exact layer dims); the ORDERING and magnitudes match."""
    r_of = balance.aggregate_comp_comm_ratio(
        get_config("overfeat-fast").conv_layers())
    r_vgg = balance.aggregate_comp_comm_ratio(
        get_config("vgg-a").conv_layers())
    assert 160 < r_of < 280, r_of          # paper: 208
    assert 1100 < r_vgg < 1800, r_vgg      # paper: 1456
    assert r_vgg / r_of > 4                # VGG scales much further


def test_max_nodes_overfeat_fdr_matches_paper():
    """Paper Table 1: OverFeat-FAST on FDR scales to ~128 nodes (2/node)."""
    layers = [LayerBalance(str(i), balance.conv_comp_flops(lyr, 1),
                           balance.data_parallel_comm_bytes(lyr))
              for i, lyr in enumerate(get_config("overfeat-fast").conv_layers())]
    n = balance.max_data_parallel_nodes(layers, FDR, 256)
    assert 100 < n <= 160, n


def test_max_nodes_vgg_capped_by_minibatch():
    layers = [LayerBalance(str(i), balance.conv_comp_flops(lyr, 1),
                           balance.data_parallel_comm_bytes(lyr))
              for i, lyr in enumerate(get_config("vgg-a").conv_layers())]
    assert balance.max_data_parallel_nodes(layers, FDR, 256) == 256


# ---------------------------------------------------------------------------
# §3.2 model-parallel decision rule
# ---------------------------------------------------------------------------
def test_fc_prefers_model_parallel_when_ofm_gt_minibatch():
    """Paper §3.2: for FC layers, ofm > minibatch => model parallelism."""
    fc = ConvLayerSpec("fc", ifm=4096, ofm=4096, kernel=1, out_hw=1)
    assert balance.model_parallel_preferred(fc, in_hw=1, minibatch=256)
    assert not balance.model_parallel_preferred(fc, in_hw=1, minibatch=8192)


def test_conv_prefers_data_parallel():
    """Typical conv (ofm<=1024, k=3, in_hw>=14, mb>=64): data parallel."""
    lyr = _conv(256, 512, 3, 28)
    assert not balance.model_parallel_preferred(lyr, in_hw=28, minibatch=64)


# ---------------------------------------------------------------------------
# §3.3 hybrid parallelism
# ---------------------------------------------------------------------------
@given(n=st.sampled_from([16, 64, 256, 512]),
       mb=st.sampled_from([64, 256, 1024]),
       ofm=st.sampled_from([1024, 4096, 16384]))
@settings(max_examples=30, deadline=None)
def test_optimal_G_minimizes_hybrid_volume(n, mb, ofm):
    """The closed-form G = sqrt(N*mb/ofm) beats (or ties) every other G."""
    g_star = balance.optimal_group_count(n, mb, ofm)
    v_star = balance.hybrid_comm_bytes(1, ofm, 1, 1, mb, g_star, n)
    for g in {1, 2, 4, 8, max(1, g_star - 1), g_star + 1, n}:
        if 1 <= g <= n:
            v = balance.hybrid_comm_bytes(1, ofm, 1, 1, mb, g, n)
            assert v_star <= v * 1.30 + 1e-9   # discrete rounding slack


def test_hybrid_beats_pure_model_parallel_paper_example():
    """Paper §3.3 example (ofm=4096, mb=256, N=64): hybrid < G=1 volume.
    (The paper's printed G=3 / volume 213 are inconsistent with its own
    closed form — sqrt(64*256/4096)=2 — we assert the qualitative claim.)"""
    G, v_hybrid = balance.hybrid_comm_at_optimum(1, 4096, 256, 64,
                                                 size_data=8)
    v_model = balance.hybrid_comm_bytes(1, 4096, 1, 1, 256, 1, 64,
                                        size_data=8)
    assert G in (2, 3)
    assert v_hybrid <= v_model  # exact tie at this point with our formulas
    # a nearby configuration where hybrid is STRICTLY better than both ends
    G2, v2 = balance.hybrid_comm_at_optimum(1, 4096, 1024, 64, size_data=8)
    v_model2 = balance.hybrid_comm_bytes(1, 4096, 1, 1, 1024, 1, 64,
                                         size_data=8)
    v_data2 = balance.hybrid_comm_bytes(1, 4096, 1, 1, 1024, 64, 64,
                                        size_data=8)
    assert G2 > 1 and v2 < v_model2 and v2 < v_data2


# ---------------------------------------------------------------------------
# §3.1 bubbles
# ---------------------------------------------------------------------------
def test_bubble_first_layer_never_hidden():
    layers = [LayerBalance("l0", 1e9, 1e6)]
    b = balance.bubble_schedule(layers, FDR)
    # only comp_0/3 can overlap layer 0's comm
    assert b[0] == pytest.approx(1e6 / FDR.link_bw
                                 - (1e9 / 3) / FDR.peak_flops)


def test_scaling_efficiency_bounds():
    layers = [LayerBalance(f"lyr{i}", 1e9 / (i + 1), 4e6) for i in range(5)]
    eff = balance.scaling_efficiency(layers, FDR)
    assert 0.0 < eff <= 1.0


def test_efficiency_improves_with_more_compute_per_node():
    small = [LayerBalance("lyr", 1e8, 4e6)]
    big = [LayerBalance("lyr", 1e10, 4e6)]
    assert balance.scaling_efficiency(big, FDR) \
        >= balance.scaling_efficiency(small, FDR)
