"""Cluster subsystem tests: ClusterSpec env resolution, elastic failure
detection, world-size replan of the zero1 strip state, and the real
multi-process launcher (2 processes over ``jax.distributed`` + gloo).

Process-spawning tests go through ``python -m repro.launch.cluster`` like a
user would; the forced-device-count tests run in subprocesses so the rest
of the suite keeps the single real CPU device (same isolation policy as
tests/test_distributed.py)."""
import json
import os
import re
import subprocess
import sys
import tempfile
import textwrap
import time

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout: int = 300) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    prelude = "import repro.jaxcompat\n"
    out = subprocess.run([sys.executable, "-c",
                          prelude + textwrap.dedent(code)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def run_cluster_cli(argv, timeout: int = 420):
    """Invoke the supervisor exactly as a user would."""
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.cluster"] + argv,
        env=env, capture_output=True, text=True, timeout=timeout)


# ---------------------------------------------------------------------------
# ClusterSpec
# ---------------------------------------------------------------------------

def test_cluster_spec_env_round_trip():
    from repro.cluster import ClusterSpec
    spec = ClusterSpec(coordinator="localhost:12345", num_processes=4,
                       process_id=2, local_devices=3)
    assert ClusterSpec.from_env(spec.env()) == spec
    # missing vars keep single-process defaults
    assert ClusterSpec.from_env({}).num_processes == 1
    assert not ClusterSpec.from_env({}).is_multiprocess


def test_cluster_spec_validation():
    from repro.cluster import ClusterSpec
    with pytest.raises(ValueError):
        ClusterSpec(num_processes=0)
    with pytest.raises(ValueError):
        ClusterSpec(num_processes=2, process_id=2)
    with pytest.raises(ValueError):
        ClusterSpec(coordinator="no-port")
    with pytest.raises(ValueError):
        ClusterSpec(local_devices=0)


def test_in_worker_detection():
    from repro.cluster.spec import ENV_PROCESS_ID, in_worker
    assert not in_worker({})
    assert in_worker({ENV_PROCESS_ID: "0"})


# ---------------------------------------------------------------------------
# elastic failure detection (no real processes: duck-typed handles)
# ---------------------------------------------------------------------------

class _FakeProc:
    def __init__(self, returncode=None):
        self.returncode = returncode

    def poll(self):
        return self.returncode


def _handle(pid, returncode=None, hb=None, tmpdir="/tmp"):
    from repro.cluster.launcher import WorkerHandle
    hb_file = os.path.join(tmpdir, f"hb_{pid}")
    if hb is not None:
        with open(hb_file, "w") as f:
            f.write(str(hb))
    return WorkerHandle(proc=_FakeProc(returncode), process_id=pid,
                        hb_file=hb_file, log_file=None)


def test_failure_detects_nonzero_exit(tmp_path):
    from repro.cluster.elastic import _failure
    hs = [_handle(0, tmpdir=str(tmp_path)),
          _handle(1, returncode=-9, tmpdir=str(tmp_path))]
    fail = _failure(hs, time.monotonic(), heartbeat_timeout=60.0)
    assert fail is not None and fail["reason"] == "exit"
    assert fail["dead"] == [1]


def test_failure_ignores_clean_exit_and_fresh_group(tmp_path):
    from repro.cluster.elastic import _failure
    hs = [_handle(0, tmpdir=str(tmp_path)),
          _handle(1, returncode=0, tmpdir=str(tmp_path))]
    assert _failure(hs, time.monotonic(), heartbeat_timeout=60.0) is None


def test_failure_declares_hang_only_when_whole_group_stale(tmp_path):
    from repro.cluster.elastic import _failure
    # both alive, spawned long ago, no heartbeat ever written -> hang
    hs = [_handle(0, tmpdir=str(tmp_path)),
          _handle(1, tmpdir=str(tmp_path))]
    old = time.monotonic() - 1000.0
    fail = _failure(hs, old, heartbeat_timeout=60.0)
    assert fail is not None and fail["reason"] == "heartbeat"
    assert fail["dead"] == []
    # one member freshly beating -> healthy (sync SGD: a real hang is
    # always collective)
    hs2 = [_handle(0, hb=5, tmpdir=str(tmp_path)),
           _handle(1, tmpdir=str(tmp_path))]
    assert _failure(hs2, old, heartbeat_timeout=60.0) is None


# ---------------------------------------------------------------------------
# world-size replan of the strip state
# ---------------------------------------------------------------------------

def _value_strips(payload_vals, world):
    from repro.core.collectives import padded_size
    from repro.optim.dist import owner_perm
    p = padded_size(len(payload_vals), world["G"])
    flat = np.zeros(p, np.float32)
    flat[:len(payload_vals)] = payload_vals
    arr = flat.reshape(world["G"], -1)
    perm = owner_perm(world["hierarchical"], world["axes_sizes"])
    return arr[perm] if perm is not None else arr


def test_replan_strip_leaf_round_trips_across_worlds():
    from repro.checkpoint.replan import replan_strip_leaf, world_meta
    payload = np.random.default_rng(0).normal(size=10).astype(np.float32)
    worlds = [world_meta([8], False, 4), world_meta([2, 4], True, 4),
              world_meta([4, 2], True, 4), world_meta([4], False, 4),
              world_meta([2, 2], True, 4), world_meta([1], False, 4)]
    for old in worlds:
        for new in worlds:
            got = replan_strip_leaf(_value_strips(payload, old),
                                    len(payload), old, new)
            np.testing.assert_array_equal(got,
                                          _value_strips(payload, new))


def test_replan_strip_leaf_rejects_wrong_shape():
    from repro.checkpoint.replan import replan_strip_leaf, world_meta
    old, new = world_meta([4], False, 4), world_meta([2], False, 4)
    with pytest.raises(ValueError):
        replan_strip_leaf(np.zeros((2, 8), np.float32), 10, old, new)
    with pytest.raises(ValueError):   # padded size inconsistent w/ payload
        replan_strip_leaf(np.zeros((4, 9), np.float32), 10, old, new)


def test_replan_strip_state_rejects_bucket_bytes_change():
    from repro.checkpoint.replan import replan_strip_state, world_meta
    with pytest.raises(ValueError, match="bucket_bytes"):
        replan_strip_state({}, [], None, world_meta([4], False, 4),
                           world_meta([2], False, 8))


def test_replan_strip_state_full_state_matches_ginvariant_run():
    """Run the REAL bucketed update twice at G=4 (hierarchical 2x2), replan
    the resulting momentum strips to G=2 (flat), and compare against the
    state the same two updates produce when run at G=2 directly — the
    G-invariance of the §3.4 update makes them equal to float tolerance."""
    out = run_py("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import AxisType

        from repro.checkpoint.replan import replan_strip_state, world_meta
        from repro.comm.bucketer import CommConfig, plan_buckets
        from repro.optim import MomentumSGD
        from repro.optim.dist import make_distributed_update

        params = {"w": jnp.linspace(-1, 1, 37, dtype=jnp.float32),
                  "b": jnp.linspace(0, 2, 11, dtype=jnp.float32)}
        grads = jax.tree.map(lambda p: jnp.cos(p) + 0.1, params)
        comm = CommConfig(bucket_bytes=64, hierarchical=True)
        opt = MomentumSGD(momentum=0.9)

        def run_world(devs, axes, hier):
            mesh = jax.make_mesh(devs, axes,
                                 devices=jax.devices()[:int(np.prod(devs))],
                                 axis_types=(AxisType.Auto,) * len(devs))
            cc = CommConfig(bucket_bytes=64, hierarchical=hier)
            init, upd = make_distributed_update(opt, mesh, data_axes=axes,
                                                comm=cc)
            p, s = params, init(params)
            for _ in range(2):
                p, s = upd(p, grads, s, 0.05)
            return p, s

        p4, s4 = run_world((2, 2), ("pod", "data"), True)
        p2, s2 = run_world((2,), ("data",), False)
        np.testing.assert_allclose(np.asarray(p4["w"]), np.asarray(p2["w"]),
                                   rtol=2e-6, atol=2e-6)

        old_w = world_meta([2, 2], True, 64)
        new_w = world_meta([2], False, 64)
        plan = plan_buckets(params, 2, 64)
        old_leaves = [np.asarray(x) for x in jax.tree.leaves(s4)]
        replanned = replan_strip_state(s2, old_leaves, plan, old_w, new_w)
        for got, want in zip(jax.tree.leaves(replanned),
                             jax.tree.leaves(s2)):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=2e-6, atol=2e-6)
        print("OK")
    """, devices=4)
    assert "OK" in out


# ---------------------------------------------------------------------------
# make_host_mesh device-drop fix
# ---------------------------------------------------------------------------

def test_make_host_mesh_warns_and_keeps_all_devices():
    out = run_py("""
        import warnings
        import jax
        from repro.launch.mesh import make_host_mesh, mesh_devices
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            mesh = make_host_mesh(model_ways=4)   # 4 does not divide 6
        assert len(w) == 1, [str(x.message) for x in w]
        msg = str(w[0].message)
        assert "drop 2" in msg and "model_ways=3" in msg, msg
        assert mesh_devices(mesh) == 6, dict(mesh.shape)
        assert dict(mesh.shape) == {"data": 2, "model": 3}, dict(mesh.shape)
        print("OK")
    """, devices=6)
    assert "OK" in out


def test_divisible_factorization():
    from repro.launch.mesh import _divisible_factorization
    assert _divisible_factorization(6, 4, 1) == (3, 1)
    assert _divisible_factorization(8, 4, 2) == (4, 2)
    assert _divisible_factorization(7, 4, 2) == (1, 7) or \
        _divisible_factorization(7, 4, 2)[0] * \
        _divisible_factorization(7, 4, 2)[1] in (1, 7)
    assert _divisible_factorization(1, 1, 1) == (1, 1)


# ---------------------------------------------------------------------------
# checkpoint restore onto a different world size (through compile_run)
# ---------------------------------------------------------------------------

_STAGE = """
    import jax
    from repro.api import MeshSpec, RunSpec, compile_run
    # constant schedule: the LR at step k must not depend on spec.steps,
    # or the save-at-3 run and the 6-step reference would train the first
    # three steps under different LRs
    spec = RunSpec(arch="vgg-a", smoke=True, parallel="zero1",
                   mesh=MeshSpec(pods={pods}), steps={steps}, batch=8,
                   schedule="constant",
                   ckpt_dir={ckpt_dir!r}, ckpt_every={ckpt_every},
                   log_every=100)
    run = compile_run(spec)
    hist = run.fit({fit_args})
    run.close()
    print("FINAL", hist[-1]["loss"] if hist else "none")
"""


def _final(out: str) -> float:
    m = re.search(r"FINAL ([\d.eE+-]+)", out)
    assert m, out
    return float(m.group(1))


@pytest.mark.parametrize("resume_devices,resume_pods", [(4, 1), (2, 1)])
def test_restore_across_world_sizes(tmp_path, resume_devices, resume_pods):
    """Save at G=8 (hierarchical pods=2 x data=4), restore at G=4 and G=2
    (flat): the strip state is re-planned and the trajectory continues —
    final loss matches an uninterrupted run at the RESUME world size."""
    ckpt = str(tmp_path / "ckpt")
    run_py(_STAGE.format(pods=2, steps=3, ckpt_dir=ckpt, ckpt_every=3,
                         fit_args=""), devices=8)
    resumed = _final(run_py(
        _STAGE.format(pods=resume_pods, steps=6, ckpt_dir=ckpt,
                      ckpt_every=0, fit_args=""),
        devices=resume_devices))
    ref = _final(run_py(
        _STAGE.format(pods=resume_pods, steps=6, ckpt_dir=None,
                      ckpt_every=0, fit_args="start_step=0"),
        devices=resume_devices))
    assert abs(resumed - ref) < 5e-3, (resumed, ref)


def test_restore_without_meta_still_fails_cleanly(tmp_path):
    """A shape-mismatched checkpoint with NO zero1 meta must raise a real
    error, not replan garbage."""
    out = run_py(f"""
        import numpy as np
        import jax
        from repro.api import MeshSpec, RunSpec, compile_run
        from repro.checkpoint import ckpt as ckpt_lib
        spec = RunSpec(arch="vgg-a", smoke=True, parallel="zero1",
                       mesh=MeshSpec(), steps=2, batch=8,
                       ckpt_dir={str(tmp_path)!r}, log_every=100)
        run = compile_run(spec)
        # forge a checkpoint with wrong strip shapes and no meta
        bad_state = jax.tree.map(
            lambda s: np.zeros((7,) + tuple(s.shape[1:]), np.float32)
            if getattr(s, 'ndim', 0) >= 2 else np.asarray(s),
            run.opt_state)
        ckpt_lib.save({str(tmp_path)!r}, 1, params=run.params,
                      opt_state=bad_state)
        try:
            run.restore(1)
        except ValueError as e:
            assert "meta" in str(e) or "shape" in str(e), e
            print("RAISED")
    """, devices=2)
    assert "RAISED" in out


# ---------------------------------------------------------------------------
# the real thing: multi-process jax.distributed via the launcher CLI
# ---------------------------------------------------------------------------

def test_two_process_smoke_matches_single_process():
    """2 real processes over gloo, --verify: the launcher itself asserts
    |cluster final loss - single-process final loss| <= tol and exits
    nonzero on mismatch."""
    with tempfile.TemporaryDirectory() as td:
        out = run_cluster_cli(
            ["--processes", "2", "--arch", "vgg-a", "--smoke",
             "--steps", "4", "--batch", "8", "--run-dir", td, "--verify"])
        assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-2000:]
        assert "verify:" in out.stdout and "OK" in out.stdout, out.stdout
        result = json.load(open(os.path.join(td, "result.json")))
        assert result["world"] == 2 and result["final_loss"] is not None


def test_chaos_kill_one_worker_recovers_and_matches():
    """The chaos harness: SIGKILL worker 1 mid-run; the supervisor must
    detect it, re-form at world=1, resume from the latest checkpoint with
    a replanned G=2 -> G=1 state, and land on the SAME final loss as an
    uninterrupted single-process run of the full schedule."""
    with tempfile.TemporaryDirectory() as td:
        ckpt = os.path.join(td, "ckpt")
        out = run_cluster_cli(
            ["--processes", "2", "--arch", "vgg-a", "--smoke",
             "--steps", "16", "--batch", "8", "--schedule", "constant",
             "--ckpt-dir", ckpt, "--run-dir", td, "--ckpt-every", "2",
             "--chaos-kill-step", "3", "--heartbeat-timeout", "60"])
        assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-2000:]
        assert "attempt 1: world=1" in out.stdout, out.stdout
        assert "resuming from checkpoint" in out.stdout, out.stdout
        result = json.load(open(os.path.join(td, "result.json")))
        assert result["world"] == 1

        ref = _final(run_py(
            _STAGE.format(pods=1, steps=16, ckpt_dir=None, ckpt_every=0,
                          fit_args="start_step=0"), devices=1))
        assert abs(result["final_loss"] - ref) < 5e-3, (result, ref)


# ---------------------------------------------------------------------------
# satellites: linear-scale-warmup schedule, cross-host balance regimes
# ---------------------------------------------------------------------------

def test_linear_scale_warmup_shape():
    from repro.optim import linear_scale_warmup
    sched = linear_scale_warmup(1e-3, 8, 10, 100)
    assert float(sched(0)) == pytest.approx(1e-3)
    assert float(sched(5)) == pytest.approx((1e-3 + 8e-3) / 2)
    assert float(sched(10)) == pytest.approx(8e-3)
    # decays after warmup, floored at final_frac * peak
    assert float(sched(100)) == pytest.approx(0.1 * 8e-3, rel=1e-3)
    assert float(sched(55)) < 8e-3


def test_linear_scale_warmup_in_runspec():
    from repro.api import SCHEDULES, RunSpec
    assert "linear-scale-warmup" in SCHEDULES
    RunSpec(arch="vgg-a", schedule="linear-scale-warmup")   # validates
    with pytest.raises(ValueError):
        RunSpec(arch="vgg-a", schedule="nope")


def test_cross_host_hw_regimes():
    from repro.configs import XEON_E5_2698V3_FDR as FDR
    from repro.core.balance import CROSS_HOST_REGIMES, cross_host_hw
    eth = cross_host_hw(FDR, "ethernet-10gbe")
    assert eth.link_bw == pytest.approx(10e9 / 8)
    assert eth.sw_latency == pytest.approx(50e-6)
    ib = cross_host_hw(FDR, "infiniband-fdr")
    assert ib.link_bw == pytest.approx(56e9 / 8)
    assert set(CROSS_HOST_REGIMES) == {"infiniband-fdr", "ethernet-10gbe"}
    with pytest.raises(ValueError):
        cross_host_hw(FDR, "carrier-pigeon")


def test_comm_config_cross_backend_validation():
    from repro.comm import CommConfig
    CommConfig(cross_backend="pallas-ring")   # valid
    with pytest.raises(ValueError, match="cross_backend"):
        CommConfig(cross_backend="smoke-signals")


# ---------------------------------------------------------------------------
# mode interop: a zero1 checkpoint resumes under stale-sync
# ---------------------------------------------------------------------------

def test_zero1_ckpt_resumes_under_stale_sync_same_world(tmp_path):
    """The inner strip state of stale-sync is BIT-identical to zero1's, so
    a zero1 checkpoint restores into a stale-sync run with the staleness
    buffer re-initialized — and the first post-resume step is then exactly
    synchronous (empty carry), so training one step past the checkpoint
    must land on the SAME params as an uninterrupted zero1 run."""
    ckpt = str(tmp_path / "ckpt")
    out = run_py(f"""
        import numpy as np, jax
        from repro.api import RunSpec, compile_run
        quiet = lambda *_: None
        base = RunSpec(arch="vgg-a", smoke=True, steps=3, batch=8,
                       schedule="constant", parallel="zero1",
                       ckpt_dir={ckpt!r}, ckpt_every=3, log_every=100)
        rz = compile_run(base)
        rz.fit(log_fn=quiet); rz.close()

        # resume the zero1 checkpoint under stale-sync, train ONE step
        logs = []
        rs = compile_run(base.replace(parallel="stale-sync", steps=4,
                                      ckpt_every=0))
        rs.fit(log_fn=logs.append)
        assert any("resuming from checkpoint step 3" in str(ln)
                   for ln in logs), logs
        assert set(rs.opt_state) == {{"stale", "synced", "zero1"}}
        rs.close()

        # uninterrupted zero1 for the same 4 steps
        ref = compile_run(base.replace(steps=4, ckpt_dir=None,
                                       ckpt_every=0))
        ref.fit(log_fn=quiet); ref.close()
        for a, b in zip(jax.tree.leaves(rs.params),
                        jax.tree.leaves(ref.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-7)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.parametrize("resume_devices,resume_pods", [(4, 1), (2, 1)])
def test_zero1_ckpt_resumes_under_stale_sync_across_worlds(
        tmp_path, resume_devices, resume_pods):
    """Cross-world interop: a hierarchical G=8 zero1 checkpoint restores
    into a FLAT smaller-world stale-sync run — the inner strips are
    re-planned (owner layout included), the staleness buffer re-initialized
    at the new world's bucket geometry.  One synchronous post-resume step
    must match uninterrupted zero1 at the RESUME world size (the §3.4
    update is G-invariant to float tolerance)."""
    ckpt = str(tmp_path / "ckpt")
    run_py(_STAGE.format(pods=2, steps=3, ckpt_dir=ckpt, ckpt_every=3,
                         fit_args=""), devices=8)
    out = run_py(f"""
        import numpy as np, jax
        from repro.api import MeshSpec, RunSpec, compile_run
        quiet = lambda *_: None
        base = RunSpec(arch="vgg-a", smoke=True, steps=4, batch=8,
                       schedule="constant", mesh=MeshSpec(pods={resume_pods}),
                       log_every=100)
        logs = []
        rs = compile_run(base.replace(parallel="stale-sync",
                                      ckpt_dir={ckpt!r}))
        rs.fit(log_fn=logs.append)
        assert any("resuming from checkpoint step 3" in str(ln)
                   for ln in logs), logs
        rs.close()
        ref = compile_run(base.replace(parallel="zero1"))
        ref.fit(log_fn=quiet); ref.close()
        for a, b in zip(jax.tree.leaves(rs.params),
                        jax.tree.leaves(ref.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-6)
        print("OK")
    """, devices=resume_devices)
    assert "OK" in out
