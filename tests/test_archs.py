"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward + one train step on CPU, asserting
output shapes and finiteness; decode-vs-full consistency for the cache path.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_config, smoke_variant
from repro.configs.base import CNNConfig
from repro.core.sharding import ShardingCtx
from repro.models import cnn, dnn, frontends, transformer
from repro.optim import AdamW
from repro.optim.schedule import constant
from repro.train import make_train_step

CTX = ShardingCtx()
KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def make_batch(cfg, key=KEY, batch=B, seq=S):
    if cfg.frontend == "vision":
        s_img = cfg.vision_tokens
        return {
            "tokens": jax.random.randint(key, (batch, seq), 0,
                                         cfg.vocab_size),
            "patch_embeds": frontends.vision_stub_embeds(
                key, batch, s_img, cfg.d_model),
            "positions": frontends.mrope_positions(batch, s_img, seq,
                                                   grid_w=4),
        }
    if cfg.frontend == "audio":
        return {
            "frame_embeds": frontends.audio_stub_embeds(key, batch, seq,
                                                        cfg.d_model),
            "codebook_labels": jax.random.randint(
                key, (batch, seq, cfg.num_codebooks), 0, cfg.vocab_size),
        }
    return {"tokens": jax.random.randint(key, (batch, seq), 0,
                                         cfg.vocab_size)}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_forward_shapes_and_finiteness(arch):
    cfg = smoke_variant(get_config(arch))
    assert cfg.num_layers <= 6 and cfg.d_model <= 512
    assert (cfg.num_experts or 4) <= 4
    params = transformer.init_params(cfg, KEY)
    batch = make_batch(cfg)
    logits, aux, _ = transformer.forward(
        params, cfg, CTX,
        tokens=batch.get("tokens"),
        embeds=batch.get("patch_embeds", batch.get("frame_embeds")),
        positions=batch.get("positions"))
    seq_total = S + (cfg.vision_tokens if cfg.frontend == "vision" else 0)
    if cfg.num_codebooks:
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, seq_total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_one_train_step(arch):
    cfg = smoke_variant(get_config(arch))
    params = transformer.init_params(cfg, KEY)
    opt = AdamW()
    state = opt.init(params)
    step = make_train_step(
        lambda p, b: transformer.lm_loss(p, cfg, CTX, b), opt,
        constant(1e-3))
    batch = make_batch(cfg)
    new_params, _, metrics = jax.jit(step)(params, state, 0, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(new_params),
                                jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS])
def test_arch_decode_consistency(arch):
    """prefill(S) + decode(1) logits == full forward logits at position S."""
    cfg = smoke_variant(get_config(arch))
    if cfg.frontend == "audio":
        pytest.skip("audio decode exercised via serve path")
    params = transformer.init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (B, 17), 0, cfg.vocab_size)
    if cfg.frontend == "vision":
        emb = frontends.vision_stub_embeds(KEY, B, cfg.vision_tokens,
                                           cfg.d_model)
        full, _, _ = transformer.forward(params, cfg, CTX, tokens=tokens,
                                         embeds=emb)
        pytest.skip("vlm decode needs position bookkeeping beyond smoke")
    full, _, _ = transformer.forward(params, cfg, CTX, tokens=tokens)
    caches = transformer.init_caches(cfg, B, 24)
    _, _, caches = transformer.forward(params, cfg, CTX,
                                       tokens=tokens[:, :16],
                                       caches=caches, update_cache=True)
    pos = jnp.full((B, 1), 16, jnp.int32)
    dec, _, _ = transformer.forward(params, cfg, CTX,
                                    tokens=tokens[:, 16:17],
                                    positions=pos, caches=caches)
    np.testing.assert_allclose(dec[:, 0], full[:, 16], rtol=0.05, atol=0.05)


def test_sliding_window_variant_bounds_cache():
    """long-context mode: caches stay bounded by the window."""
    cfg = smoke_variant(get_config("llama3-8b"))
    caches = transformer.init_caches(cfg, 1, 10_000, long_ctx=True)
    k = caches[0].k
    assert k.shape[2] == cfg.long_context_window  # (R, B, C, Hkv, D)


@pytest.mark.parametrize("arch", PAPER_ARCHS)
def test_paper_arch_one_train_step(arch):
    cfg = smoke_variant(get_config(arch))
    from repro.data import stream_for
    batch = jax.tree.map(jnp.asarray, next(stream_for(cfg, 4, 16)))
    if isinstance(cfg, CNNConfig):
        params = cnn.init_params(cfg, KEY)
        def loss(p, b):
            return cnn.loss_fn(p, cfg, b)
    else:
        params = dnn.init_params(cfg, KEY)
        def loss(p, b):
            return dnn.loss_fn(p, cfg, b)
    opt = AdamW()
    step = make_train_step(loss, opt, constant(1e-3))
    _, _, metrics = jax.jit(step)(params, opt.init(params), 0, batch)
    assert bool(jnp.isfinite(metrics["loss"]))


def test_cnn_pallas_forward_matches_xla():
    cfg = smoke_variant(get_config("vgg-a"))
    params = cnn.init_params(cfg, KEY)
    x = jax.random.normal(KEY, (2, cfg.image_size, cfg.image_size, 3))
    a = cnn.forward(params, cfg, x, use_pallas=False)
    b = cnn.forward(params, cfg, x, use_pallas=True)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)
