"""Tests for the declarative run-assembly layer (repro.api).

The in-process tests run on the single real CPU device (meshes degrade to
(1, 1)); the multi-device equivalence tests reuse the subprocess machinery
of test_distributed.py so the rest of the suite keeps one device."""
import dataclasses

import pytest

from test_distributed import run_py


# ---------------------------------------------------------------------------
# RunSpec validation + family registry (no jax compute)
# ---------------------------------------------------------------------------
def test_runspec_validates_fields():
    from repro.api import RunSpec
    with pytest.raises(ValueError):
        RunSpec(arch="vgg-a", parallel="async")
    with pytest.raises(ValueError):
        RunSpec(arch="vgg-a", optimizer="lars")
    with pytest.raises(ValueError):
        RunSpec(arch="vgg-a", schedule="linear")
    with pytest.raises(ValueError):
        RunSpec(arch="vgg-a", steps=0)
    with pytest.raises(ValueError):
        # comm knobs only drive the explicit bucketed zero1 path; setting
        # them on dp/serial would be silently ignored, so it's rejected
        from repro.comm import CommConfig
        RunSpec(arch="vgg-a", parallel="dp", comm=CommConfig())
    spec = RunSpec(arch="vgg-a")
    assert dataclasses.replace(spec, parallel="zero1").parallel == "zero1"


def test_mode_caps_table_drives_validation():
    """Satellite: the MODE_CAPS capability table replaces the comm->zero1
    special-case.  Every parallel mode has an entry, and each comm knob is
    accepted or rejected per the table, not per hard-coded mode names."""
    from repro.api import MODE_CAPS, PARALLEL_MODES, ModeCaps, RunSpec
    from repro.comm import CommConfig

    assert set(PARALLEL_MODES) == set(MODE_CAPS)
    assert {"serial", "dp", "zero1", "zero1-gspmd",
            "stale-sync", "gossip"} <= set(MODE_CAPS)
    assert isinstance(MODE_CAPS["zero1"], ModeCaps)

    # commful modes accept comm; comm-less modes reject it
    for mode in ("zero1", "stale-sync", "gossip"):
        assert MODE_CAPS[mode].comm
    RunSpec(arch="vgg-a", parallel="stale-sync",
            comm=CommConfig(bucket_bytes=1 << 14))
    with pytest.raises(ValueError):
        RunSpec(arch="vgg-a", parallel="serial", comm=CommConfig())

    # overlap is a zero1-only capability: stale-sync re-schedules the
    # reduce across steps itself, so the backward-pass hooks don't apply
    with pytest.raises(ValueError):
        RunSpec(arch="vgg-a", parallel="stale-sync",
                comm=CommConfig(overlap=True))
    with pytest.raises(ValueError):
        RunSpec(arch="vgg-a", parallel="gossip",
                comm=CommConfig(overlap=True, backend="gossip"))

    # the gossip backend is selected by parallel="gossip", not as a zero1
    # backend swap (it changes the consistency model, not just the wire)
    with pytest.raises(ValueError):
        RunSpec(arch="vgg-a", parallel="zero1",
                comm=CommConfig(backend="gossip"))
    RunSpec(arch="vgg-a", parallel="gossip",
            comm=CommConfig(backend="gossip"))
    # stale-sync runs the synchronous wire: lax or the Pallas ring
    RunSpec(arch="vgg-a", parallel="stale-sync",
            comm=CommConfig(backend="pallas-ring"))
    with pytest.raises(ValueError):
        RunSpec(arch="vgg-a", parallel="stale-sync",
                comm=CommConfig(backend="gossip"))


def test_mode_caps_drive_cli_mapping():
    """launch.train derives its argument checks and backend defaults from
    MODE_CAPS: --parallel gossip flips the default --comm-backend to
    gossip and stays flat even under --pods 2."""
    import argparse

    from repro.launch.train import add_run_args, check_run_args, \
        spec_from_args

    ap = add_run_args(argparse.ArgumentParser())

    def parse(*argv):
        return ap.parse_args(list(argv))

    args = parse("--arch", "vgg-a", "--smoke", "--parallel", "gossip",
                 "--pods", "2", "--bucket-mb", "4")
    check_run_args(ap, args)
    spec = spec_from_args(args)
    assert spec.comm.backend == "gossip"
    assert not spec.comm.hierarchical

    # no comm flags -> comm stays None; assemble picks the mode default
    assert spec_from_args(parse("--arch", "vgg-a", "--smoke",
                                "--parallel", "gossip")).comm is None

    args = parse("--arch", "vgg-a", "--smoke", "--parallel", "stale-sync",
                 "--bucket-mb", "4")
    check_run_args(ap, args)
    assert spec_from_args(args).comm.bucket_bytes == 4 * 2 ** 20

    with pytest.raises(SystemExit):
        check_run_args(ap, parse("--arch", "vgg-a", "--smoke",
                                 "--parallel", "stale-sync", "--overlap"))
    with pytest.raises(SystemExit):
        check_run_args(ap, parse("--arch", "vgg-a", "--smoke",
                                 "--parallel", "zero1",
                                 "--comm-backend", "gossip"))
    with pytest.raises(SystemExit):
        check_run_args(ap, parse("--arch", "vgg-a", "--smoke",
                                 "--parallel", "serial", "--bucket-mb", "4"))


def test_meshspec_axes():
    from repro.api import MeshSpec
    assert MeshSpec().axis_names == ("data", "model")
    assert MeshSpec(pods=2).axis_names == ("pod", "data", "model")
    assert MeshSpec(pods=2).data_axes == ("pod", "data")
    assert MeshSpec().data_axes == ("data",)


def test_family_registry_resolves_all_config_types():
    from repro.api import adapter_for, families
    from repro.configs import get_config
    assert set(families()) == {"cnn", "dnn", "transformer"}
    assert adapter_for(get_config("vgg-a")).family == "cnn"
    assert adapter_for(get_config("cd-dnn")).family == "dnn"
    assert adapter_for(get_config("llama3-8b")).family == "transformer"
    with pytest.raises(TypeError):
        adapter_for(object())


def test_register_family_override_wins():
    from repro.api import adapter_for, register_family
    from repro.api.families import CNN_FAMILY
    from repro.configs import get_config
    cfg = get_config("vgg-a")
    custom = dataclasses.replace(CNN_FAMILY, family="cnn-custom")
    register_family(custom)
    try:
        assert adapter_for(cfg).family == "cnn-custom"
    finally:
        register_family(CNN_FAMILY)
    assert adapter_for(cfg).family == "cnn"


def test_smoke_and_stream_delegate_to_adapters():
    """configs.smoke_variant / data.stream_for route through the registry
    (the isinstance ladders are gone) and keep their old behavior."""
    import numpy as np

    from repro.configs import get_config, smoke_variant
    from repro.data import stream_for
    cnn_smoke = smoke_variant(get_config("vgg-a"))
    assert cnn_smoke.name == "vgg-a-smoke" and cnn_smoke.image_size == 32
    dnn_smoke = smoke_variant(get_config("cd-dnn"))
    assert dnn_smoke.hidden_dim == 64
    lm_smoke = smoke_variant(get_config("llama3-8b"))
    assert lm_smoke.d_model <= 256
    b = next(stream_for(cnn_smoke, 4, 0))
    assert b["images"].shape == (4, 32, 32, 3)
    b = next(stream_for(lm_smoke, 2, 16))
    assert b["tokens"].shape == (2, 16)
    assert b["tokens"].dtype == np.int32


# ---------------------------------------------------------------------------
# throughput accounting (satellite: CNN/DNN runs reported 0 tok/s)
# ---------------------------------------------------------------------------
def test_trainer_counts_samples_for_vision_batches():
    import numpy as np

    from repro.train.trainer import _batch_items
    n, unit = _batch_items({"tokens": np.zeros((4, 16))})
    assert (n, unit) == (64, "tok")
    n, unit = _batch_items({"images": np.zeros((8, 32, 32, 3)),
                            "labels": np.zeros((8,))})
    assert (n, unit) == (8, "samples")
    n, unit = _batch_items({"frames": np.zeros((5, 40)),
                            "senones": np.zeros((5,))})
    assert (n, unit) == (5, "samples")
    n, unit = _batch_items({"codebook_labels": np.zeros((2, 8, 4)),
                            "frame_embeds": np.zeros((2, 8, 16))})
    assert (n, unit) == (64, "tok")


# ---------------------------------------------------------------------------
# compile matrix: every arch x every parallel mode assembles
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("parallel", ["serial", "dp", "zero1",
                                      "stale-sync", "gossip"])
def test_compile_run_matrix(parallel):
    import jax

    from repro.api import RunSpec, compile_run
    from repro.configs import ALL_ARCHS
    for arch in ALL_ARCHS:
        spec = RunSpec(arch=arch, smoke=True, parallel=parallel,
                       steps=2, batch=2, seq=32)
        run = compile_run(spec)
        assert callable(run.train_step), arch
        assert jax.tree.leaves(run.params), arch
        assert run.family.family in ("cnn", "dnn", "transformer")
        if parallel == "serial":
            assert run.mesh is None
        else:
            assert "data" in run.mesh.axis_names
        # opt_state materialized (zero1: strip-sharded fusion buffers)
        assert jax.tree.leaves(run.opt_state) is not None
        run.close()


def test_compile_run_one_train_step_per_family():
    """One real step through the compiled Run for each family (serial)."""
    from repro.api import RunSpec, compile_run
    for arch in ("vgg-a", "cd-dnn", "llama-100m"):
        run = compile_run(RunSpec(arch=arch, smoke=True, steps=2, batch=2,
                                  seq=32, log_every=1))
        metrics = run.step(next(run.data))
        assert float(metrics["loss"]) > 0, arch
        run.close()


# ---------------------------------------------------------------------------
# multi-device equivalence: RunSpec(zero1) == RunSpec(serial) to float tol
# ---------------------------------------------------------------------------
def test_api_zero1_matches_serial_vgg():
    """The compiled zero1 step (explicit bucketed §3.4 strips over an
    8-way data mesh) reproduces the serial run's params to float
    tolerance — the acceptance property for the api layer."""
    run_py("""
        import numpy as np, jax
        from repro.api import RunSpec, compile_run
        from repro.comm import CommConfig
        quiet = lambda *_: None
        base = RunSpec(arch="vgg-a", smoke=True, steps=3, batch=8, lr=5e-3,
                       schedule="constant", log_every=100, seed=0)
        rs = compile_run(base)
        hs = rs.fit(log_fn=quiet); rs.close()
        for comm in (None, CommConfig(bucket_bytes=1 << 14),
                     CommConfig(bucket_bytes=1 << 25)):
            rz = compile_run(base.replace(parallel="zero1", comm=comm))
            assert rz.mesh.shape["data"] == 8
            hz = rz.fit(log_fn=quiet); rz.close()
            np.testing.assert_allclose(hz[-1]["loss"], hs[-1]["loss"],
                                       rtol=1e-5)
            for a, b in zip(jax.tree.leaves(rs.params),
                            jax.tree.leaves(rz.params)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-4, atol=1e-6)
        print("OK")
    """)


def test_api_overlap_matches_serial_vgg():
    """CommConfig(overlap=True): the §3.1 backprop-overlapped zero1 run —
    bucket reduces issued inside the backward pass — reproduces the serial
    run to float tolerance, flat (8-way) and hierarchical (2 pods)."""
    run_py("""
        import numpy as np, jax
        from repro.api import RunSpec, MeshSpec, compile_run
        from repro.comm import CommConfig
        quiet = lambda *_: None
        base = RunSpec(arch="vgg-a", smoke=True, steps=3, batch=8, lr=5e-3,
                       schedule="constant", log_every=100, seed=0)
        rs = compile_run(base)
        hs = rs.fit(log_fn=quiet); rs.close()
        variants = [
            base.replace(parallel="zero1",
                         comm=CommConfig(bucket_bytes=1 << 14, overlap=True)),
            base.replace(parallel="zero1", mesh=MeshSpec(pods=2),
                         comm=CommConfig(bucket_bytes=1 << 14, overlap=True,
                                         hierarchical=True)),
        ]
        for spec in variants:
            rz = compile_run(spec)
            hz = rz.fit(log_fn=quiet); rz.close()
            np.testing.assert_allclose(hz[-1]["loss"], hs[-1]["loss"],
                                       rtol=1e-5)
            for a, b in zip(jax.tree.leaves(rs.params),
                            jax.tree.leaves(rz.params)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-4, atol=1e-6)
        print("OK")
    """)


def test_api_zero1_resume_roundtrip():
    """Kill-and-relaunch semantics under zero1: a run interrupted at step 4
    and recompiled from scratch resumes from the checkpoint (strip opt_state
    restored ONTO its data-axis shardings, data stream re-aligned) and lands
    exactly where the uninterrupted run does."""
    run_py("""
        import tempfile, numpy as np, jax
        from repro.api import RunSpec, compile_run
        from repro.comm import CommConfig
        quiet = lambda *_: None
        with tempfile.TemporaryDirectory() as d1, \\
                tempfile.TemporaryDirectory() as d2:
            base = RunSpec(arch="vgg-a", smoke=True, steps=6, batch=8,
                           lr=5e-3, schedule="constant", log_every=1,
                           parallel="zero1",
                           comm=CommConfig(bucket_bytes=1 << 14),
                           ckpt_every=2, ckpt_dir=d1)
            # "killed" run: only 4 of the 6 steps happen
            ra = compile_run(base.replace(steps=4))
            ra.fit(log_fn=quiet); ra.close()
            # relaunch with the SAME ckpt_dir: must resume at 4, not 0
            logs = []
            rb = compile_run(base)
            hb = rb.fit(log_fn=logs.append); rb.close()
            assert any("resuming from checkpoint step 4" in str(ln)
                       for ln in logs), logs
            assert hb[0]["step"] == 5, hb
            # uninterrupted reference over the same seeded stream
            rc = compile_run(base.replace(ckpt_dir=d2))
            hc = rc.fit(log_fn=quiet); rc.close()
            np.testing.assert_allclose(hb[-1]["loss"], hc[-1]["loss"],
                                       rtol=1e-6)
            for a, b in zip(jax.tree.leaves(rb.params),
                            jax.tree.leaves(rc.params)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-7)
            # restored zero1 strip state sits on the run's shardings, and a
            # finished run relaunched again trains zero further steps
            rd = compile_run(base)
            hd = rd.fit(log_fn=quiet)
            assert hd == []
            for s in jax.tree.leaves(rd.opt_state):
                if getattr(s, "ndim", 0) >= 2:
                    assert "data" in str(s.sharding.spec), s.sharding
            rd.close()
        print("OK")
    """)


def test_api_zero1_hierarchical_and_gspmd_match_serial_lm():
    """Transformer family: the pods=2 hierarchical zero1 run and the
    GSPMD zero1 run both reproduce serial training."""
    run_py("""
        import numpy as np, jax
        from repro.api import RunSpec, MeshSpec, compile_run
        from repro.comm import CommConfig
        quiet = lambda *_: None
        # momentum SGD: linear in the gradients, so float-level gradient
        # noise stays float-level in the params (AdamW's m/sqrt(v) turns
        # noise-level grads of unused vocab rows into +-lr sign flips)
        base = RunSpec(arch="llama3-8b", smoke=True, steps=2, batch=8,
                       seq=16, lr=1e-3, optimizer="sgd",
                       schedule="constant", log_every=100)
        rs = compile_run(base)
        hs = rs.fit(log_fn=quiet); rs.close()
        variants = [
            base.replace(parallel="zero1", mesh=MeshSpec(pods=2),
                         comm=CommConfig(bucket_bytes=1 << 16,
                                         hierarchical=True)),
            base.replace(parallel="zero1-gspmd"),
        ]
        for spec in variants:
            rv = compile_run(spec)
            hv = rv.fit(log_fn=quiet); rv.close()
            np.testing.assert_allclose(hv[-1]["loss"], hs[-1]["loss"],
                                       rtol=2e-3, err_msg=spec.parallel)
            for a, b in zip(jax.tree.leaves(rs.params),
                            jax.tree.leaves(rv.params)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-3, atol=1e-5,
                                           err_msg=spec.parallel)
        print("OK")
    """)


def test_api_pallas_ring_matches_serial_vgg():
    """CommConfig(backend="pallas-ring"): the compiled zero1 run through the
    explicit Pallas ring collectives reproduces the serial run to float
    tolerance — flat (8-way) and hierarchical (2 pods), with and without
    the §3.1 backprop overlap.  The acceptance property for the backend
    seam: swapping the wire implementation must not change training."""
    run_py("""
        import numpy as np, jax
        from repro.api import RunSpec, MeshSpec, compile_run
        from repro.comm import CommConfig
        quiet = lambda *_: None
        base = RunSpec(arch="vgg-a", smoke=True, steps=3, batch=8, lr=5e-3,
                       schedule="constant", log_every=100, seed=0)
        rs = compile_run(base)
        hs = rs.fit(log_fn=quiet); rs.close()
        ring = dict(bucket_bytes=1 << 16, backend="pallas-ring")
        variants = [
            base.replace(parallel="zero1", comm=CommConfig(**ring)),
            base.replace(parallel="zero1",
                         comm=CommConfig(overlap=True, **ring)),
            base.replace(parallel="zero1", mesh=MeshSpec(pods=2),
                         comm=CommConfig(hierarchical=True, **ring)),
            base.replace(parallel="zero1", mesh=MeshSpec(pods=2),
                         comm=CommConfig(hierarchical=True, overlap=True,
                                         **ring)),
        ]
        for spec in variants:
            rz = compile_run(spec)
            hz = rz.fit(log_fn=quiet); rz.close()
            tag = (f"hier={spec.comm.hierarchical}/"
                   f"overlap={spec.comm.overlap}")
            np.testing.assert_allclose(hz[-1]["loss"], hs[-1]["loss"],
                                       rtol=1e-5, err_msg=tag)
            for a, b in zip(jax.tree.leaves(rs.params),
                            jax.tree.leaves(rz.params)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-4, atol=1e-6, err_msg=tag)
        print("OK")
    """)


def test_api_stale_sync_and_gossip_converge_vs_serial():
    """The relaxed-consistency acceptance property, on both paper
    workloads: (a) gossip — every member computes the same global-batch
    gradient in this single-process emulation, so the pair mean equals the
    full mean and the run must TRACK serial to float tolerance; (b)
    stale-sync — a one-step-old gradient, so the trajectory lags but must
    still optimize (VGG-A: large loss drop) and stay glued to serial where
    the landscape is flat (cd-dnn)."""
    run_py("""
        import numpy as np
        from repro.api import RunSpec, compile_run
        quiet = lambda *_: None

        def fit(arch, mode, steps, lr):
            r = compile_run(RunSpec(arch=arch, smoke=True, parallel=mode,
                                    steps=steps, batch=8, lr=lr,
                                    schedule="constant", log_every=100,
                                    seed=0))
            h = r.fit(log_fn=quiet); r.close()
            return [float(x["loss"]) for x in h]

        # VGG-A: all three modes must actually train
        serial = fit("vgg-a", "serial", 12, 5e-3)
        gossip = fit("vgg-a", "gossip", 12, 5e-3)
        stale = fit("vgg-a", "stale-sync", 12, 5e-3)
        np.testing.assert_allclose(gossip, serial, rtol=1e-4)
        assert serial[-1] < 0.5 * serial[0], serial
        assert stale[-1] < 0.5 * stale[0], stale
        # one-step staleness lags but stays the same order as serial
        assert stale[-1] < 2.0 * serial[-1], (stale[-1], serial[-1])

        # cd-dnn: both modes track the serial trajectory
        serial = fit("cd-dnn", "serial", 8, 5e-4)
        gossip = fit("cd-dnn", "gossip", 8, 5e-4)
        stale = fit("cd-dnn", "stale-sync", 8, 5e-4)
        np.testing.assert_allclose(gossip, serial, rtol=1e-4)
        np.testing.assert_allclose(stale, serial, rtol=5e-2, atol=5e-2)
        print("OK")
    """)
