"""Property tests for repro.comm (bucket planner + pack/unpack) and the
§3.2 latency+bucket extension of core.balance.

The multi-device equivalence matrix (bucketed update == per-tensor update ==
serial update, across bucket sizes / wire dtypes / hierarchical schedule)
lives in tests/test_distributed.py — it needs forced host devices.  Here we
pin everything that is pure: the plan, the fusion-buffer round trip, and the
cost model the sweep benchmark reports."""
import math

import numpy as np
import pytest

import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.comm.bucketer import CommConfig, pack_bucket, plan_buckets, unpack_buckets
from repro.configs import XEON_E5_2666V3_10GBE as GBE, XEON_E5_2698V3_FDR as FDR
from repro.core import balance

MIB = 2**20


def _sizes(seed, n):
    rng = np.random.default_rng(seed)
    # mix of tiny (bias-like) and larger (weight-like) leaves
    return [int(s) for s in rng.choice(
        [1, 3, 7, 32, 65, 128, 500, 2048], size=n)]


def _tree(seed, n):
    rng = np.random.default_rng(seed + 1)
    return [jnp.asarray(rng.normal(size=(s,)), jnp.float32)
            for s in _sizes(seed, n)]


# ---------------------------------------------------------------------------
# planner invariants
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 10_000), n=st.integers(1, 12),
       group=st.sampled_from([1, 2, 4, 8]),
       bucket_bytes=st.sampled_from([0, 16, 256, 4096, 10**9]))
@settings(max_examples=40, deadline=None)
def test_plan_covers_every_leaf_once(seed, n, group, bucket_bytes):
    tree = _tree(seed, n)
    plan = plan_buckets(tree, group, bucket_bytes)
    seen = sorted(s.index for b in plan.buckets for s in b.slots)
    assert seen == list(range(n))
    for b in plan.buckets:
        # slots are laid out contiguously, in order, and the pad rounds the
        # bucket to an equal strip per group member
        off = 0
        for s in b.slots:
            assert s.offset == off
            off += s.size
        assert b.size == off
        assert b.padded_size % group == 0
        assert 0 <= b.padded_size - b.size < group
    assert plan.total_elements == sum(int(x.size) for x in tree)


@given(seed=st.integers(0, 10_000), n=st.integers(1, 12),
       group=st.sampled_from([1, 4, 8]),
       bucket_bytes=st.sampled_from([0, 16, 4096, 10**9]))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_round_trip(seed, n, group, bucket_bytes):
    tree = _tree(seed, n)
    plan = plan_buckets(tree, group, bucket_bytes)
    bufs = [pack_bucket(tree, b) for b in plan.buckets]
    for buf, b in zip(bufs, plan.buckets):
        assert buf.shape == (b.padded_size,)
    back = unpack_buckets(bufs, plan)
    for a, b in zip(tree, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_collective_count_drops_from_tensors_to_bytes():
    """The headline: per-tensor issues O(#tensors) collectives, bucketing
    O(total_bytes / bucket_bytes)."""
    n = 64
    tree = [jnp.zeros((256,), jnp.float32)] * n      # 1 KiB each, 64 KiB all
    per_tensor = plan_buckets(tree, 8, 0)
    assert per_tensor.n_collectives == n
    fused = plan_buckets(tree, 8, 8 * 1024)          # 8 KiB buckets
    assert fused.n_collectives == 64 * 1024 // (8 * 1024)
    whole = plan_buckets(tree, 8, 10**9)             # bucket > whole tree
    assert whole.n_collectives == 1


@given(seed=st.integers(0, 10_000), n=st.integers(2, 12),
       cap_kib=st.sampled_from([1, 4, 16]))
@settings(max_examples=25, deadline=None)
def test_greedy_bucket_count_is_near_optimal(seed, n, cap_kib):
    """First-fit in order: every closed bucket + its successor leaf overflow
    the cap, so at most 2*ceil(B/cap)+1 buckets when no leaf exceeds cap."""
    cap = cap_kib * 1024
    tree = _tree(seed, n)
    if any(int(x.size) * 4 > cap for x in tree):
        return
    plan = plan_buckets(tree, 4, cap)
    total = sum(int(x.size) for x in tree) * 4
    assert plan.n_collectives <= 2 * math.ceil(total / cap) + 1


def test_mixed_dtype_leaves_never_share_a_bucket():
    """Concatenating mixed-dtype leaves would silently promote them; the
    planner closes buckets on dtype change and unpack restores each leaf's
    recorded dtype even if the optimizer promoted the buffer."""
    tree = [jnp.ones((8,), jnp.bfloat16), jnp.ones((8,), jnp.float32),
            jnp.ones((8,), jnp.bfloat16), jnp.ones((8,), jnp.bfloat16)]
    plan = plan_buckets(tree, 2, 10**9)
    for b in plan.buckets:
        assert len({s.dtype for s in b.slots}) == 1
    assert plan.n_collectives == 3       # bf16 | f32 | bf16+bf16
    # bf16 byte accounting: 8 elements * 2 B = 16 B fits a 16 B cap exactly
    assert plan_buckets([jnp.ones((8,), jnp.bfloat16)] * 2, 2,
                        16).n_collectives == 2
    bufs = [pack_bucket(tree, b) for b in plan.buckets]
    # simulate optimizer promotion of every buffer to fp32
    back = unpack_buckets([b.astype(jnp.float32) for b in bufs], plan)
    for a, b in zip(tree, back):
        assert b.dtype == a.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_oversize_leaf_gets_its_own_bucket():
    tree = [jnp.zeros((4,), jnp.float32), jnp.zeros((10_000,), jnp.float32),
            jnp.zeros((4,), jnp.float32)]
    plan = plan_buckets(tree, 2, 1024)   # middle leaf is 40 KB > 1 KiB cap
    big = [b for b in plan.buckets if any(s.size == 10_000 for s in b.slots)]
    assert len(big) == 1 and len(big[0].slots) == 1


def test_comm_config_validates_dtype():
    assert CommConfig(reduce_dtype="bfloat16").wire_dtype == jnp.bfloat16
    assert CommConfig().wire_dtype == jnp.float32
    with pytest.raises(ValueError):
        CommConfig(reduce_dtype="float16")


# ---------------------------------------------------------------------------
# §3.2 latency + bucket cost model
# ---------------------------------------------------------------------------
@given(total_mib=st.sampled_from([8, 64, 512]),
       g=st.sampled_from([4, 16, 64, 256]),
       hw=st.sampled_from([FDR, GBE]))
@settings(max_examples=30, deadline=None)
def test_optimal_bucket_minimizes_model_time(total_mib, g, hw):
    """The closed form sqrt(B*SWlat*BW*G) beats (or ties, within the ceil()
    discretization) every power-of-two bucket size."""
    total = total_mib * MIB
    n_tensors = 200
    b_star = balance.optimal_bucket_bytes(total, g, hw)
    assert 64 * 1024 <= b_star <= total
    t_star = balance.bucketed_allreduce_time(total, n_tensors, b_star, g, hw)
    for b in [2**k * 1024 for k in range(4, 16)]:
        t = balance.bucketed_allreduce_time(total, n_tensors, b, g, hw)
        assert t_star <= t * 1.35 + 1e-12


def test_bucketing_beats_per_tensor_in_latency_regime():
    """Many small tensors: fusing into MiB buckets cuts the predicted time
    (this is the regime the ISSUE calls out for VGG-A's conv/bias tensors)."""
    total, n_tensors = 64 * MIB, 500
    t_per_tensor = balance.bucketed_allreduce_time(total, n_tensors, 0,
                                                   64, FDR)
    t_bucketed = balance.bucketed_allreduce_time(total, n_tensors, 4 * MIB,
                                                 64, FDR)
    assert t_bucketed < t_per_tensor


def test_collective_count_model():
    assert balance.collective_count(64 * MIB, 500, 0) == 500
    assert balance.collective_count(64 * MIB, 500, 4 * MIB) == 16
    assert balance.collective_count(1, 500, 10**12) == 1


def test_ring_time_scales_with_bytes_and_members():
    t1 = balance.ring_collective_time(MIB, 8, FDR)
    assert balance.ring_collective_time(2 * MIB, 8, FDR) > t1
    assert balance.ring_collective_time(MIB, 16, FDR) > t1
    assert balance.ring_collective_time(MIB, 1, FDR) == 0.0


# ---------------------------------------------------------------------------
# §3.1 overlap: readiness metadata + bubble schedule closed forms
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 10_000), n=st.integers(1, 12),
       bucket_bytes=st.sampled_from([0, 64, 4096, 10**9]))
@settings(max_examples=25, deadline=None)
def test_backprop_order_issues_last_leaves_first(seed, n, bucket_bytes):
    """Backprop materializes gradients in reverse tree order, so the issue
    order must visit buckets by descending trigger leaf, covering each
    bucket exactly once."""
    from repro.comm.overlap import bucket_triggers, issue_order
    plan = plan_buckets(_tree(seed, n), 4, bucket_bytes)
    for b in plan.buckets:
        assert b.trigger_index == min(s.index for s in b.slots)
    order = plan.backprop_order
    assert sorted(order) == list(range(plan.n_collectives))
    trig = [plan.buckets[b].trigger_index for b in order]
    assert trig == sorted(trig, reverse=True)
    # tree-order default of bucket_triggers == the Bucket property
    assert bucket_triggers(plan) == tuple(
        b.trigger_index for b in plan.buckets)
    assert issue_order(bucket_triggers(plan)) == order


def test_paper_family_tree_order_is_forward_layer_order():
    """jax flattens dicts in LEXICAL key order, so the CNN/DNN param keys
    must zero-pad their layer index: 'conv2' sorting after 'conv10' (or
    'b0..bN' before 'w0..wN') would interleave first- and last-layer leaves
    in the bucket plan and defeat the §3.1 overlap schedule for the paper's
    own nets."""
    import re

    import jax

    from repro.api import adapter_for
    from repro.configs import get_config
    for net in ("vgg-a", "overfeat-fast", "cd-dnn"):
        cfg = get_config(net)
        flat = jax.tree_util.tree_flatten_with_path(
            adapter_for(cfg).param_specs(cfg))[0]
        layers = [int(re.search(r"\d+", jax.tree_util.keystr(p)).group())
                  for p, _ in flat]
        assert layers == sorted(layers), (net, layers)


def test_bucket_triggers_with_layer_map():
    """A bucket spanning leaves of several layers is completed by its
    EARLIEST forward layer (the last one backprop reaches)."""
    from repro.comm.overlap import bucket_triggers, issue_order
    tree = [jnp.zeros((8,), jnp.float32)] * 6
    plan = plan_buckets(tree, 2, 64)      # 2 leaves (64 B) per bucket
    assert plan.n_collectives == 3
    leaf_layer = [0, 0, 1, 1, 2, 2]       # w+b per layer
    assert bucket_triggers(plan, leaf_layer) == (0, 1, 2)
    assert issue_order((0, 1, 2)) == (2, 1, 0)
    # leaves interleaved across layers: min wins
    assert bucket_triggers(plan, [2, 0, 1, 2, 0, 1]) == (0, 1, 0)


def test_bucket_bubble_schedule_reduces_to_layer_closed_form():
    """With exactly one bucket per layer the §3.1 bucket-granular schedule
    IS the paper's per-layer ``bubble_schedule``."""
    rng = np.random.default_rng(0)
    layers = [balance.LayerBalance(f"lyr{i}", float(c), float(m))
              for i, (c, m) in enumerate(zip(
                  rng.uniform(1e9, 1e12, 7), rng.uniform(1e5, 1e8, 7)))]
    for hw in (FDR, GBE):
        want = balance.bubble_schedule(layers, hw, efficiency=0.7)
        got = balance.bucket_bubble_schedule(
            [lb.comm / hw.link_bw for lb in layers],
            list(range(len(layers))),
            [lb.comp for lb in layers], hw, efficiency=0.7)
        np.testing.assert_allclose(got, want, rtol=1e-12)


def test_overlap_exposed_time_bounds():
    """Timeline exposure: equals the all-exposed total with nothing to
    overlap, vanishes when compute dwarfs comm, and never exceeds the
    monolithic schedule."""
    comm = [0.01, 0.02, 0.005, 0.04]
    trig = [0, 1, 2, 3]
    no_comp = balance.overlap_exposed_time(comm, trig, [0.0] * 4, FDR)
    np.testing.assert_allclose(no_comp, sum(comm), rtol=1e-12)
    huge = [1e18] * 4                   # seconds of compute per layer
    assert balance.overlap_exposed_time(comm, trig, huge, FDR) == 0.0
    rng = np.random.default_rng(1)
    for _ in range(20):
        n = rng.integers(1, 8)
        comm = rng.uniform(1e-4, 1e-1, n).tolist()
        trig = sorted(rng.integers(0, 5, n).tolist())
        comps = rng.uniform(0, 1e12, 5).tolist()
        on = balance.overlap_exposed_time(comm, trig, comps, FDR, 0.75)
        assert 0.0 <= on <= sum(comm) + 1e-12


def test_overlap_grad_strips_match_serial_gradient():
    """On a 1-member group the hooked backward's strips ARE the packed
    serial gradient (no reduction): the custom_vjp side channel is exact."""
    import jax
    from jax.sharding import AxisType, PartitionSpec as P

    from repro.comm.bucketer import pack_bucket as pack
    from repro.comm.overlap import make_overlap_grad
    mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(6, 3)),
                               jnp.float32),
              "b": jnp.zeros((3,), jnp.float32)}
    batch = {"x": jnp.asarray(np.random.default_rng(1).normal(size=(4, 6)),
                              jnp.float32)}

    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"]) ** 2)

    comm = CommConfig(bucket_bytes=64)
    og = make_overlap_grad(loss, "data", comm, G=1)
    with jax.set_mesh(mesh):
        fn = jax.shard_map(og, mesh=mesh,
                           in_specs=(P(), P("data")),
                           out_specs=(P(), P("data")), check_vma=False)
        lval, strips = jax.jit(fn)(params, batch)
    ref_l, ref_g = jax.value_and_grad(loss)(params, batch)
    plan = plan_buckets(params, 1, comm.bucket_bytes)
    ref_strips = [pack(jax.tree.leaves(ref_g), b) for b in plan.buckets]
    np.testing.assert_allclose(float(lval), float(ref_l), rtol=1e-6)
    assert len(strips) == plan.n_collectives
    for got, want in zip(strips, ref_strips):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)


def test_hierarchical_beats_flat_ring_with_fast_pod_links():
    """Two-level 16x8 with 4x in-pod bandwidth beats one flat 128-ring: the
    cross-pod hop only moves strip bytes and the latency term shrinks from
    2*(128-1) to 2*(16-1) + 2*(8-1) messages per bucket."""
    total, n_tensors = 500 * MIB, 300
    t_flat = balance.bucketed_allreduce_time(total, n_tensors, 4 * MIB,
                                             128, FDR)
    t_hier = balance.hierarchical_allreduce_time(total, n_tensors, 4 * MIB,
                                                 16, 8, FDR,
                                                 pod_bw=4 * FDR.link_bw)
    assert t_hier < t_flat


# ---------------------------------------------------------------------------
# backend seam: make_schedule resolution + CommConfig validation
# ---------------------------------------------------------------------------
def test_make_schedule_rejects_three_axis_hierarchy():
    """hierarchical=True with >2 axes has no defined composition order —
    it must raise (naming the axes), not silently go flat (the seed bug)."""
    from repro.comm.schedule import FlatSchedule, make_schedule
    with pytest.raises(ValueError, match=r"\('a', 'b', 'c'\)"):
        make_schedule(("a", "b", "c"), hierarchical=True)
    # the documented one-axis fallback stays: a one-axis "hierarchy" IS the
    # flat ring
    assert isinstance(make_schedule("data", hierarchical=True), FlatSchedule)
    assert isinstance(make_schedule(("data",), hierarchical=True),
                      FlatSchedule)


def test_make_schedule_binds_backends_per_level():
    from repro.comm import LaxBackend, PallasRingBackend
    from repro.comm.schedule import FlatSchedule, HierarchicalSchedule, make_schedule
    flat = make_schedule("data", backend="pallas-ring")
    assert isinstance(flat, FlatSchedule)
    assert isinstance(flat.backend, PallasRingBackend)
    # hierarchical: requested backend in-pod, lax on the cross-pod hop
    hier = make_schedule(("pod", "data"), hierarchical=True,
                         backend="pallas-ring")
    assert isinstance(hier, HierarchicalSchedule)
    assert isinstance(hier.inner_backend, PallasRingBackend)
    assert isinstance(hier.outer_backend, LaxBackend)
    # explicit cross_backend override + instance pass-through
    mine = PallasRingBackend(interpret=True)
    hier2 = make_schedule(("pod", "data"), hierarchical=True,
                          backend=mine, cross_backend="pallas-ring")
    assert hier2.inner_backend is mine
    assert isinstance(hier2.outer_backend, PallasRingBackend)


def test_get_backend_and_commconfig_validate_names():
    from repro.comm import COLLECTIVE_BACKENDS, get_backend
    assert set(COLLECTIVE_BACKENDS) == {"lax", "pallas-ring", "gossip"}
    with pytest.raises(ValueError, match="nccl"):
        get_backend("nccl")
    # a real exception (never assert: -O must not disable config validation)
    with pytest.raises(ValueError, match="nccl"):
        CommConfig(backend="nccl")
    with pytest.raises(ValueError, match="float16"):
        CommConfig(reduce_dtype="float16")
    assert CommConfig().backend == "lax"
    assert CommConfig(backend="pallas-ring").backend == "pallas-ring"


def test_backend_models_cover_all_backends():
    """Every registered backend has §3.2 cost-model constants, and the ring
    time responds to them (lax is the calibration identity)."""
    from repro.comm import COLLECTIVE_BACKENDS
    from repro.core.balance import RING_BACKEND_MODELS, backend_hw
    assert set(RING_BACKEND_MODELS) == set(COLLECTIVE_BACKENDS)
    assert backend_hw(FDR, "lax") is FDR
    ring = backend_hw(FDR, "pallas-ring")
    assert ring.sw_latency < FDR.sw_latency
    assert ring.link_bw <= FDR.link_bw
    t_lax = balance.ring_collective_time(MIB, 8, FDR, backend="lax")
    t_ring = balance.ring_collective_time(MIB, 8, FDR, backend="pallas-ring")
    assert t_lax == balance.ring_collective_time(MIB, 8, FDR)
    assert t_ring != t_lax
    with pytest.raises(ValueError, match="nccl"):
        backend_hw(FDR, "nccl")
