import os
import sys

# NOTE: no XLA_FLAGS here on purpose — unit/smoke tests run on the single
# real CPU device.  Multi-device tests (tests/test_distributed.py) spawn
# subprocesses with their own --xla_force_host_platform_device_count.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402  (sys.path bootstrap must precede)

jax.config.update("jax_enable_x64", False)
