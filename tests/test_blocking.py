"""Paper §2.2 blocking solver tests."""
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import blocking


def test_paper_c5_unblocked_bf():
    """Paper §2.2: OverFeat-FAST C5 (12x12 out, 3x3 kernel) row-at-a-time
    B/F = 0.54."""
    bf = blocking.layer_bf_unblocked(12, 3)
    assert bf == pytest.approx(0.54, abs=0.02)


def test_paper_c5_fully_cached_bf():
    """Paper §2.2: best-case B/F for C5 at minibatch 256 is quoted as 0.003.
    The literal transcription of their formula gives 7.8e-4 — same order,
    ~700x below the unblocked 0.54 (the paper's actual point)."""
    bf = blocking.layer_bf_fully_cached(256, 512, 1024, 12, 3)
    assert bf < 0.004
    assert blocking.layer_bf_unblocked(12, 3) / bf > 100


def test_solver_respects_capacity_and_alignment():
    blk = blocking.solve_conv_blocking(1, 512, 1024, 12, 3,
                                       cache_bytes=128 * 1024, simd=16)
    assert blk.bytes_per_block <= 128 * 1024
    assert blk.b_ofm % 16 == 0


def test_paper_128kb_cache_claim():
    """Paper §2.2: 'with 128 KB of cache per thread ... a B/F ratio of
    <= 0.04 can be maintained for most convolutional layers even for a
    minibatch size of 1'."""
    cases = [
        (512, 1024, 12, 3),    # OverFeat C5
        (256, 512, 12, 3),
        (256, 512, 28, 3),     # VGG-A conv4
        (512, 512, 14, 3),     # VGG-A conv5
    ]
    ok = 0
    for ifm, ofm, out, k in cases:
        blk = blocking.solve_conv_blocking(1, ifm, ofm, out, k,
                                           cache_bytes=128 * 1024, simd=16)
        ok += blk.bf_ratio <= 0.05
    assert ok >= 3, "most layers should reach the paper's B/F band"


@given(m=st.sampled_from([128, 256, 1024, 4096]),
       n=st.sampled_from([128, 512, 2048]),
       k=st.sampled_from([128, 512, 4096]))
@settings(max_examples=25, deadline=None)
def test_gemm_solver_capacity_and_closed_form(m, n, k):
    vmem = 4 * 2**20
    blk = blocking.solve_gemm_blocking(m, n, k, vmem_bytes=vmem)
    assert blk.bytes_per_block <= vmem
    assert blk.bn % 128 == 0 and blk.bk % 128 == 0
    # closed form: B/F improves with the harmonic mean of (bm, bn); the
    # brute force must match the analytic steady-state formula it minimized
    expect = (4 * (blk.bm * k + k * blk.bn) + 4 * blk.bm * blk.bn) \
        / (2.0 * blk.bm * blk.bn * k)
    assert blk.bf_ratio == pytest.approx(expect, rel=1e-9)


def test_gemm_bigger_cache_never_worse():
    small = blocking.solve_gemm_blocking(4096, 4096, 4096,
                                         vmem_bytes=1 * 2**20)
    big = blocking.solve_gemm_blocking(4096, 4096, 4096,
                                       vmem_bytes=8 * 2**20)
    assert big.bf_ratio <= small.bf_ratio


def test_conv_solver_beats_naive_rowwise():
    """The searched blocking must beat the paper's unblocked row-at-a-time
    traversal for the C5 case study."""
    blk = blocking.solve_conv_blocking(1, 512, 1024, 12, 3,
                                       cache_bytes=128 * 1024, simd=16)
    assert blk.bf_ratio < blocking.layer_bf_unblocked(12, 3)
