"""Use real ``hypothesis`` when installed (CI does: see requirements-ci.txt),
otherwise a minimal deterministic fallback so the tier-1 suite collects and
runs in containers without it (the seed suite died at collection here).

The fallback implements just the subset this repo's property tests use —
``given``, ``settings`` and the ``integers`` / ``sampled_from`` / ``floats``
/ ``booleans`` strategies — drawing from a seeded ``random.Random`` so runs
are reproducible.  No shrinking, no database; a failing example prints its
drawn arguments in the assertion traceback instead.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rnd):
            return self._draw(rnd)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda r: r.choice(seq))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: r.random() < 0.5)

        @staticmethod
        def lists(elements, min_size=0, max_size=8):
            return _Strategy(lambda r: [
                elements.draw(r)
                for _ in range(r.randint(min_size, max_size))])

    st = _Strategies()

    def settings(**kwargs):
        def deco(f):
            f._max_examples = kwargs.get("max_examples", _FALLBACK_EXAMPLES)
            return f
        return deco

    def given(*pos_strategies, **strategies):
        def deco(f):
            n = min(getattr(f, "_max_examples", _FALLBACK_EXAMPLES), 25)
            sig = inspect.signature(f)
            named = dict(strategies)
            # positional strategies bind to the function's parameters in
            # order, as real hypothesis does
            for name, strat in zip(sig.parameters, pos_strategies):
                named[name] = strat

            @functools.wraps(f)
            def wrapper(*args, **kwargs):
                for i in range(n):
                    rnd = random.Random(0xC0FFEE + 10007 * i)
                    drawn = {k: s.draw(rnd) for k, s in named.items()}
                    f(*args, **kwargs, **drawn)

            # hide the strategy-filled parameters from pytest's fixture
            # resolution (real hypothesis does the same)
            keep = [p for name, p in sig.parameters.items()
                    if name not in named]
            wrapper.__signature__ = sig.replace(parameters=keep)
            return wrapper
        return deco
