"""Telemetry subsystem tests: recorder span semantics, sinks/Chrome trace,
metrics histograms, the comm="auto" autotuner (exact on a synthetic timing
table, end-to-end loss-equal in a subprocess), the heartbeat redesign
(monotonic payload vs NTP-jumped mtimes), and the benchmark regression gate.

Forced-device-count runs go through subprocesses (same isolation policy as
tests/test_cluster.py) so the rest of the suite keeps the single real CPU
device."""
import json
import os
import subprocess
import sys
import tempfile
import textwrap
import time

import numpy as np
import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(REPO, "src")


def run_py(code: str, devices: int = 8, timeout: int = 420,
           extra_env=None) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    env.update(extra_env or {})
    prelude = "import repro.jaxcompat\n"
    out = subprocess.run([sys.executable, "-c",
                          prelude + textwrap.dedent(code)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# Recorder: span nesting, ordering, listeners, lifecycle
# ---------------------------------------------------------------------------

def test_span_nesting_and_ordering():
    from repro.telemetry import Recorder
    r = Recorder()
    with r.span("step", step=1):
        with r.span("compile", step=1):
            pass
        with r.span("ckpt_write", step=1):
            pass
    r.event("note", x=3)
    kinds = [e["kind"] for e in r.events]
    # children finish (and are emitted) before their parent
    assert kinds == ["compile", "ckpt_write", "step", "note"]
    by_kind = {e["kind"]: e for e in r.events}
    step, compile_, ckpt = (by_kind[k] for k in
                            ("step", "compile", "ckpt_write"))
    # monotonic-timestamp invariants: parent brackets its children, the
    # sibling spans don't overlap, durations are consistent
    assert step["t0"] <= compile_["t0"] <= compile_["t1"] <= step["t1"]
    assert compile_["t1"] <= ckpt["t0"]
    for e in (step, compile_, ckpt):
        assert e["dur"] == pytest.approx(e["t1"] - e["t0"])
    assert step["depth"] == 0
    assert compile_["depth"] == 1 and ckpt["depth"] == 1
    assert by_kind["note"]["ph"] == "instant"
    assert by_kind["note"]["x"] == 3


def test_span_durations_feed_histograms_and_listeners_see_events():
    from repro.telemetry import Recorder
    r = Recorder()
    seen = []
    r.add_listener(seen.append)
    with r.span("step", step=1):
        pass
    r.count("steps")
    r.count("items_tok", 128)
    r.gauge("lr", 1e-3)
    assert [e["kind"] for e in seen] == ["step"]
    m = r.metrics()
    assert m["counters"] == {"steps": 1, "items_tok": 128}
    assert m["gauges"] == {"lr": 1e-3}
    assert m["histograms"]["span/step_s"]["count"] == 1


def test_recorder_close_is_idempotent_and_emits_metrics():
    from repro.telemetry import Recorder
    r = Recorder()
    r.count("steps")
    r.close()
    r.close()
    assert r.events[-1]["kind"] == "metrics"
    assert sum(e["kind"] == "metrics" for e in r.events) == 1


def test_null_recorder_overhead_is_cheap():
    """The no-op default must be cheap enough to leave in every hot path:
    bound 100k span enters+exits well under a second (they are attribute
    lookups returning a cached null object)."""
    from repro.telemetry import NULL_RECORDER
    assert not NULL_RECORDER.enabled
    t0 = time.perf_counter()
    for _ in range(100_000):
        with NULL_RECORDER.span("step", step=1):
            pass
    dt = time.perf_counter() - t0
    assert dt < 2.0, f"null span overhead {dt:.3f}s for 100k spans"
    assert NULL_RECORDER.hist("x").count == 0   # null histogram, no state


# ---------------------------------------------------------------------------
# metrics: histogram percentiles against numpy
# ---------------------------------------------------------------------------

def test_histogram_percentiles_match_numpy():
    from repro.telemetry import Histogram
    rng = np.random.default_rng(0)
    vals = rng.lognormal(size=257)
    h = Histogram()
    for v in vals:
        h.observe(float(v))
    assert h.count == 257
    assert h.percentile(50) == pytest.approx(np.percentile(vals, 50))
    assert h.percentile(99) == pytest.approx(np.percentile(vals, 99))
    s = h.summary()
    assert s["mean"] == pytest.approx(vals.mean())
    assert s["max"] == pytest.approx(vals.max())
    empty = Histogram()
    assert empty.percentile(50) is None
    assert empty.summary()["p99"] is None


# ---------------------------------------------------------------------------
# sinks: JSONL round trip and Chrome trace schema
# ---------------------------------------------------------------------------

def test_jsonl_sink_and_chrome_trace_schema(tmp_path):
    from repro.telemetry import (
        Recorder,
        JsonlSink,
        merge_process_traces,
        read_jsonl,
        trace_path,
    )
    r = Recorder(process="train", process_index=0)
    sink = JsonlSink(trace_path(str(tmp_path), 0))
    r.add_listener(sink)
    r.event("meta", process="train", process_index=0, clock="monotonic")
    with r.span("step", step=1):
        with r.span("compile", step=1):
            pass
    r.close()
    sink.close()

    lines = read_jsonl(trace_path(str(tmp_path), 0))
    assert [e["kind"] for e in lines][:3] == ["meta", "compile", "step"]

    merged = merge_process_traces(str(tmp_path))
    assert merged == os.path.join(str(tmp_path), "trace.json")
    doc = json.loads(open(merged).read())        # strict: valid JSON only
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    span_evs = [e for e in evs if e.get("ph") == "X"]
    assert {e["name"] for e in span_evs} == {"step", "compile"}
    for e in span_evs:
        # Chrome trace contract: complete events carry µs ts + dur, pid/tid
        assert set(e) >= {"name", "ph", "ts", "dur", "pid", "tid"}
        assert e["ts"] >= 0 and e["dur"] >= 0
    assert any(e.get("ph") == "M" and e["args"]["name"] == "train[0]"
               for e in evs)
    assert any(e.get("ph") == "i" for e in evs)   # instants present
    # ts rebased to the process's first event, so spans start near zero
    assert min(e["ts"] for e in span_evs) < 1e6


def test_merge_process_traces_empty_dir_returns_none(tmp_path):
    from repro.telemetry import merge_process_traces
    assert merge_process_traces(str(tmp_path)) is None


# ---------------------------------------------------------------------------
# autotune: exact fit on a synthetic timing table
# ---------------------------------------------------------------------------

def test_fit_comm_model_recovers_synthetic_constants():
    from repro.telemetry import CommProbe, choose_bucket_bytes, fit_comm_model
    G, lat, bw = 8, 5e-6, 6.8e9            # the FDR table constants
    probes = [CommProbe(nbytes=n, backend="lax",
                        seconds=2 * (G - 1) * lat + 2 * (G - 1) / G * n / bw)
              for n in (4096, 65536, 1 << 20, 4 << 20)]
    got_lat, got_bw = fit_comm_model(probes, G)
    assert got_lat == pytest.approx(lat, rel=1e-6)
    assert got_bw == pytest.approx(bw, rel=1e-6)
    # the chosen bucket is the §3.2 closed form at the fitted constants
    from repro.core.balance import optimal_bucket_bytes
    from repro.telemetry.autotune import measured_hw
    total = 128 << 20
    want = int(optimal_bucket_bytes(float(total), G, measured_hw(lat, bw)))
    assert choose_bucket_bytes(total, G, lat, bw) == want
    assert want == pytest.approx(
        np.sqrt(total * lat * bw * G), rel=1e-6)   # sqrt(B*SWlat*BW*G)


def test_fit_comm_model_degenerate_group():
    from repro.telemetry import choose_bucket_bytes, fit_comm_model
    from repro.telemetry.autotune import MAX_BANDWIDTH, MIN_LATENCY_S
    lat, bw = fit_comm_model([], 1)
    assert lat == MIN_LATENCY_S and bw == MAX_BANDWIDTH
    # G=1: no wire time, one whole-tree bucket
    assert choose_bucket_bytes(10 << 20, 1, lat, bw) == 10 << 20


def test_autotune_picks_measured_optimal_bucket_on_mesh():
    """Drive the real autotuner (real mesh, real schedules) but with a FAKE
    clock advanced by the synthetic ring model — the fitted constants and
    the chosen bucket must then be exactly the model's closed form."""
    out = run_py("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.comm.bucketer import CommConfig
        from repro.launch.mesh import make_host_mesh
        from repro.telemetry.autotune import autotune_comm
        from repro.telemetry.events import Recorder

        mesh = make_host_mesh(1)
        G = 8
        params = {"w": jnp.zeros((200_000,), jnp.float32),
                  "b": jnp.zeros((1000,), jnp.float32)}
        rec = Recorder()
        comm = autotune_comm(params, mesh, ("data",), CommConfig(),
                             recorder=rec, reps=1, log=print)
        plan = [e for e in rec.events if e["kind"] == "autotune_plan"]
        assert len(plan) == 1, rec.events
        p = plan[0]
        assert p["group"] == G and p["chosen_backend"] == comm.backend
        assert p["bucket_bytes"] == comm.bucket_bytes
        probes = [e for e in rec.events if e["kind"] == "collective"]
        assert len(probes) >= 2
        assert all(e["phase"] == "autotune-probe" for e in probes)
        # bucket plan stays inside the clamp range and is G-padded sane
        total = (200_000 + 1000) * 4
        assert 1 <= comm.bucket_bytes <= total
        print("OK bucket", comm.bucket_bytes, "backend", comm.backend)
    """, devices=8)
    assert "OK" in out


def test_comm_auto_run_matches_fixed_comm_loss_and_emits_trace():
    """The acceptance criterion: a --comm auto run completes with the same
    final loss as the fixed-comm run to tight tolerance (the §3.4 update is
    bucket-size INVARIANT, but the autotuner also picks the wire format
    jointly and int8's per-hop quantization is lossy), emits a loadable
    Chrome trace containing step/data_wait/collective spans, and logs the
    autotuned plan including the chosen wire format."""
    with tempfile.TemporaryDirectory() as td:
        out = run_py(f"""
            import json
            from repro.launch.train import main
            quiet_args = ["--arch", "vgg-a", "--smoke", "--steps", "4",
                          "--batch", "8", "--schedule", "constant",
                          "--parallel", "zero1"]
            h_auto = main(quiet_args + ["--comm", "auto",
                                        "--trace-dir", {td!r}])
            h_fix = main(quiet_args)
            diff = abs(h_auto[-1]["loss"] - h_fix[-1]["loss"])
            assert diff <= 1e-3 * abs(h_fix[-1]["loss"]), (h_auto, h_fix)
            evs = json.load(open({td!r} + "/trace.json"))["traceEvents"]
            plan = next(e for e in evs
                        if e.get("name") == "autotune_plan")
            assert plan["args"]["chosen_wire_format"] in (
                "fp32", "bf16", "int8"), plan
            names = {{e.get("name") for e in evs}}
            for want in ("step", "data_wait", "collective",
                         "autotune_plan", "autotune"):
                assert want in names, (want, names)
            steps = sorted(e["args"]["step"] for e in evs
                           if e.get("name") == "step" and e.get("ph") == "X")
            assert steps == [1, 2, 3, 4], steps
            print("LOSS_EQUAL")
        """, devices=8)
        assert "LOSS_EQUAL" in out


# ---------------------------------------------------------------------------
# spec plumbing: TelemetrySpec coercion + comm="auto" validation
# ---------------------------------------------------------------------------

def test_runspec_telemetry_coercion_and_comm_auto_validation():
    from repro.api import RunSpec, TelemetrySpec
    s = RunSpec(arch="vgg-a", telemetry="/tmp/tr")
    assert isinstance(s.telemetry, TelemetrySpec)
    assert s.telemetry.trace_dir == "/tmp/tr"
    RunSpec(arch="vgg-a", parallel="zero1", comm="auto")      # valid
    with pytest.raises(ValueError, match="auto"):
        RunSpec(arch="vgg-a", parallel="zero1", comm="fastest-please")
    with pytest.raises(ValueError, match="comm-capable"):
        RunSpec(arch="vgg-a", parallel="dp", comm="auto")
    with pytest.raises(ValueError):
        TelemetrySpec(autotune_reps=0)
    with pytest.raises(ValueError):
        RunSpec(arch="vgg-a", telemetry=123)


def test_train_cli_rejects_comm_auto_conflicts():
    import argparse

    from repro.launch.train import add_run_args, check_run_args
    ap = add_run_args(argparse.ArgumentParser())
    with pytest.raises(SystemExit):
        check_run_args(ap, ap.parse_args(
            ["--arch", "vgg-a", "--parallel", "zero1", "--comm", "auto",
             "--bucket-mb", "4"]))
    with pytest.raises(SystemExit):
        check_run_args(ap, ap.parse_args(
            ["--arch", "vgg-a", "--parallel", "dp", "--comm", "auto"]))
    # clean combination passes
    check_run_args(ap, ap.parse_args(
        ["--arch", "vgg-a", "--parallel", "zero1", "--comm", "auto"]))


# ---------------------------------------------------------------------------
# serve: latency histograms == external computation (asserted ONCE, here;
# benchmarks/serve_load.py now consumes latency_stats instead of re-deriving)
# ---------------------------------------------------------------------------

def test_server_latency_stats_match_external_numpy():
    from repro.api import ServeSpec, compile_serve
    spec = ServeSpec(arch="llama3-8b", smoke=True, max_batch=2,
                     page_size=8, num_pages=16, max_prompt=8,
                     max_new_tokens=4, prefill_bucket=8)
    server = compile_serve(spec)
    rng = np.random.default_rng(0)
    for _ in range(5):
        server.submit(rng.integers(1, 100, size=4).astype(np.int32), 3)
    done = server.drain()
    assert len(done) == 5
    stats = server.latency_stats()
    e2e = np.array([r.latency for r in done])
    ttft = np.array([r.first_token_t - r.submit_t for r in done])
    assert stats["n"] == 5
    assert stats["e2e_p50_s"] == pytest.approx(np.percentile(e2e, 50))
    assert stats["e2e_p99_s"] == pytest.approx(np.percentile(e2e, 99))
    assert stats["ttft_p50_s"] == pytest.approx(np.percentile(ttft, 50))
    assert stats["ttft_p99_s"] == pytest.approx(np.percentile(ttft, 99))
    server.reset_latency_stats()
    assert server.latency_stats()["n"] == 0
    assert server.latency_stats()["e2e_p50_s"] is None


def test_server_emits_prefill_and_decode_spans():
    from repro.api import ServeSpec, compile_serve
    from repro.telemetry import Recorder
    rec = Recorder()
    spec = ServeSpec(arch="llama3-8b", smoke=True, max_batch=2,
                     page_size=8, num_pages=16, max_prompt=8,
                     max_new_tokens=2, prefill_bucket=8)
    server = compile_serve(spec, recorder=rec)
    server.submit(np.ones(4, np.int32), 2)
    server.drain()
    kinds = {e["kind"] for e in rec.events}
    assert "prefill" in kinds and "decode" in kinds
    pre = next(e for e in rec.events if e["kind"] == "prefill")
    assert pre["tokens"] == 4 and pre["bucket"] == 8


# ---------------------------------------------------------------------------
# heartbeat redesign: monotonic payload beats NTP-jumped mtimes
# ---------------------------------------------------------------------------

def _fake_handle(tmpdir, name="hb"):
    from repro.cluster.launcher import WorkerHandle

    class _Alive:
        returncode = None

        def poll(self):
            return None

    return WorkerHandle(proc=_Alive(), process_id=0,
                        hb_file=os.path.join(tmpdir, name), log_file=None)


def test_heartbeat_write_parse_round_trip(tmp_path):
    from repro.cluster.launcher import parse_heartbeat, write_heartbeat
    p = str(tmp_path / "hb")
    assert parse_heartbeat(p) is None
    write_heartbeat(p, 7, 123.5)
    hb = parse_heartbeat(p)
    assert (hb.step, hb.mono) == (7, 123.5)
    # legacy bare-int files still parse, mono-less
    with open(p, "w") as f:
        f.write("42")
    hb = parse_heartbeat(p)
    assert hb.step == 42 and hb.mono is None
    with open(p, "w") as f:
        f.write("not json at all {")
    assert parse_heartbeat(p) is None


def test_staleness_tracks_payload_change_not_wall_clock(tmp_path):
    from repro.cluster.launcher import write_heartbeat
    h = _fake_handle(str(tmp_path))
    now = time.monotonic()
    spawned = now - 100.0
    # no beat yet: stale since spawn
    assert h.staleness(now, spawned) == pytest.approx(100.0, abs=1.0)
    write_heartbeat(h.hb_file, 3, 50.0)
    # first observation of the payload: fresh from the supervisor's view
    assert h.staleness(now, spawned) == pytest.approx(0.0, abs=1e-6)
    # same payload 80s later: 80s stale — even though we now smash the
    # file's MTIME to look brand new (an NTP forward jump must not mask
    # a genuine hang)
    os.utime(h.hb_file, (time.time() + 3600, time.time() + 3600))
    assert h.staleness(now + 80.0, spawned) == pytest.approx(80.0, abs=1e-6)
    # the payload changes (worker made a step): fresh again, regardless of
    # an mtime far in the PAST (NTP backward jump must not false-trigger)
    write_heartbeat(h.hb_file, 4, 51.0)
    os.utime(h.hb_file, (0, 0))
    assert h.staleness(now + 81.0, spawned) == pytest.approx(0.0, abs=1e-6)


def test_staleness_legacy_mtime_fallback(tmp_path):
    h = _fake_handle(str(tmp_path))
    with open(h.hb_file, "w") as f:
        f.write("5")
    now = time.monotonic()
    # fresh mtime -> fresh
    assert h.staleness(now, now - 500.0) < 5.0
    # old mtime -> stale by about that much
    old_wall = time.time() - 300.0
    os.utime(h.hb_file, (old_wall, old_wall))
    assert h.staleness(now, now - 500.0) == pytest.approx(300.0, abs=5.0)


def test_heartbeat_listener_rides_step_spans(tmp_path):
    from repro.cluster.launcher import (
        make_heartbeat_listener,
        parse_heartbeat,
    )
    from repro.telemetry import Recorder
    r = Recorder()
    hb = str(tmp_path / "hb")
    r.add_listener(make_heartbeat_listener(hb))
    with r.span("data_wait", step=1):
        pass
    assert parse_heartbeat(hb) is None        # only step spans beat
    with r.span("step", step=1):
        pass
    beat = parse_heartbeat(hb)
    assert beat.step == 1 and beat.mono is not None
    step_ev = next(e for e in r.events if e["kind"] == "step")
    assert beat.mono == pytest.approx(step_ev["t1"])


def test_cluster_run_merges_per_process_traces():
    """2 real worker processes with --trace-dir: the supervisor must merge
    both workers' JSONL traces into one Chrome trace whose step spans are
    per-process monotonic-consistent."""
    with tempfile.TemporaryDirectory() as td:
        env = dict(os.environ, PYTHONPATH=SRC)
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.cluster",
             "--processes", "2", "--arch", "vgg-a", "--smoke",
             "--steps", "3", "--batch", "8", "--schedule", "constant",
             "--run-dir", td, "--trace-dir", td],
            env=env, capture_output=True, text=True, timeout=420)
        assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-2000:]
        evs = json.load(open(os.path.join(td, "trace.json")))["traceEvents"]
        by_pid = {}
        for e in evs:
            if e.get("name") == "step" and e.get("ph") == "X":
                by_pid.setdefault(e["pid"], []).append(e)
        assert set(by_pid) == {0, 1}, sorted(by_pid)
        for pid, spans in by_pid.items():
            spans.sort(key=lambda e: e["args"]["step"])
            assert [e["args"]["step"] for e in spans] == [1, 2, 3]
            # within a process the rebased timestamps are ordered and
            # non-overlapping (step N ends before step N+1 begins)
            for a, b in zip(spans, spans[1:]):
                assert a["ts"] + a["dur"] <= b["ts"] + 1e-9


# ---------------------------------------------------------------------------
# benchmark regression gate
# ---------------------------------------------------------------------------

def _run_checker(fresh, baseline):
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks",
                                      "check_regression.py"),
         "--fresh-dir", fresh, "--baseline-dir", baseline,
         "--files", "BENCH_kernels.json"],
        capture_output=True, text=True, timeout=60)


def test_check_regression_bands(tmp_path):
    base = {"benchmark": "kernels_micro",
            "rows": {"kernel/x": {"us": 100.0, "derived": "ok=True"}},
            "gates": {"n_kernels": 4, "all_ok": True}}
    bdir, fdir = tmp_path / "base", tmp_path / "fresh"
    bdir.mkdir(), fdir.mkdir()
    (bdir / "BENCH_kernels.json").write_text(json.dumps(base))

    # identical -> pass
    (fdir / "BENCH_kernels.json").write_text(json.dumps(base))
    out = _run_checker(str(fdir), str(bdir))
    assert out.returncode == 0, out.stdout

    # wall-clock drift (2x) stays advisory -> pass with a warning
    drift = json.loads(json.dumps(base))
    drift["rows"]["kernel/x"]["us"] = 200.0
    (fdir / "BENCH_kernels.json").write_text(json.dumps(drift))
    out = _run_checker(str(fdir), str(bdir))
    assert out.returncode == 0, out.stdout
    assert "WARN" not in out.stdout       # 2x is inside the 8x band
    drift["rows"]["kernel/x"]["us"] = 5000.0
    (fdir / "BENCH_kernels.json").write_text(json.dumps(drift))
    out = _run_checker(str(fdir), str(bdir))
    assert out.returncode == 0 and "WARN" in out.stdout, out.stdout

    # oracle gate flip -> hard fail
    bad = json.loads(json.dumps(base))
    bad["gates"]["all_ok"] = False
    (fdir / "BENCH_kernels.json").write_text(json.dumps(bad))
    out = _run_checker(str(fdir), str(bdir))
    assert out.returncode == 1 and "all_ok" in out.stdout, out.stdout

    # a baselined metric vanishing from the fresh report -> hard fail
    gone = json.loads(json.dumps(base))
    del gone["gates"]["all_ok"]
    (fdir / "BENCH_kernels.json").write_text(json.dumps(gone))
    out = _run_checker(str(fdir), str(bdir))
    assert out.returncode == 1 and "missing" in out.stdout, out.stdout

    # fresh report absent entirely -> hard fail
    os.remove(fdir / "BENCH_kernels.json")
    out = _run_checker(str(fdir), str(bdir))
    assert out.returncode == 1, out.stdout
