"""Compressed gradient wire formats (``CommConfig.wire_format``).

Covers the whole vertical: the int8/topk Pallas kernels against their jnp
oracles (with error bounds across message sizes and G in {1, 2, 4, 8}),
CommConfig/RunSpec validation against the MODE_CAPS capability table, the
bytes-on-wire balance models, the topk error-feedback residual through
checkpoint save/restore and cross-world replan, the persisted comm=auto
plan cache, and its invalidation by the elastic supervisor on a world-size
change (fake-proc harness — no real processes).

Forced-device-count tests run in subprocesses so the rest of the suite
keeps the single real CPU device (same isolation policy as
tests/test_distributed.py)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels import ring as kring

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

RNG = np.random.default_rng(42)


def _arr(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


def run_py(code: str, devices: int = 8, timeout: int = 300) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    prelude = "import repro.jaxcompat\n"
    out = subprocess.run([sys.executable, "-c",
                          prelude + textwrap.dedent(code)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# int8 quantize / ring-hop kernels vs oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 5, 128, 1000, 4096])
def test_int8_quantize_matches_oracle_exactly(n):
    x = _arr(n)
    q, s = kring.int8_quantize(x, interpret=True)
    qr, sr = kref.int8_quantize_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-7)
    assert np.abs(np.asarray(q)).max() <= 127


def test_int8_quantize_all_zero_message_is_well_defined():
    q, s = kring.int8_quantize(jnp.zeros((64,), jnp.float32), interpret=True)
    assert float(s[0]) == 1.0     # scale 1.0 so dequantize is a no-op
    assert not np.asarray(q).any()


@pytest.mark.parametrize("n", [7, 640, 4096])
def test_int8_roundtrip_error_bounded_by_half_scale(n):
    x = _arr(n) * 10.0
    q, s = kref.int8_quantize_ref(x)
    back = np.asarray(kref.int8_dequantize_ref(q, s))
    # round-to-nearest: per-element error <= scale/2
    bound = float(s[0]) / 2 + 1e-6
    assert np.abs(back - np.asarray(x)).max() <= bound


@pytest.mark.parametrize("G", [1, 2, 4, 8])
@pytest.mark.parametrize("n", [3, 257])
def test_ring_hop_int8_matches_oracle(G, n):
    chunks = _arr(G, n)
    q, s = kref.int8_quantize_ref(_arr(n))
    for c in range(G):
        qk, sk = kring.ring_hop_int8(chunks, q, s, jnp.int32(c),
                                     interpret=True)
        qr, sr = kref.ring_hop_int8_ref(chunks, q, s, c)
        np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
        np.testing.assert_allclose(np.asarray(sk), np.asarray(sr),
                                   rtol=1e-6)


@pytest.mark.parametrize("G", [2, 4, 8])
@pytest.mark.parametrize("n", [8, 640, 4096])
def test_int8_ring_error_is_additive_across_hops(G, n):
    """Per-hop f32 accumulation keeps the total quantization error bounded
    by the SUM of the per-hop half-scales (one rounding per hop), not a
    product — the property the fused hop kernel exists to preserve."""
    chunks = _arr(G, n)
    exact = np.asarray(chunks.astype(jnp.float32).sum(0))
    q, s = kref.int8_quantize_ref(chunks[0])
    bound = float(s[0]) / 2
    for j in range(1, G):
        q, s = kref.ring_hop_int8_ref(chunks, q, s, jnp.int32(j))
        bound += float(s[0]) / 2
    got = np.asarray(kref.int8_dequantize_ref(q, s))
    assert np.abs(got - exact).max() <= bound + 1e-6


# ---------------------------------------------------------------------------
# topk select / scatter / ring-hop kernels vs oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("G", [1, 2, 4, 8])
@pytest.mark.parametrize("n,k", [(8, 2), (40, 5), (257, 32)])
def test_ring_hop_topk_matches_oracle(G, n, k):
    chunks = _arr(G, n)
    vals, idx = kref.topk_select_ref(_arr(n), k)
    for c in range(G):
        got = kring.ring_hop_topk(chunks, vals, idx, jnp.int32(c),
                                  interpret=True)
        want = kref.ring_hop_topk_ref(chunks, vals, idx, c)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


def test_topk_select_scatter_round_trips_at_full_density():
    x = _arr(129)
    vals, idx = kref.topk_select_ref(x, 129)
    np.testing.assert_allclose(
        np.asarray(kref.topk_scatter_ref(vals, idx, 129)), np.asarray(x),
        rtol=1e-7)
    assert idx.dtype == jnp.int32


def test_topk_mask_keeps_largest_magnitudes_in_place():
    x = _arr(200)
    k = 20
    kept = np.asarray(kref.topk_mask_ref(x, k))
    xn = np.asarray(x)
    nz = np.flatnonzero(kept)
    assert len(nz) == k
    np.testing.assert_array_equal(kept[nz], xn[nz])   # in place, unscaled
    dropped = np.setdiff1d(np.arange(200), nz)
    assert np.abs(xn[nz]).min() >= np.abs(xn[dropped]).max()
    # residual + kept reconstructs the input exactly
    np.testing.assert_array_equal(kept + (xn - kept), xn)


def test_topk_chunk_k_floor_and_ceiling():
    from repro.comm.backends.pallas_ring import topk_chunk_k
    assert topk_chunk_k(100, 0.05) == 5
    assert topk_chunk_k(10, 0.25) == 3          # ceil(2.5)
    assert topk_chunk_k(10, 0.01) == 1          # never empty
    assert topk_chunk_k(10, 0.01, floor=4) == 4
    assert topk_chunk_k(3, 1.0) == 3            # never more than n
    assert topk_chunk_k(3, 1.0, floor=8) == 3


# ---------------------------------------------------------------------------
# CommConfig / RunSpec validation against MODE_CAPS
# ---------------------------------------------------------------------------

def test_comm_config_unknown_wire_format_names_supported_set():
    from repro.comm.bucketer import WIRE_FORMATS, CommConfig
    with pytest.raises(ValueError) as ei:
        CommConfig(wire_format="fp4")
    msg = str(ei.value)
    assert "fp4" in msg
    for fmt in WIRE_FORMATS:
        assert fmt in msg, msg


def test_comm_config_unknown_reduce_dtype_names_supported_set():
    from repro.comm import CommConfig
    with pytest.raises(ValueError) as ei:
        CommConfig(reduce_dtype="float8")
    msg = str(ei.value)
    assert "float8" in msg and "float32" in msg and "bfloat16" in msg


def test_comm_config_wire_format_derivation_and_properties():
    from repro.comm import CommConfig
    assert CommConfig().wire_format == "fp32"
    assert CommConfig(reduce_dtype="bfloat16").wire_format == "bf16"
    assert CommConfig(reduce_dtype="bfloat16").wire_dtype == jnp.bfloat16
    int8 = CommConfig(wire_format="int8")
    assert int8.compressed and int8.wire_dtype == jnp.float32
    assert not CommConfig().compressed
    with pytest.raises(ValueError, match="conflicting"):
        CommConfig(reduce_dtype="bfloat16", wire_format="int8")
    for bad in (0.0, -0.1, 1.5):
        with pytest.raises(ValueError, match="topk_ratio"):
            CommConfig(wire_format="topk", topk_ratio=bad)


def test_runspec_mode_caps_gate_wire_formats():
    from repro.api import RunSpec
    from repro.api.spec import MODE_CAPS
    from repro.comm import CommConfig
    topk = CommConfig(wire_format="topk")
    RunSpec(arch="vgg-a", parallel="zero1", comm=topk)          # valid
    # stale-sync takes the stateless int8 wire but not the EF-stateful topk
    RunSpec(arch="vgg-a", parallel="stale-sync",
            comm=CommConfig(wire_format="int8"))
    with pytest.raises(ValueError, match="not valid under parallel="):
        RunSpec(arch="vgg-a", parallel="stale-sync", comm=topk)
    # gossip moves no ring message at all: dense formats only
    for fmt in ("int8", "topk"):
        with pytest.raises(ValueError, match="not valid under parallel="):
            RunSpec(arch="vgg-a", parallel="gossip",
                    comm=CommConfig(backend="gossip", wire_format=fmt))
    RunSpec(arch="vgg-a", parallel="gossip",
            comm=CommConfig(backend="gossip", reduce_dtype="bfloat16"))
    assert MODE_CAPS["zero1"].wire_formats == ("fp32", "bf16", "int8",
                                               "topk")


def test_runspec_rejects_topk_under_overlap():
    from repro.api import RunSpec
    from repro.comm import CommConfig
    with pytest.raises(ValueError, match="overlap"):
        RunSpec(arch="vgg-a", parallel="zero1",
                comm=CommConfig(wire_format="topk", overlap=True))
    # int8 is stateless, so it overlaps fine
    RunSpec(arch="vgg-a", parallel="zero1",
            comm=CommConfig(wire_format="int8", overlap=True))


def test_train_cli_rejects_wire_format_outside_caps():
    import argparse

    from repro.launch.train import add_run_args, check_run_args
    for argv in (["--parallel", "gossip", "--wire-format", "int8"],
                 ["--parallel", "stale-sync", "--wire-format", "topk"],
                 ["--parallel", "zero1", "--wire-format", "topk",
                  "--overlap"]):
        ap = argparse.ArgumentParser()
        add_run_args(ap)
        with pytest.raises(SystemExit):
            check_run_args(ap, ap.parse_args(["--arch", "vgg-a"] + argv))


def test_spec_from_args_threads_wire_format_and_ratio():
    import argparse

    from repro.launch.train import add_run_args, check_run_args, \
        spec_from_args
    ap = argparse.ArgumentParser()
    add_run_args(ap)
    args = ap.parse_args(["--arch", "vgg-a", "--parallel", "zero1",
                          "--wire-format", "topk", "--topk-ratio", "0.25"])
    check_run_args(ap, args)
    spec = spec_from_args(args)
    assert spec.comm.wire_format == "topk"
    assert spec.comm.topk_ratio == 0.25


# ---------------------------------------------------------------------------
# bytes-on-wire balance models
# ---------------------------------------------------------------------------

def test_wire_reduce_factor_table():
    from repro.core.balance import wire_reduce_factor
    assert wire_reduce_factor("fp32") == 1.0
    assert wire_reduce_factor("bf16") == 0.5
    assert wire_reduce_factor("int8") == 0.25
    assert wire_reduce_factor("topk", 0.05) == pytest.approx(0.1)
    assert wire_reduce_factor("topk", 0.25) == pytest.approx(0.5)
    with pytest.raises(ValueError, match="fp4"):
        wire_reduce_factor("fp4")


def test_compressed_allreduce_time_reduces_to_dense_at_fp32():
    from repro.core.balance import bucketed_allreduce_time, \
        compressed_allreduce_time
    from repro.telemetry.autotune import measured_hw
    hw = measured_hw(1e-5, 1e9)
    kw = dict(total_bytes=64 * 2**20, n_tensors=20, bucket_bytes=4 * 2**20,
              G=8, hw=hw)
    assert compressed_allreduce_time(wire_format="fp32", **kw) == \
        pytest.approx(bucketed_allreduce_time(**kw))
    # every compressed format is strictly cheaper than the dense wire
    dense = compressed_allreduce_time(wire_format="fp32", **kw)
    for fmt in ("bf16", "int8", "topk"):
        assert compressed_allreduce_time(wire_format=fmt, **kw) < dense


def test_optimal_bucket_grows_with_compression():
    """b* = sqrt(B*SWlat*BW*G * 2/(1+f)): a compressed reduce wire shrinks
    the bandwidth term, so the latency term amortizes over a LARGER
    bucket — int8 (f=1/4) by exactly sqrt(2/1.25 / 1) vs fp32."""
    import math

    from repro.core.balance import optimal_bucket_bytes
    from repro.telemetry.autotune import measured_hw
    hw = measured_hw(1e-5, 1e9)
    B = 256 * 2**20
    b_fp32 = optimal_bucket_bytes(B, 8, hw)
    b_int8 = optimal_bucket_bytes(B, 8, hw, wire_format="int8")
    assert b_int8 == pytest.approx(b_fp32 * math.sqrt(2.0 / 1.25))
    assert b_fp32 < b_int8 < B


def test_int8_wire_reduce_bytes_cut_by_at_least_3p5x():
    """The BENCH_comm gate's model: int8 cuts reduce-side wire bytes >= 3.5x
    vs fp32 (4x payload minus the per-message scale overhead)."""
    from repro.core.balance import wire_reduce_bytes
    total = 4 * 10_000_000            # 10M fp32 gradient elements
    dense = wire_reduce_bytes(total, G=8, n_coll=12, wire_format="fp32")
    i8 = wire_reduce_bytes(total, G=8, n_coll=12, wire_format="int8")
    assert dense == total
    assert i8 > total / 4             # scale overhead is accounted
    assert dense / i8 > 3.5


# ---------------------------------------------------------------------------
# the persisted comm=auto plan cache
# ---------------------------------------------------------------------------

def test_autotune_cache_save_load_round_trip(tmp_path):
    from repro.telemetry.autotune import _load_cached_plan, \
        _save_cached_plan
    path = str(tmp_path / "cache.json")
    key = {"G": 4, "axes": ["data"], "total_bytes": 100,
           "backends": ["lax"], "wire_formats": ["fp32", "int8"]}
    plan = {"bucket_bytes": 65536, "chosen_backend": "lax",
            "chosen_wire_format": "int8"}
    assert _load_cached_plan(path, key) is None          # absent
    _save_cached_plan(path, key, plan)
    assert _load_cached_plan(path, key) == plan
    assert _load_cached_plan(path, dict(key, G=2)) is None   # other topology
    with open(path, "w") as f:
        f.write("{not json")
    assert _load_cached_plan(path, key) is None          # corrupt


def test_autotune_comm_cache_hit_skips_probing(tmp_path, monkeypatch):
    """Second launch with the same key must return the persisted plan
    WITHOUT timing a single collective (probing is made to raise)."""
    from jax.sharding import AxisType

    from repro.comm import CommConfig
    from repro.telemetry import autotune
    def quiet(*a, **k):
        pass
    params = {"w": jnp.zeros((4096,), jnp.float32),
              "b": jnp.zeros((128,), jnp.float32)}
    mesh = jax.make_mesh((1,), ("data",), devices=jax.devices()[:1],
                         axis_types=(AxisType.Auto,))
    path = str(tmp_path / "autotune_cache.json")
    first = autotune.autotune_comm(params, mesh, ("data",), CommConfig(),
                                   backends=["lax"], reps=1, log=quiet,
                                   wire_formats=("fp32", "bf16", "int8"),
                                   cache_path=path)
    saved = json.load(open(path))
    assert saved["plan"]["chosen_backend"] == first.backend
    assert saved["plan"]["chosen_wire_format"] == first.wire_format
    assert saved["plan"]["bucket_bytes"] == first.bucket_bytes

    def boom(*a, **k):
        raise RuntimeError("probe ran despite a cached plan")

    monkeypatch.setattr(autotune, "_time_backend", boom)
    second = autotune.autotune_comm(params, mesh, ("data",), CommConfig(),
                                    backends=["lax"], reps=1, log=quiet,
                                    wire_formats=("fp32", "bf16", "int8"),
                                    cache_path=path)
    assert second == first
    # a different candidate set is a different key: must re-probe (and
    # here, hit the tripwire) — stale plans never leak across configs
    with pytest.raises(RuntimeError, match="probe ran"):
        autotune.autotune_comm(params, mesh, ("data",), CommConfig(),
                               backends=["lax"], reps=1, log=quiet,
                               wire_formats=("fp32",), cache_path=path)


def test_autotune_joint_choice_picks_int8_never_topk():
    """With a real fitted model the predicted wire time orders strictly by
    the reduce factor at equal latency count, so the joint (backend,
    format) winner is int8; topk is filtered from auto entirely (lossy AND
    stateful — explicit opt-in only)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import AxisType
        from repro.comm import CommConfig
        from repro.telemetry.autotune import autotune_comm
        quiet = lambda *a, **k: None
        params = {"w": jnp.zeros((4096,), jnp.float32),
                  "b": jnp.zeros((512,), jnp.float32)}
        mesh = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4],
                             axis_types=(AxisType.Auto,))
        comm = autotune_comm(params, mesh, ("data",), CommConfig(),
                             backends=["lax"], reps=1, log=quiet,
                             wire_formats=("fp32", "bf16", "int8", "topk"))
        assert comm.wire_format == "int8", comm.wire_format
        assert comm.backend == "lax"
        print("OK")
    """, devices=4)
    assert "OK" in out


# ---------------------------------------------------------------------------
# elastic supervisor: world-size change invalidates the plan cache
# (fake-proc harness — duck-typed handles, no real processes)
# ---------------------------------------------------------------------------

class _FakeProc:
    def __init__(self, returncode):
        self.returncode = returncode

    def poll(self):
        return self.returncode


def _fake_handle(pid, returncode, tmpdir):
    from repro.cluster.launcher import WorkerHandle
    return WorkerHandle(proc=_FakeProc(returncode), process_id=pid,
                        hb_file=os.path.join(tmpdir, f"hb_{pid}"),
                        log_file=None)


def _elastic_fixture(tmp_path, monkeypatch, first_attempt_rcs, later_world_rc=0):
    """Monkeypatched spawn_workers: attempt 0 returns handles with the given
    returncodes; later attempts return a healthy group.  Pre-writes the
    autotune cache and worker 0's result.json."""
    from repro.cluster import elastic
    from repro.cluster.launcher import autotune_cache_path, result_path
    run_dir = str(tmp_path)
    cache = autotune_cache_path(run_dir)
    with open(cache, "w") as f:
        json.dump({"key": {"G": 2}, "plan": {"bucket_bytes": 1}}, f)
    with open(result_path(run_dir), "w") as f:
        json.dump({"final_loss": 1.0}, f)
    calls = []

    def fake_spawn(world, argv, rd, attempt=0, local_devices=1):
        calls.append((attempt, world))
        if attempt == 0:
            return [_fake_handle(i, rc, run_dir)
                    for i, rc in enumerate(first_attempt_rcs)]
        return [_fake_handle(i, later_world_rc, run_dir)
                for i in range(world)]

    monkeypatch.setattr(elastic, "spawn_workers", fake_spawn)
    return elastic, run_dir, cache, calls


def test_elastic_shrink_invalidates_autotune_cache(tmp_path, monkeypatch):
    elastic, run_dir, cache, calls = _elastic_fixture(
        tmp_path, monkeypatch, first_attempt_rcs=[0, -9])
    logs = []
    res = elastic.run_elastic(["worker"], run_dir, num_processes=2,
                              poll_interval=0.01, log=logs.append)
    assert res.final_world == 1 and res.attempts == 2
    assert calls == [(0, 2), (1, 1)]
    assert not os.path.exists(cache), \
        "stale autotune plan survived a world-size change"
    assert any("invalidated" in str(ln) for ln in logs), logs


def test_elastic_grow_back_same_world_keeps_cache(tmp_path, monkeypatch):
    """grow_back relaunches at FULL strength: the world size is unchanged,
    so the cached plan is still valid and must survive."""
    elastic, run_dir, cache, calls = _elastic_fixture(
        tmp_path, monkeypatch, first_attempt_rcs=[0, -9])
    res = elastic.run_elastic(["worker"], run_dir, num_processes=2,
                              poll_interval=0.01, grow_back=True,
                              log=lambda *_: None)
    assert res.final_world == 2 and res.attempts == 2
    assert calls == [(0, 2), (1, 2)]
    assert os.path.exists(cache), \
        "same-topology relaunch must not re-probe"


# ---------------------------------------------------------------------------
# topk error-feedback residual through checkpoint save/restore and replan
# ---------------------------------------------------------------------------

_TOPK_COMM = ('CommConfig(backend="pallas-ring", wire_format="topk", '
              'topk_ratio=0.25)')


def test_topk_ef_ckpt_resumes_same_world_exact(tmp_path):
    """Same-world resume restores the residual strictly (it is part of the
    saved opt_state), so one post-resume step lands on the SAME params as
    an uninterrupted run — the EF state round-trips losslessly."""
    ckpt = str(tmp_path / "ckpt")
    out = run_py(f"""
        import numpy as np, jax
        from repro.api import RunSpec, compile_run
        from repro.comm import CommConfig
        quiet = lambda *_: None
        base = RunSpec(arch="vgg-a", smoke=True, steps=3, batch=8,
                       schedule="constant", parallel="zero1",
                       comm={_TOPK_COMM},
                       ckpt_dir={ckpt!r}, ckpt_every=3, log_every=100)
        r1 = compile_run(base)
        r1.fit(log_fn=quiet)
        assert set(r1.opt_state) == {{"residual", "zero1"}}
        res = [np.asarray(x)
               for x in jax.tree.leaves(r1.opt_state["residual"])]
        assert any(np.abs(r).max() > 0 for r in res)   # EF mass carried
        r1.close()

        logs = []
        r2 = compile_run(base.replace(steps=4, ckpt_every=0))
        r2.fit(log_fn=logs.append)
        assert any("resuming from checkpoint step 3" in str(ln)
                   for ln in logs), logs
        r2.close()

        ref = compile_run(base.replace(steps=4, ckpt_dir=None,
                                       ckpt_every=0))
        ref.fit(log_fn=quiet); ref.close()
        for a, b in zip(jax.tree.leaves(r2.params),
                        jax.tree.leaves(ref.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-7)
        print("OK")
    """)
    assert "OK" in out


def test_topk_ef_ckpt_replans_across_worlds_rezeroing_residual(tmp_path):
    """Cross-world restore: the inner zero1 strips are re-planned to the
    new group size, but the residual is member-LOCAL unsent mass with no
    owner in the new world — it must come back ZERO at the new geometry."""
    ckpt = str(tmp_path / "ckpt")
    run_py(f"""
        from repro.api import RunSpec, compile_run
        from repro.comm import CommConfig
        spec = RunSpec(arch="vgg-a", smoke=True, steps=3, batch=8,
                       schedule="constant", parallel="zero1",
                       comm={_TOPK_COMM},
                       ckpt_dir={ckpt!r}, ckpt_every=3, log_every=100)
        run = compile_run(spec)
        run.fit(log_fn=lambda *_: None)
        run.close()
    """, devices=4)
    out = run_py(f"""
        import numpy as np, jax
        from repro.api import RunSpec, compile_run
        from repro.comm import CommConfig
        spec = RunSpec(arch="vgg-a", smoke=True, steps=4, batch=8,
                       schedule="constant", parallel="zero1",
                       comm={_TOPK_COMM},
                       ckpt_dir={ckpt!r}, log_every=100)
        run = compile_run(spec)
        run.restore(3)
        assert set(run.opt_state) == {{"residual", "zero1"}}
        for r in jax.tree.leaves(run.opt_state["residual"]):
            arr = np.asarray(r)
            assert arr.shape[0] == 2, arr.shape   # new world's G rows
            assert not arr.any()                  # re-zeroed, not replanned
        run.close()
        print("OK")
    """, devices=2)
    assert "OK" in out


def test_bare_zero1_ckpt_restores_into_topk_run(tmp_path):
    """Mode interop: a plain zero1 checkpoint (no residual saved) restores
    into a topk run — the inner strips load strictly, the EF wrapper
    re-initializes its residual to zero."""
    ckpt = str(tmp_path / "ckpt")
    out = run_py(f"""
        import numpy as np, jax
        from repro.api import RunSpec, compile_run
        from repro.comm import CommConfig
        quiet = lambda *_: None
        base = RunSpec(arch="vgg-a", smoke=True, steps=3, batch=8,
                       schedule="constant", parallel="zero1",
                       ckpt_dir={ckpt!r}, ckpt_every=3, log_every=100)
        rz = compile_run(base)
        rz.fit(log_fn=quiet); rz.close()

        rt = compile_run(base.replace(comm={_TOPK_COMM}, ckpt_every=0))
        rt.restore(3)
        assert set(rt.opt_state) == {{"residual", "zero1"}}
        for r in jax.tree.leaves(rt.opt_state["residual"]):
            assert not np.asarray(r).any()
        for a, b in zip(jax.tree.leaves(rt.opt_state["zero1"]),
                        jax.tree.leaves(rz.opt_state)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)
        rt.close()
        print("OK")
    """, devices=4)
    assert "OK" in out


# ---------------------------------------------------------------------------
# end-to-end acceptance: int8 on the Pallas ring converges with fp32
# ---------------------------------------------------------------------------

def test_int8_pallas_ring_smoke_within_1pct_of_fp32():
    out = run_py("""
        from repro.api import RunSpec, compile_run
        from repro.comm import CommConfig
        quiet = lambda *_: None
        def final(fmt):
            spec = RunSpec(arch="vgg-a", smoke=True, steps=4, batch=8,
                           schedule="constant", parallel="zero1",
                           comm=CommConfig(backend="pallas-ring",
                                           wire_format=fmt),
                           log_every=100)
            run = compile_run(spec)
            hist = run.fit(log_fn=quiet)
            run.close()
            return hist[-1]["loss"]
        fp32 = final("fp32")
        int8 = final("int8")
        gap = abs(int8 - fp32) / abs(fp32)
        assert gap <= 0.01, (fp32, int8, gap)
        print("OK", gap)
    """, devices=4)
    assert "OK" in out
