"""SSM property tests: chunk-parallel forms == naive per-step recurrences."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core.sharding import ShardingCtx
from repro.models import ssm

RNG = np.random.default_rng(7)


def _arr(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------
def ssd_naive(x, dt, A, Bm, Cm):
    """Literal recurrence: h_t = h_{t-1}*exp(A dt_t) + dt_t B_t x_t."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    h = jnp.zeros((Bsz, H, P, N))
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A[None, :])                  # (B,H)
        h = h * dA[:, :, None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt[:, t], x[:, t], Bm[:, t])
        ys.append(jnp.einsum("bhpn,bn->bhp", h, Cm[:, t]))
    return jnp.stack(ys, axis=1), h


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_naive(chunk):
    B, S, H, P, N = 2, 16, 3, 4, 8
    x = _arr(B, S, H, P)
    dt = jax.nn.softplus(_arr(B, S, H))
    A = -jnp.abs(_arr(H)) - 0.1
    Bm, Cm = _arr(B, S, N), _arr(B, S, N)
    y_naive, h_naive = ssd_naive(x, dt, A, Bm, Cm)
    y, h = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(y, y_naive, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h, h_naive, rtol=2e-4, atol=2e-4)


def test_ssd_chunk_size_invariance():
    B, S, H, P, N = 1, 32, 2, 4, 4
    x = _arr(B, S, H, P)
    dt = jax.nn.softplus(_arr(B, S, H))
    A = -jnp.abs(_arr(H)) - 0.1
    Bm, Cm = _arr(B, S, N), _arr(B, S, N)
    y8, _ = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    y32, _ = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    np.testing.assert_allclose(y8, y32, rtol=2e-4, atol=2e-4)


def test_ssd_init_state_continuation():
    """Splitting a sequence in two with state carry == one pass."""
    B, S, H, P, N = 1, 16, 2, 4, 4
    x = _arr(B, S, H, P)
    dt = jax.nn.softplus(_arr(B, S, H))
    A = -jnp.abs(_arr(H)) - 0.1
    Bm, Cm = _arr(B, S, N), _arr(B, S, N)
    y_full, h_full = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk=4)
    y1, h1 = ssm.ssd_chunked(x[:, :8], dt[:, :8], A, Bm[:, :8], Cm[:, :8],
                             chunk=4)
    y2, h2 = ssm.ssd_chunked(x[:, 8:], dt[:, 8:], A, Bm[:, 8:], Cm[:, 8:],
                             chunk=4, init_state=h1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h2, h_full, rtol=2e-4, atol=2e-4)


def test_mamba_block_decode_matches_full():
    cfg = smoke_variant(get_config("zamba2-2.7b"))
    sp = ssm.mamba_specs(cfg)
    from repro.core.params import init_tree
    p = init_tree(sp, jax.random.PRNGKey(0))
    ctx = ShardingCtx()
    x = _arr(2, 9, cfg.d_model)
    full, _ = ssm.mamba_block(p, x, cfg, ctx)
    cache = ssm.init_mamba_cache(cfg, 2)
    out, cache = ssm.mamba_block(p, x[:, :8], cfg, ctx, cache=cache)
    step, cache = ssm.mamba_block(p, x[:, 8:9], cfg, ctx, cache=cache)
    np.testing.assert_allclose(step[:, 0], full[:, 8], rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_naive(q, k, v, log_f, log_i):
    """Literal stabilized recurrence (xLSTM paper eqs)."""
    B, S, H, P = q.shape
    qs = q / (P ** 0.5)
    C = jnp.zeros((B, H, P, P))
    n = jnp.zeros((B, H, P))
    m = jnp.full((B, H), -1e30)
    ys = []
    for t in range(S):
        m_new = jnp.maximum(log_f[:, t] + m, log_i[:, t])
        f = jnp.exp(log_f[:, t] + m - m_new)
        i = jnp.exp(log_i[:, t] - m_new)
        C = f[:, :, None, None] * C + i[:, :, None, None] * jnp.einsum(
            "bhp,bhq->bhpq", k[:, t], v[:, t])
        n = f[..., None] * n + i[..., None] * k[:, t]
        num = jnp.einsum("bhp,bhpq->bhq", qs[:, t], C)
        den = jnp.abs(jnp.einsum("bhp,bhp->bh", n, qs[:, t]))
        ys.append(num / jnp.maximum(den, jnp.exp(-m_new))[..., None])
        m = m_new
    return jnp.stack(ys, 1)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_mlstm_chunked_matches_naive(chunk):
    B, S, H, P = 2, 16, 2, 4
    q, k, v = _arr(B, S, H, P), _arr(B, S, H, P), _arr(B, S, H, P)
    log_f = jax.nn.log_sigmoid(_arr(B, S, H) + 2.0)
    log_i = _arr(B, S, H) * 0.5
    want = mlstm_naive(q, k, v, log_f, log_i)
    got, _ = ssm._mlstm_chunk_scan(q, k, v, log_f, log_i, chunk, None)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_mlstm_block_decode_matches_full():
    cfg = smoke_variant(get_config("xlstm-125m"))
    from repro.core.params import init_tree
    p = init_tree(ssm.mlstm_specs(cfg), jax.random.PRNGKey(1))
    ctx = ShardingCtx()
    x = _arr(2, 9, cfg.d_model)
    full, _ = ssm.mlstm_block(p, x, cfg, ctx, chunk=4)
    cache = ssm.init_mlstm_cache(cfg, 2)
    _, cache = ssm.mlstm_block(p, x[:, :8], cfg, ctx, cache=cache, chunk=4)
    step, _ = ssm.mlstm_block(p, x[:, 8:9], cfg, ctx, cache=cache)
    np.testing.assert_allclose(step[:, 0], full[:, 8], rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def test_slstm_decode_matches_full():
    cfg = smoke_variant(get_config("xlstm-125m"))
    from repro.core.params import init_tree
    p = init_tree(ssm.slstm_specs(cfg), jax.random.PRNGKey(2))
    ctx = ShardingCtx()
    x = _arr(2, 9, cfg.d_model)
    full, _ = ssm.slstm_block(p, x, cfg, ctx)
    cache = ssm.init_slstm_cache(cfg, 2)
    _, cache = ssm.slstm_block(p, x[:, :8], cfg, ctx, cache=cache)
    step, _ = ssm.slstm_block(p, x[:, 8:9], cfg, ctx, cache=cache)
    np.testing.assert_allclose(step[:, 0], full[:, 8], rtol=5e-3, atol=5e-3)


def test_slstm_stabilizer_no_overflow():
    """Exponential input gate must not overflow with large preactivations."""
    cfg = smoke_variant(get_config("xlstm-125m"))
    from repro.core.params import init_tree
    p = init_tree(ssm.slstm_specs(cfg), jax.random.PRNGKey(3))
    p = jax.tree.map(lambda a: a * 5.0, p)
    ctx = ShardingCtx()
    out, _ = ssm.slstm_block(p, _arr(1, 32, cfg.d_model) * 10, cfg, ctx)
    assert bool(jnp.isfinite(out).all())
