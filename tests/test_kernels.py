"""Per-kernel allclose sweeps against the ref.py oracles (interpret mode)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.kernels import ops, ref
from repro.kernels.blocked_matmul import blocked_matmul
from repro.kernels.conv2d import conv2d_nhwc
from repro.kernels.flash_attention import flash_attention

RNG = np.random.default_rng(42)


def _arr(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# blocked matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,n,k", [
    (8, 128, 128), (128, 128, 128), (256, 512, 384), (64, 256, 1024),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes_dtypes(m, n, k, dtype):
    a, b = _arr(m, k, dtype=dtype), _arr(k, n, dtype=dtype)
    out = blocked_matmul(a, b, interpret=True)
    want = ref.matmul_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, want, rtol=tol, atol=tol)


@given(st.integers(0, 3), st.integers(0, 3), st.integers(0, 3))
@settings(max_examples=12, deadline=None)
def test_matmul_hypothesis_pow2(i, j, p):
    m, n, k = 8 * 2**i, 128 * 2**j, 128 * 2**p
    a, b = _arr(m, k), _arr(k, n)
    np.testing.assert_allclose(blocked_matmul(a, b, interpret=True),
                               ref.matmul_ref(a, b), rtol=2e-4, atol=2e-4)


def test_matmul_uses_solver_blocking():
    from repro.core.blocking import solve_gemm_blocking
    blk = solve_gemm_blocking(256, 512, 384, vmem_bytes=2 * 2**20)
    a, b = _arr(256, 384), _arr(384, 512)
    out = blocked_matmul(a, b, blocking=blk, interpret=True)
    np.testing.assert_allclose(out, ref.matmul_ref(a, b), rtol=2e-4,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k,stride,pad", [
    (3, 1, 1), (3, 2, 1), (5, 1, 0), (1, 1, 0), (11, 4, 0),
])
def test_conv_kernel_configs(k, stride, pad):
    h = max(k + 3, 12)
    x, w = _arr(2, h, h, 8), _arr(k, k, 8, 16)
    out = conv2d_nhwc(x, w, stride=stride, padding=pad, interpret=True)
    want = ref.conv2d_ref(x, w, stride=stride, padding=pad)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


@given(ifm=st.sampled_from([3, 8, 16]), ofm=st.sampled_from([8, 16, 32]),
       size=st.sampled_from([8, 12, 16]))
@settings(max_examples=10, deadline=None)
def test_conv_hypothesis_channels(ifm, ofm, size):
    x, w = _arr(1, size, size, ifm), _arr(3, 3, ifm, ofm)
    np.testing.assert_allclose(
        conv2d_nhwc(x, w, stride=1, padding=1, interpret=True),
        ref.conv2d_ref(x, w, stride=1, padding=1), rtol=1e-4, atol=1e-4)


def test_conv_channel_blocking_matches():
    x, w = _arr(1, 12, 12, 32), _arr(3, 3, 32, 64)
    out = conv2d_nhwc(x, w, stride=1, padding=1, bifm=8, bofm=16,
                      interpret=True)
    np.testing.assert_allclose(out, ref.conv2d_ref(x, w, 1, 1),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (8, 1)])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (64, 0.0), (0, 30.0),
                                            (128, 50.0)])
def test_flash_attention_features(hq, hkv, window, softcap):
    q = _arr(2, 256, hq, 64)
    k = _arr(2, 256, hkv, 64)
    v = _arr(2, 256, hkv, 64)
    out = flash_attention(q, k, v, causal=True, window=window,
                          logit_softcap=softcap, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=window,
                             logit_softcap=softcap)
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, tol):
    q, k, v = (_arr(1, 128, 4, 128, dtype=dtype) for _ in range(3))
    out = flash_attention(q, k, v, interpret=True)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@given(sq=st.sampled_from([128, 256]), d=st.sampled_from([32, 64, 128]))
@settings(max_examples=8, deadline=None)
def test_flash_attention_hypothesis(sq, d):
    q, k, v = _arr(1, sq, 2, d), _arr(1, sq, 2, d), _arr(1, sq, 2, d)
    np.testing.assert_allclose(
        flash_attention(q, k, v, interpret=True),
        ref.attention_ref(q, k, v), rtol=3e-4, atol=3e-4)


def test_attention_op_gradient_matches_ref():
    q, k, v = _arr(1, 128, 4, 32), _arr(1, 128, 2, 32), _arr(1, 128, 2, 32)
    g = jax.grad(lambda *a: jnp.sum(ops.attention(*a, True, 0, 0.0) ** 2),
                 argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: jnp.sum(ref.attention_ref(*a) ** 2),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_decode_attention_ref_ring_buffer_invariance():
    """Softmax over a set: ring-buffer rotation must not change output."""
    B, C, H, D = 2, 32, 4, 16
    k = _arr(B, C, H, D)
    v = _arr(B, C, H, D)
    q = _arr(B, 1, H, D)
    ln = jnp.full((B,), C, jnp.int32)
    out1 = ref.decode_attention_ref(q, k, v, ln)
    def rot(t):
        return jnp.roll(t, 7, axis=1)
    out2 = ref.decode_attention_ref(q, rot(k), rot(v), ln)
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# §3.4 ring reduce-scatter / all-gather (kernels/ring.py)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 5e-2)])
@given(G=st.sampled_from([1, 2, 3, 4, 8]), n=st.sampled_from([1, 3, 8, 40]))
@settings(max_examples=12, deadline=None)
def test_ring_reduce_scatter_matches_oracle(dtype, tol, G, n):
    from repro.kernels.ring import ring_reduce_scatter
    stacked = _arr(G, G * n, dtype=dtype)
    got = ring_reduce_scatter(stacked, interpret=True)
    want = ref.ring_reduce_scatter_ref(stacked)
    assert got.shape == (G, n) and got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@given(G=st.sampled_from([1, 2, 3, 4, 8]), n=st.sampled_from([1, 3, 8, 40]))
@settings(max_examples=12, deadline=None)
def test_ring_all_gather_matches_oracle(dtype, G, n):
    from repro.kernels.ring import ring_all_gather
    strips = _arr(G, n, dtype=dtype)
    got = ring_all_gather(strips, interpret=True)
    want = ref.ring_all_gather_ref(strips)
    assert got.shape == (G, G * n)
    # pure data movement: must be EXACT in any dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(G=st.sampled_from([2, 4, 8]), n=st.sampled_from([2, 16]))
@settings(max_examples=8, deadline=None)
def test_ring_round_trip_is_allreduce(G, n):
    """all_gather(reduce_scatter(x)) == the replicated full sum on every
    member — the §3.4 part-reduce/part-broadcast identity the ZeRO-1 strip
    update relies on."""
    from repro.kernels.ring import ring_all_gather, ring_reduce_scatter
    stacked = _arr(G, G * n)
    full = ring_all_gather(ring_reduce_scatter(stacked, interpret=True),
                           interpret=True)
    want = np.broadcast_to(np.asarray(stacked).sum(axis=0), (G, G * n))
    np.testing.assert_allclose(np.asarray(full), want, rtol=1e-5, atol=1e-5)


def test_ring_reduce_scatter_rejects_ragged_buffer():
    from repro.kernels.ring import ring_reduce_scatter
    with pytest.raises(ValueError):
        ring_reduce_scatter(_arr(3, 10), interpret=True)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ring_hop_accum_matches_jnp(dtype):
    """The distributed backend's per-hop combine: recv + chunks[c] for every
    valid (traced) chunk index."""
    from repro.kernels.ring import ring_hop_accum
    G, n = 4, 24
    chunks = _arr(G, n, dtype=dtype)
    recv = _arr(n, dtype=dtype)
    for c in range(G):
        got = ring_hop_accum(chunks, recv, jnp.int32(c), interpret=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(recv + chunks[c], np.float32), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# paged decode attention (kernels/paged_attn.py, scalar-prefetch page gather)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("window,softcap", [
    (0, 0.0), (6, 0.0), (0, 30.0), (5, 50.0),
])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_paged_decode_matches_oracle(window, softcap, dtype, tol):
    from repro.kernels.paged_attn import paged_decode_attention
    B, n, ps, Hq, Hkv, D, P = 3, 5, 4, 8, 2, 16, 20
    pages_k = _arr(P, ps, Hkv, D, dtype=dtype)
    pages_v = _arr(P, ps, Hkv, D, dtype=dtype)
    q = _arr(B, Hq, D, dtype=dtype)
    # non-contiguous layout: each request's logical pages scattered over the
    # physical pool (never page 0, the null page)
    pt = jnp.asarray(RNG.permutation(P - 1)[:B * n].reshape(B, n) + 1,
                     jnp.int32)
    lengths = jnp.asarray([1, 9, n * ps], jnp.int32)   # edge: 1 and full
    got = paged_decode_attention(q, pages_k, pages_v, pt, lengths,
                                 window=window, logit_softcap=softcap,
                                 interpret=True)
    want = ref.paged_decode_attention_ref(q, pages_k, pages_v, pt, lengths,
                                          window=window,
                                          logit_softcap=softcap)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_paged_ref_matches_dense_decode_ref():
    """Identity page layout: the paged oracle must agree with the dense
    ring-buffer decode oracle (same math, different cache addressing)."""
    B, C, Hq, Hkv, D, ps = 2, 32, 4, 2, 16, 8
    n = C // ps
    P = 1 + B * n
    pages_k, pages_v = _arr(P, ps, Hkv, D), _arr(P, ps, Hkv, D)
    q = _arr(B, Hq, D)
    pt = jnp.arange(1, P, dtype=jnp.int32).reshape(B, n)
    lengths = jnp.asarray([5, C], jnp.int32)
    dense_k = pages_k[1:].reshape(B, C, Hkv, D)
    dense_v = pages_v[1:].reshape(B, C, Hkv, D)
    paged = ref.paged_decode_attention_ref(q, pages_k, pages_v, pt, lengths)
    dense = ref.decode_attention_ref(q[:, None], dense_k, dense_v, lengths)
    np.testing.assert_allclose(paged, dense[:, 0], rtol=1e-5, atol=1e-5)
