"""Serving engine tests.

Four layers, matching the ServeSpec -> compile_serve stack:

- analytic cache budgets (``cache_bytes`` / ``paged_cache_bytes``) pinned to
  the ACTUAL buffer sizes ``init_caches`` / ``init_paged_caches`` allocate,
  across every block kind the registry covers;
- the host-side :class:`PagedKVCache` free-list allocator;
- paged decode logits == dense ring-buffer decode logits, token by token,
  for both the gather and the Pallas kernel impl;
- the full Server against ``generate``: continuous batching (through
  preemption churn) and the static policy must reproduce the dense greedy
  tokens exactly, plus ServeSpec/admission validation.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import ServeSpec, compile_serve
from repro.configs import get_config, smoke_variant
from repro.core.sharding import ShardingCtx
from repro.models import layers, transformer
from repro.serve.decode import generate
from repro.serve.kvcache import PagedKVCache, cache_bytes, paged_cache_bytes

RNG = np.random.default_rng(7)
CTX = ShardingCtx()


def _float_bytes(tree):
    """Bytes across float leaves (the data buffers; int bookkeeping like
    ring positions / page tables is excluded on both sides)."""
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree)
               if jnp.issubdtype(l.dtype, jnp.floating))


# ---------------------------------------------------------------------------
# analytic budgets == actual buffers
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", [
    "llama3-8b",       # global attention
    "gemma2-2b",       # local/global interleave
    "zamba2-2.7b",     # mamba + shared attention
    "xlstm-125m",      # mlstm/slstm
    "h2o-danube-3-4b",
])
@pytest.mark.parametrize("batch,ctx_len", [(2, 64), (3, 160)])
def test_cache_bytes_matches_init_caches(arch, batch, ctx_len):
    cfg = smoke_variant(get_config(arch))
    caches = transformer.init_caches(cfg, batch, ctx_len)
    assert cache_bytes(cfg, batch, ctx_len) == _float_bytes(caches)


@pytest.mark.parametrize("num_pages,page_size", [(8, 4), (32, 16)])
def test_paged_cache_bytes_matches_init_paged_caches(num_pages, page_size):
    cfg = smoke_variant(get_config("llama3-8b"))
    caches = transformer.init_paged_caches(cfg, 2, num_pages, page_size,
                                           pages_per_req=4)
    assert paged_cache_bytes(cfg, num_pages, page_size) == _float_bytes(caches)


def test_init_paged_caches_rejects_ssm_blocks():
    cfg = smoke_variant(get_config("zamba2-2.7b"))
    with pytest.raises(ValueError, match="attention blocks only"):
        transformer.init_paged_caches(cfg, 2, 8, 4, pages_per_req=2)


# ---------------------------------------------------------------------------
# PagedKVCache free-list allocator
# ---------------------------------------------------------------------------
def test_allocator_reserves_null_page():
    a = PagedKVCache(num_pages=8, page_size=4)
    assert a.n_free == 7                       # page 0 never handed out
    got = a.alloc(rid=1, n=7)
    assert 0 not in got and sorted(got) == list(range(1, 8))


def test_allocator_all_or_nothing_and_free():
    a = PagedKVCache(num_pages=6, page_size=4)
    assert a.alloc(1, 3) is not None
    assert a.alloc(2, 3) is None               # only 2 left: nothing taken
    assert a.n_free == 2 and a.n_owned(2) == 0
    assert a.free(1) == 3
    assert a.alloc(2, 3) is not None


def test_allocator_ensure_grows_idempotently():
    a = PagedKVCache(num_pages=8, page_size=2)
    assert a.ensure(5, 2) and a.n_owned(5) == 2
    assert a.ensure(5, 2) and a.n_owned(5) == 2    # no-op
    assert a.ensure(5, 5) and a.n_owned(5) == 5
    assert not a.ensure(5, 99) and a.n_owned(5) == 5
    assert a.pages_for(1) == 1 and a.pages_for(2) == 1 and a.pages_for(3) == 2


def test_allocator_page_row_pads_with_null():
    a = PagedKVCache(num_pages=8, page_size=4)
    got = a.alloc(3, 2)
    row = a.page_row(3, width=5)
    assert row.tolist() == got + [0, 0, 0]
    assert a.page_row(42, width=3).tolist() == [0, 0, 0]   # unknown rid


# ---------------------------------------------------------------------------
# ServeSpec validation / compile_serve arch gating
# ---------------------------------------------------------------------------
def test_servespec_validates():
    with pytest.raises(ValueError, match="scheduler"):
        ServeSpec(arch="llama3-8b", scheduler="fifo")
    with pytest.raises(ValueError, match="attn_impl"):
        ServeSpec(arch="llama3-8b", attn_impl="cuda")
    with pytest.raises(ValueError, match="num_pages"):
        ServeSpec(arch="llama3-8b", num_pages=4, max_prompt=64,
                  max_new_tokens=64, page_size=16)
    spec = ServeSpec(arch="llama3-8b", max_prompt=60, max_new_tokens=5,
                     page_size=16)
    assert spec.max_context == 65 and spec.pages_per_request == 5


@pytest.mark.parametrize("arch,why", [
    ("xlstm-125m", "attention blocks only"),    # slstm/mlstm pattern
    ("zamba2-2.7b", "attention blocks only"),   # mamba hybrid
    ("musicgen-medium", "codebook"),            # codebook heads
    ("qwen2-vl-2b", "M-RoPE"),                  # vision frontend + mrope
    ("vgg-a", "ModelConfig"),                   # CNN family
])
def test_compile_serve_rejects_unservable_archs(arch, why):
    with pytest.raises(ValueError, match=why):
        compile_serve(ServeSpec(arch=arch, smoke=True))


# ---------------------------------------------------------------------------
# paged decode == dense ring decode, token by token
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["llama3-8b", "gemma2-2b"])
@pytest.mark.parametrize("impl", ["gather", "pallas"])
def test_paged_forward_logits_match_dense(arch, impl):
    cfg = smoke_variant(get_config(arch))
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B, T, ps, n = 2, 6, 4, 2                     # n*ps = 8 >= T
    toks = jnp.asarray(RNG.integers(1, cfg.vocab_size, size=(B, T)),
                       jnp.int32)
    num_pages = 1 + B * n
    dense = transformer.init_caches(cfg, B, T)
    paged = transformer.init_paged_caches(cfg, B, num_pages, ps, n, impl=impl)
    pt = jnp.arange(1, num_pages, dtype=jnp.int32).reshape(B, n)
    R = cfg.pattern_repeats
    pt_s = jnp.broadcast_to(pt[None], (R, B, n))

    for t in range(T):
        pos = jnp.full((B, 1), t, jnp.int32)
        ld, _, dense = transformer.forward(
            params, cfg, CTX, tokens=toks[:, t:t + 1], positions=pos,
            caches=dense)
        len_s = jnp.full((R, B), t, jnp.int32)
        paged = tuple(
            layers.PagedKVState(c.pages_k, c.pages_v, pt_s, len_s, impl)
            for c in paged)
        lp, _, paged = transformer.forward(
            params, cfg, CTX, tokens=toks[:, t:t + 1], positions=pos,
            caches=paged)
        np.testing.assert_allclose(lp, ld, rtol=1e-5, atol=1e-5,
                                   err_msg=f"{arch}/{impl} step {t}")


# ---------------------------------------------------------------------------
# Server end-to-end vs dense generate
# ---------------------------------------------------------------------------
def _drain_and_compare(spec, n_req, max_new):
    srv = compile_serve(spec)
    V = srv.cfg.vocab_size
    prompts = [RNG.integers(1, V, size=int(L)).astype(np.int32)
               for L in RNG.integers(2, spec.max_prompt + 1, size=n_req)]
    rids = [srv.submit(p, max_new) for p in prompts]
    done = {r.rid: r for r in srv.drain()}
    assert len(done) == n_req
    for rid, p in zip(rids, prompts):
        ref = np.asarray(generate(srv.params, srv.cfg, CTX,
                                  p[None], max_new))[0]
        np.testing.assert_array_equal(done[rid].output, ref)
    return srv


def test_server_continuous_with_preemption_matches_generate():
    # 5 usable pages, up to 5 pages/request, 3 slots: forces preemptions
    srv = _drain_and_compare(
        ServeSpec(arch="llama3-8b", smoke=True, max_batch=3, page_size=4,
                  num_pages=6, max_prompt=10, max_new_tokens=8),
        n_req=5, max_new=5)
    assert srv.stats["completed"] == 5
    assert srv.alloc.n_free == srv.spec.num_pages - 1   # all pages returned


def test_server_static_policy_matches_generate():
    srv = _drain_and_compare(
        ServeSpec(arch="llama3-8b", smoke=True, max_batch=2, page_size=4,
                  num_pages=32, max_prompt=10, max_new_tokens=8,
                  scheduler="static"),
        n_req=4, max_new=4)
    assert srv.stats["preemptions"] == 0


def test_server_admission_control():
    srv = compile_serve(ServeSpec(arch="llama3-8b", smoke=True, max_queue=2,
                                  max_prompt=8, max_new_tokens=4))
    with pytest.raises(ValueError, match="prompt length"):
        srv.submit(np.ones(9, np.int32))
    with pytest.raises(ValueError, match="max_new_tokens"):
        srv.submit(np.ones(4, np.int32), 5)
    srv.submit(np.ones(4, np.int32))
    srv.submit(np.ones(4, np.int32))
    with pytest.raises(RuntimeError, match="max_queue"):
        srv.submit(np.ones(4, np.int32))
