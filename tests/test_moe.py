"""MoE routing/dispatch tests."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core.params import init_tree
from repro.core.sharding import ShardingCtx
from repro.models import moe

RNG = np.random.default_rng(5)


def _cfg():
    return smoke_variant(get_config("mixtral-8x22b"))  # E=4, k=2, dropless


def _params(cfg, seed=0):
    return init_tree(moe.moe_specs(cfg), jax.random.PRNGKey(seed))


def moe_dense_reference(p, x, cfg):
    """Dense reference: every token through its top-k experts, no capacity."""
    from repro.models.layers import rms_norm
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    logits = h.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    w = w / w.sum(-1, keepdims=True)
    # run every expert densely, then combine
    g = jax.nn.silu(jnp.einsum("bsd,edf->bsef", h, p["w_gate"]))
    u = jnp.einsum("bsd,edf->bsef", h, p["w_up"])
    ye = jnp.einsum("bsef,efd->bsed", g * u, p["w_down"])   # (B,S,E,d)
    onehot = jax.nn.one_hot(idx, cfg.num_experts)            # (B,S,k,E)
    comb = jnp.einsum("bske,bsk,bsed->bsd", onehot, w, ye)
    return x + comb


def test_dispatch_matches_dense_reference():
    cfg = _cfg()
    p = _params(cfg)
    x = jnp.asarray(RNG.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    got, aux = moe.moe_block(p, x, cfg, ShardingCtx())
    want = moe_dense_reference(p, x, cfg)
    np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)


def test_capacity_drops_tokens():
    """With capacity_factor << 1 outputs must differ from dropless (tokens
    actually get dropped) but stay finite."""
    cfg = _cfg()
    tight = cfg.replace(moe_capacity_factor=0.25)
    p = _params(cfg)
    x = jnp.asarray(RNG.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    full, _ = moe.moe_block(p, x, cfg, ShardingCtx())
    dropped, _ = moe.moe_block(p, x, tight, ShardingCtx())
    assert bool(jnp.isfinite(dropped).all())
    assert float(jnp.max(jnp.abs(full - dropped))) > 1e-6


def test_aux_loss_balanced_lower_bound():
    """Switch aux loss: E * sum f_e p_e >= 1 with equality iff balanced."""
    cfg = _cfg()
    p = _params(cfg)
    x = jnp.asarray(RNG.normal(size=(4, 32, cfg.d_model)), jnp.float32)
    _, aux = moe.moe_block(p, x, cfg, ShardingCtx())
    # aux is scaled by router_aux_loss_coef
    raw = float(aux) / cfg.router_aux_loss_coef
    assert raw >= 0.95, raw


def test_shared_experts_path():
    cfg = smoke_variant(get_config("qwen2-moe-a2.7b"))
    assert cfg.num_shared_experts >= 1
    p = _params(cfg, seed=3)
    x = jnp.asarray(RNG.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    out, aux = moe.moe_block(p, x, cfg, ShardingCtx())
    assert out.shape == x.shape and bool(jnp.isfinite(out).all())


def test_decode_gather_path_matches_train_path():
    """S==1 weight-gather path == capacity path (dropless config)."""
    cfg = _cfg()
    p = _params(cfg, seed=4)
    x = jnp.asarray(RNG.normal(size=(3, 1, cfg.d_model)), jnp.float32)
    dec, _ = moe.moe_block(p, x, cfg, ShardingCtx())
    # trick: run train path by reshaping to sequence on batch 1... instead
    # compare against the dense reference
    want = moe_dense_reference(p, x, cfg)
    np.testing.assert_allclose(dec, want, rtol=3e-3, atol=3e-3)


def test_moe_gradients_flow_to_router_and_experts():
    cfg = _cfg()
    p = _params(cfg, seed=5)
    x = jnp.asarray(RNG.normal(size=(2, 8, cfg.d_model)), jnp.float32)

    def loss(p):
        out, aux = moe.moe_block(p, x, cfg, ShardingCtx())
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["w_gate"]).max()) > 0


def test_chunked_loss_equals_plain():
    import jax

    from repro.configs import get_config, smoke_variant
    from repro.models import transformer
    cfg = smoke_variant(get_config("qwen2-moe-a2.7b"))
    p = jax.tree.map(lambda a: a, transformer.init_params(
        cfg, jax.random.PRNGKey(0)))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0,
                                          cfg.vocab_size)}
    from repro.core.sharding import ShardingCtx
    l0 = transformer.lm_loss(p, cfg, ShardingCtx(), batch)
    l1 = transformer.lm_loss(p, cfg.replace(loss_chunk=4), ShardingCtx(),
                             batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


def test_expert_pad_preserves_semantics():
    """Padded (dummy) experts never receive tokens -> identical output."""
    import jax.numpy as jnp

    from repro.configs import get_config, smoke_variant
    from repro.core.sharding import ShardingCtx
    from repro.models import transformer
    cfg = smoke_variant(get_config("qwen2-moe-a2.7b"))
    p = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                                          cfg.vocab_size)}
    l0 = transformer.lm_loss(p, cfg, ShardingCtx(), batch)

    def pad_fix(path, a):
        ks = jax.tree_util.keystr(path)
        if any(w in ks for w in ["w_gate", "w_up", "w_down"]):
            return jnp.pad(a, [(0, 0), (0, 2)] + [(0, 0)] * (a.ndim - 2))
        return a

    pp = jax.tree_util.tree_map_with_path(pad_fix, p)
    l2 = transformer.lm_loss(pp, cfg.replace(moe_expert_pad=2),
                             ShardingCtx(), batch)
    np.testing.assert_allclose(float(l0), float(l2), rtol=1e-5)
