"""Layer-level tests: RoPE/M-RoPE, chunked attention, norms, MLP."""
import numpy as np
import pytest

import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.kernels import ref
from repro.models import layers

RNG = np.random.default_rng(11)


def _arr(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def test_rope_relative_position_property():
    """q_i . k_j after RoPE depends only on (i - j)."""
    D = 64
    q = _arr(1, 1, 1, D)
    k = _arr(1, 1, 1, D)

    def dot_at(pi, pj):
        qr = layers.apply_rope(q, jnp.full((1, 1), pi))
        kr = layers.apply_rope(k, jnp.full((1, 1), pj))
        return float(jnp.sum(qr * kr))

    assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), rel=1e-4)
    assert dot_at(17, 0) == pytest.approx(dot_at(1017, 1000), rel=1e-4)


def test_rope_norm_preserving():
    x = _arr(2, 8, 4, 64)
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    y = layers.apply_rope(x, pos)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)


def test_mrope_equals_rope_when_positions_equal():
    """If all 3 position components coincide (text tokens), M-RoPE must
    reduce to plain RoPE."""
    D = 64
    x = _arr(1, 6, 2, D)
    pos1 = jnp.broadcast_to(jnp.arange(6), (1, 6))
    pos3 = jnp.repeat(pos1[..., None], 3, axis=-1)
    got = layers.apply_mrope(x, pos3, (8, 12, 12), 10000.0)
    want = layers.apply_rope(x, pos1, 10000.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# chunked attention == oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("window,softcap,gqa", [
    (0, 0.0, 1), (32, 0.0, 2), (0, 50.0, 4), (64, 30.0, 2),
])
def test_chunked_attention_vs_ref(window, softcap, gqa):
    hq, hkv = 4, 4 // gqa
    q, k, v = _arr(2, 128, hq, 32), _arr(2, 128, hkv, 32), _arr(2, 128, hkv, 32)
    got = layers.chunked_attention(q, k, v, causal=True, window=window,
                                   logit_softcap=softcap, chunk=32)
    want = ref.attention_ref(q, k, v, causal=True, window=window,
                             logit_softcap=softcap)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@given(chunk=st.sampled_from([16, 32, 64, 128]))
@settings(max_examples=8, deadline=None)
def test_chunked_attention_chunk_invariance(chunk):
    q, k, v = _arr(1, 128, 2, 32), _arr(1, 128, 2, 32), _arr(1, 128, 2, 32)
    got = layers.chunked_attention(q, k, v, chunk=chunk)
    want = layers.chunked_attention(q, k, v, chunk=128)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_chunked_attention_right_aligned_decode_window():
    """Sq < Skv (queries right-aligned): last query attends to the last
    `window` keys only."""
    q = _arr(1, 1, 1, 16)
    k, v = _arr(1, 64, 1, 16), _arr(1, 64, 1, 16)
    got = layers.chunked_attention(q, k, v, causal=True, window=8, chunk=16)
    want = ref.attention_ref(q, k, v, causal=True, window=8)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def test_rms_norm_scale_invariance():
    x = _arr(2, 4, 16)
    w = jnp.zeros((16,))
    y1 = layers.rms_norm(x, w)
    y2 = layers.rms_norm(x * 100.0, w)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)


def test_rms_norm_unit_rms():
    x = _arr(2, 4, 256)
    y = layers.rms_norm(x, jnp.zeros((256,)))
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(rms, jnp.ones_like(rms), rtol=1e-3)
