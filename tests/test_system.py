"""End-to-end behaviour tests for the reproduction framework."""
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core.sharding import ShardingCtx
from repro.data import Prefetcher, stream_for
from repro.models import cnn, transformer
from repro.optim import AdamW, MomentumSGD
from repro.optim.schedule import constant, warmup_cosine
from repro.serve import generate
from repro.train import Trainer, TrainerConfig, make_train_step

CTX = ShardingCtx()
KEY = jax.random.PRNGKey(0)


def test_lm_training_loss_decreases():
    cfg = smoke_variant(get_config("gemma-2b"))
    params = transformer.init_params(cfg, KEY)
    opt = AdamW(weight_decay=0.01)
    step = make_train_step(
        lambda p, b: transformer.lm_loss(p, cfg, CTX, b), opt,
        constant(3e-3))
    src = Prefetcher(stream_for(cfg, 8, 64))
    trainer = Trainer(step, TrainerConfig(total_steps=25, log_every=5))
    params, _, hist = trainer.fit(params, opt.init(params), src,
                                  log_fn=lambda *_: None)
    src.close()
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7


def test_cnn_training_loss_decreases():
    """The paper's own workload family end-to-end (reduced VGG)."""
    cfg = smoke_variant(get_config("vgg-a"))
    params = cnn.init_params(cfg, KEY)
    opt = MomentumSGD(momentum=0.9)   # the paper's optimizer
    step = make_train_step(lambda p, b: cnn.loss_fn(p, cfg, b), opt,
                           constant(5e-3))
    src = Prefetcher(stream_for(cfg, 8, 0))
    trainer = Trainer(step, TrainerConfig(total_steps=30, log_every=10))
    params, _, hist = trainer.fit(params, opt.init(params), src,
                                  log_fn=lambda *_: None)
    src.close()
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_generate_greedy_deterministic():
    cfg = smoke_variant(get_config("llama3-8b"))
    params = transformer.init_params(cfg, KEY)
    prompt = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    a = generate(params, cfg, CTX, prompt, 6, temperature=0.0)
    b = generate(params, cfg, CTX, prompt, 6, temperature=0.0)
    assert a.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_generate_matches_full_forward_argmax():
    """First generated token == argmax of the full-forward next-token
    distribution (serving path equals training path)."""
    cfg = smoke_variant(get_config("h2o-danube-3-4b"))
    params = transformer.init_params(cfg, KEY)
    prompt = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    logits, _, _ = transformer.forward(params, cfg, CTX, tokens=prompt)
    want = jnp.argmax(logits[:, -1], -1)
    out = generate(params, cfg, CTX, prompt, 1, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(want))


def test_checkpoint_roundtrip_with_opt_state():
    from repro.checkpoint import latest_step, restore, save
    cfg = smoke_variant(get_config("xlstm-125m"))
    params = transformer.init_params(cfg, KEY)
    opt = AdamW()
    state = opt.init(params)
    with tempfile.TemporaryDirectory() as d:
        save(d, 7, params=params, opt_state=state)
        assert latest_step(d) == 7
        out, step = restore(d, 7, params=params, opt_state=state)
        assert step == 7
        for a, b in zip(jax.tree.leaves(out["params"]),
                        jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(out["opt_state"]),
                        jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prefetcher_matches_direct_iteration():
    cfg = smoke_variant(get_config("gemma-2b"))
    direct = [next(stream_for(cfg, 2, 16, seed=3))["tokens"]
              for _ in range(1)]
    pf = Prefetcher(stream_for(cfg, 2, 16, seed=3))
    got = next(pf)["tokens"]
    pf.close()
    np.testing.assert_array_equal(np.asarray(got), direct[0])


def test_warmup_cosine_schedule_shape():
    sched = warmup_cosine(1e-3, 10, 100)
    assert float(sched(0)) == 0.0
    assert float(sched(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(sched(100)) == pytest.approx(1e-4, rel=1e-2)
    assert float(sched(55)) < float(sched(10))


def test_delay_pattern_property():
    from repro.models.frontends import delay_pattern
    toks = jnp.arange(2 * 8 * 4).reshape(2, 8, 4)
    d = delay_pattern(toks, 4)
    # codebook k shifted right by k
    np.testing.assert_array_equal(np.asarray(d[:, :, 0]),
                                  np.asarray(toks[:, :, 0]))
    np.testing.assert_array_equal(np.asarray(d[:, 1:, 1]),
                                  np.asarray(toks[:, :7, 1]))
    np.testing.assert_array_equal(np.asarray(d[:, 3:, 3]),
                                  np.asarray(toks[:, :5, 3]))


def test_param_counts_match_published():
    cases = {
        "gemma2-2b": (2.2e9, 3.0e9),
        "llama3-8b": (7.5e9, 8.5e9),
        "mixtral-8x22b": (1.30e11, 1.50e11),
        "qwen2-moe-a2.7b": (1.3e10, 1.5e10),
        "xlstm-125m": (0.8e8, 1.6e8),
    }
    for arch, (lo, hi) in cases.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)


def test_active_params_moe():
    cfg = get_config("mixtral-8x22b")
    act = cfg.param_count(active_only=True)
    assert 3.4e10 < act < 4.5e10   # ~39B active


def test_kvcache_accounting_matches_init_caches():
    """serve/kvcache analytic bytes == actual init_caches allocation."""
    from repro.serve import kvcache
    for arch in ("gemma2-2b", "zamba2-2.7b", "xlstm-125m"):
        cfg = smoke_variant(get_config(arch))
        caches = transformer.init_caches(cfg, 2, 64)
        actual = sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(caches)
                     if hasattr(x, "dtype") and x.ndim > 1)
        analytic = kvcache.cache_bytes(cfg, 2, 64)
        assert abs(actual - analytic) / max(actual, 1) < 0.05, (
            arch, actual, analytic)


def test_train_launcher_smoke():
    """the CLI training launcher end-to-end (reduced arch, few steps)."""
    from repro.launch import train as train_launcher
    hist = train_launcher.main([
        "--arch", "gemma-2b", "--smoke", "--steps", "6", "--batch", "4",
        "--seq", "32"])
    assert len(hist) >= 1
    assert all(h["loss"] == h["loss"] for h in hist)  # finite


def test_serve_launcher_smoke():
    from repro.launch import serve as serve_launcher
    out = serve_launcher.main([
        "--arch", "llama3-8b", "--batch", "2", "--prompt-len", "8",
        "--new", "4"])
    assert out.shape == (2, 4)
