"""End-to-end behaviour tests for the reproduction framework."""
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core.sharding import ShardingCtx
from repro.data import Prefetcher, stream_for
from repro.models import cnn, transformer
from repro.optim import AdamW, MomentumSGD
from repro.optim.schedule import constant, warmup_cosine
from repro.serve import generate
from repro.train import Trainer, TrainerConfig, make_train_step

CTX = ShardingCtx()
KEY = jax.random.PRNGKey(0)


def test_lm_training_loss_decreases():
    cfg = smoke_variant(get_config("gemma-2b"))
    params = transformer.init_params(cfg, KEY)
    opt = AdamW(weight_decay=0.01)
    step = make_train_step(
        lambda p, b: transformer.lm_loss(p, cfg, CTX, b), opt,
        constant(3e-3))
    src = Prefetcher(stream_for(cfg, 8, 64))
    trainer = Trainer(step, TrainerConfig(total_steps=25, log_every=5))
    params, _, hist = trainer.fit(params, opt.init(params), src,
                                  log_fn=lambda *_: None)
    src.close()
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7


def test_cnn_training_loss_decreases():
    """The paper's own workload family end-to-end (reduced VGG)."""
    cfg = smoke_variant(get_config("vgg-a"))
    params = cnn.init_params(cfg, KEY)
    opt = MomentumSGD(momentum=0.9)   # the paper's optimizer
    step = make_train_step(lambda p, b: cnn.loss_fn(p, cfg, b), opt,
                           constant(5e-3))
    src = Prefetcher(stream_for(cfg, 8, 0))
    trainer = Trainer(step, TrainerConfig(total_steps=30, log_every=10))
    params, _, hist = trainer.fit(params, opt.init(params), src,
                                  log_fn=lambda *_: None)
    src.close()
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_generate_greedy_deterministic():
    cfg = smoke_variant(get_config("llama3-8b"))
    params = transformer.init_params(cfg, KEY)
    prompt = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    a = generate(params, cfg, CTX, prompt, 6, temperature=0.0)
    b = generate(params, cfg, CTX, prompt, 6, temperature=0.0)
    assert a.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_generate_matches_full_forward_argmax():
    """First generated token == argmax of the full-forward next-token
    distribution (serving path equals training path)."""
    cfg = smoke_variant(get_config("h2o-danube-3-4b"))
    params = transformer.init_params(cfg, KEY)
    prompt = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    logits, _, _ = transformer.forward(params, cfg, CTX, tokens=prompt)
    want = jnp.argmax(logits[:, -1], -1)
    out = generate(params, cfg, CTX, prompt, 1, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(want))


def test_checkpoint_roundtrip_with_opt_state():
    from repro.checkpoint import latest_step, restore, save
    cfg = smoke_variant(get_config("xlstm-125m"))
    params = transformer.init_params(cfg, KEY)
    opt = AdamW()
    state = opt.init(params)
    with tempfile.TemporaryDirectory() as d:
        save(d, 7, params=params, opt_state=state)
        assert latest_step(d) == 7
        out, step = restore(d, 7, params=params, opt_state=state)
        assert step == 7
        for a, b in zip(jax.tree.leaves(out["params"]),
                        jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(out["opt_state"]),
                        jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prefetcher_matches_direct_iteration():
    cfg = smoke_variant(get_config("gemma-2b"))
    direct = [next(stream_for(cfg, 2, 16, seed=3))["tokens"]
              for _ in range(1)]
    pf = Prefetcher(stream_for(cfg, 2, 16, seed=3))
    got = next(pf)["tokens"]
    pf.close()
    np.testing.assert_array_equal(np.asarray(got), direct[0])


def test_prefetcher_finite_source_raises_stop_iteration():
    """A finite/exhausted source must end iteration, not block forever."""
    batches = [{"x": np.zeros((2,), np.float32)} for _ in range(3)]
    pf = Prefetcher(iter(batches))
    got = [next(pf) for _ in range(3)]
    assert len(got) == 3
    with pytest.raises(StopIteration):
        next(pf)
    with pytest.raises(StopIteration):     # and keeps raising
        next(pf)
    pf.close()
    assert not pf._t.is_alive()


def test_prefetcher_close_joins_worker():
    """close() must actually join the worker thread, including one blocked
    on a full queue (infinite source, consumer gone)."""
    def infinite():
        i = 0
        while True:
            yield {"x": np.full((1,), i, np.float32)}
            i += 1
    pf = Prefetcher(infinite(), depth=2)
    next(pf)
    pf.close()
    assert not pf._t.is_alive()


def test_prefetcher_propagates_source_errors():
    """A crashed pipeline must surface its exception, not masquerade as
    clean exhaustion (which the trainer treats as normal end-of-data)."""
    def bad_source():
        yield {"x": np.zeros((2,), np.float32)}
        raise OSError("corrupt shard")
    pf = Prefetcher(bad_source())
    next(pf)
    with pytest.raises(OSError, match="corrupt shard"):
        next(pf)
    with pytest.raises(OSError):       # and keeps raising
        next(pf)
    pf.close()
    assert not pf._t.is_alive()


def test_restore_validates_shape_dtype_and_missing_leaves():
    """restore() raises real exceptions (not asserts, which vanish under
    python -O): shape mismatch, dtype mismatch, missing leaf, missing file."""
    from repro.checkpoint import restore, save
    tree = {"w": jnp.ones((3, 2), jnp.float32),
            "n": jnp.zeros((), jnp.int32)}
    with tempfile.TemporaryDirectory() as d:
        save(d, 3, params=tree)
        out, _ = restore(d, 3, params=tree)
        assert out["params"]["w"].dtype == jnp.float32
        with pytest.raises(ValueError, match="shape"):
            restore(d, 3, params={"w": jnp.ones((2, 3), jnp.float32),
                                  "n": tree["n"]})
        with pytest.raises(ValueError, match="dtype"):
            restore(d, 3, params={"w": jnp.ones((3, 2), jnp.bfloat16),
                                  "n": tree["n"]})
        with pytest.raises(KeyError, match="extra"):
            restore(d, 3, params=dict(tree, extra=jnp.zeros((1,))))
        with pytest.raises(FileNotFoundError):
            restore(d, 4, params=tree)


def test_trainer_stops_cleanly_when_data_exhausted():
    """A finite source shorter than total_steps must END training with the
    accumulated params/history, not leak StopIteration out of fit()."""
    params = {"w": jnp.ones((4,), jnp.float32)}
    w0 = np.asarray(params["w"]).copy()    # fit() donates the input buffers
    opt = MomentumSGD()

    def loss(p, b):
        return jnp.sum((p["w"] - b["x"]) ** 2)

    step = make_train_step(loss, opt, constant(1e-2))
    src = Prefetcher(iter([{"x": np.full((4,), i, np.float32)}
                           for i in range(3)]))
    lines = []
    trainer = Trainer(step, TrainerConfig(total_steps=10, log_every=1))
    out_params, _, hist = trainer.fit(params, opt.init(params), src,
                                      log_fn=lines.append)
    src.close()
    assert [h["step"] for h in hist] == [1, 2, 3]
    assert any("data exhausted at step 3" in ln for ln in lines)
    assert not np.allclose(np.asarray(out_params["w"]), w0)


def test_run_refit_resume_realigns_data_stream():
    """Calling fit() again on the SAME Run must resume on the right batches:
    the cached prefetcher has already advanced, so resume restarts the
    seeded stream before fast-forwarding (else steps 4..5 would silently
    retrain on batches ~10..11)."""
    from repro.api import RunSpec, compile_run

    def quiet(*_):
        return None

    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        spec = RunSpec(arch="cd-dnn", smoke=True, steps=6, batch=4, seq=0,
                       lr=1e-3, schedule="constant", log_every=1,
                       ckpt_every=4, ckpt_dir=d1)
        ra = compile_run(spec)
        ra.fit(log_fn=quiet)           # 0..5, checkpoint lands at step 4
        lines = []
        ha = ra.fit(log_fn=lines.append)   # auto-resume at 4, retrain 4..5
        assert [h["step"] for h in ha] == [5, 6]
        # warm re-fit: jit_step already executed, so no bogus 'compile 0.0s'
        assert not any("compile" in str(ln) for ln in lines), lines
        ra.close()
        rb = compile_run(spec.replace(ckpt_dir=d2))
        rb.fit(log_fn=quiet)
        rb.close()
        for a, b in zip(jax.tree.leaves(ra.params),
                        jax.tree.leaves(rb.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-7)


def test_trainer_reports_compile_time_separately():
    """The first (jit-compiling) step must not pollute items/s: its log line
    carries the compile time instead of a rate."""
    cfg = smoke_variant(get_config("cd-dnn"))
    from repro.models import dnn
    params = dnn.init_params(cfg, KEY)
    opt = MomentumSGD()
    step = make_train_step(lambda p, b: dnn.loss_fn(p, cfg, b), opt,
                           constant(1e-3))
    src = Prefetcher(stream_for(cfg, 4, 0))
    lines = []
    trainer = Trainer(step, TrainerConfig(total_steps=6, log_every=2))
    _, _, hist = trainer.fit(params, opt.init(params), src,
                             log_fn=lines.append)
    src.close()
    assert "compile" in lines[0] and "/s" not in lines[0]
    assert all("compile" not in ln and "samples/s" in ln
               for ln in lines[1:])
    assert hist[-1]["step"] == 6


def test_run_step_and_fit_share_one_donated_jit():
    """Run.step and Run.fit must hit ONE compile cache (the old per-call
    jax.jit(train_step) re-traced and, without donate_argnums, kept a second
    copy of the params alive)."""
    from repro.api import RunSpec, compile_run
    run = compile_run(RunSpec(arch="cd-dnn", smoke=True, steps=2, batch=4,
                              seq=0, log_every=10))
    traces = 0
    orig = run.train_step
    def counting(*args):
        nonlocal traces
        traces += 1
        return orig(*args)
    run.train_step = counting
    run.fit(log_fn=lambda *_: None)           # compiles once
    batch = next(run.data)
    old_params_leaf = jax.tree.leaves(run.params)[0]
    run.step(batch, step_idx=2)               # same cache: no retrace
    run.close()
    assert traces == 1
    assert old_params_leaf.is_deleted()       # donated, not copied


def test_warmup_cosine_schedule_shape():
    sched = warmup_cosine(1e-3, 10, 100)
    assert float(sched(0)) == 0.0
    assert float(sched(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(sched(100)) == pytest.approx(1e-4, rel=1e-2)
    assert float(sched(55)) < float(sched(10))


def test_delay_pattern_property():
    from repro.models.frontends import delay_pattern
    toks = jnp.arange(2 * 8 * 4).reshape(2, 8, 4)
    d = delay_pattern(toks, 4)
    # codebook k shifted right by k
    np.testing.assert_array_equal(np.asarray(d[:, :, 0]),
                                  np.asarray(toks[:, :, 0]))
    np.testing.assert_array_equal(np.asarray(d[:, 1:, 1]),
                                  np.asarray(toks[:, :7, 1]))
    np.testing.assert_array_equal(np.asarray(d[:, 3:, 3]),
                                  np.asarray(toks[:, :5, 3]))


def test_param_counts_match_published():
    cases = {
        "gemma2-2b": (2.2e9, 3.0e9),
        "llama3-8b": (7.5e9, 8.5e9),
        "mixtral-8x22b": (1.30e11, 1.50e11),
        "qwen2-moe-a2.7b": (1.3e10, 1.5e10),
        "xlstm-125m": (0.8e8, 1.6e8),
    }
    for arch, (lo, hi) in cases.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)


def test_active_params_moe():
    cfg = get_config("mixtral-8x22b")
    act = cfg.param_count(active_only=True)
    assert 3.4e10 < act < 4.5e10   # ~39B active


def test_kvcache_accounting_matches_init_caches():
    """serve/kvcache analytic bytes == actual init_caches allocation."""
    from repro.serve import kvcache
    for arch in ("gemma2-2b", "zamba2-2.7b", "xlstm-125m"):
        cfg = smoke_variant(get_config(arch))
        caches = transformer.init_caches(cfg, 2, 64)
        actual = sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(caches)
                     if hasattr(x, "dtype") and x.ndim > 1)
        analytic = kvcache.cache_bytes(cfg, 2, 64)
        assert abs(actual - analytic) / max(actual, 1) < 0.05, (
            arch, actual, analytic)


def test_train_launcher_smoke():
    """the CLI training launcher end-to-end (reduced arch, few steps)."""
    from repro.launch import train as train_launcher
    hist = train_launcher.main([
        "--arch", "gemma-2b", "--smoke", "--steps", "6", "--batch", "4",
        "--seq", "32"])
    assert len(hist) >= 1
    assert all(h["loss"] == h["loss"] for h in hist)  # finite


def test_serve_launcher_smoke():
    from repro.launch import serve as serve_launcher
    done = serve_launcher.main([
        "--arch", "llama3-8b", "--requests", "2", "--max-batch", "2",
        "--prompt-len", "8", "--new", "4", "--num-pages", "16",
        "--page-size", "4"])
    assert len(done) == 2
    assert all(len(r.tokens) == 4 for r in done)
