"""Property tests for the strip helpers behind the §3.4 distributed update."""
import numpy as np

import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.core.collectives import flatten_pad, padded_size, unflatten


@given(n=st.integers(1, 10_000), g=st.integers(1, 64))
@settings(max_examples=100, deadline=None)
def test_padded_size_properties(n, g):
    p = padded_size(n, g)
    assert p >= n and p % g == 0 and p - n < g


@given(dims=st.lists(st.integers(1, 8), min_size=1, max_size=3),
       g=st.integers(1, 16))
@settings(max_examples=50, deadline=None)
def test_flatten_pad_unflatten_roundtrip(dims, g):
    x = jnp.arange(int(np.prod(dims)), dtype=jnp.float32).reshape(dims)
    flat = flatten_pad(x, g)
    assert flat.size % g == 0
    np.testing.assert_array_equal(np.asarray(unflatten(flat, dims)),
                                  np.asarray(x))
