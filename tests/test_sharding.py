"""Sharding rules resolver + hybrid planner tests (no multi-device needed —
the resolver is pure metadata against an abstract mesh)."""
import pytest

from jax.sharding import PartitionSpec as P

from _hypothesis_compat import given, settings, st
from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, TPU_V5E, get_config
from repro.core import hybrid
from repro.core.sharding import ShardingRules


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    # AbstractMesh avoids needing real devices
    from jax.sharding import AbstractMesh
    return AbstractMesh(shape, axes)


MESH = fake_mesh()
MESH3 = fake_mesh((2, 16, 16), ("pod", "data", "model"))


def test_divisible_dims_shard():
    r = ShardingRules()
    spec = r.spec(("embed", "ff"), (2048, 16384), MESH)
    assert spec == P(None, "model")


def test_indivisible_dims_stay_replicated():
    r = ShardingRules()
    # 60 experts % 16 != 0 -> replicated
    spec = r.spec(("experts", "embed", "moe_ff"), (60, 2048, 1408), MESH)
    assert spec == P(None, None, "model")


def test_batch_spans_pod_and_data():
    r = ShardingRules()
    spec = r.spec(("batch", "seq"), (256, 4096), MESH3)
    assert spec == P(("pod", "data"))


def test_no_axis_used_twice():
    r = ShardingRules()
    spec = r.spec(("ff", "moe_ff"), (1600, 3200), MESH)
    used = [s for s in spec if s is not None]
    assert len(used) == len(set(used)) <= 1


@given(dim=st.integers(1, 4096))
@settings(max_examples=60, deadline=None)
def test_resolver_never_breaks_divisibility(dim):
    r = ShardingRules()
    spec = r.spec(("ff",), (dim,), MESH)
    if spec and spec[0] is not None:
        assert dim % 16 == 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape", list(INPUT_SHAPES))
def test_plan_covers_all_pairs(arch, shape):
    cfg = get_config(arch)
    plan = hybrid.plan(cfg, INPUT_SHAPES[shape], MESH3, TPU_V5E)
    assert plan.G == 32 and plan.model_ways == 16
    assert plan.G_opt_head >= 1
    # long_500k must not shard batch=1
    if shape == "long_500k":
        assert plan.rules.rules["batch"] is None


def test_plan_fsdp_note_for_mixtral():
    cfg = get_config("mixtral-8x22b")
    plan = hybrid.plan(cfg, INPUT_SHAPES["train_4k"], MESH, TPU_V5E)
    assert any("fsdp" in n for n in plan.notes)
    assert plan.rules.rules["embed"] == ("data",)


def test_plan_cache_seq_for_indivisible_kv():
    cfg = get_config("musicgen-medium")  # kv=24
    plan = hybrid.plan(cfg, INPUT_SHAPES["decode_32k"], MESH, TPU_V5E)
    assert plan.rules.rules["cache_seq"] == ("model",)


def test_paper_optimal_G_reported():
    """llama3 LM head (vocab 128256) at train_4k: minibatch in the paper's
    FC sense is B*S tokens=2^20; G* = sqrt(512 * 2^20 / 128256) ~ 64."""
    cfg = get_config("llama3-8b")
    plan = hybrid.plan(cfg, INPUT_SHAPES["train_4k"], MESH3, TPU_V5E)
    assert 32 <= plan.G_opt_head <= 128
