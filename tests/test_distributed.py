"""Multi-device tests — each runs in a subprocess with
--xla_force_host_platform_device_count=8 so the rest of the suite keeps the
single real CPU device (per the dry-run isolation policy)."""
import os
import subprocess
import sys
import textwrap


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    # the snippets touch jax.sharding before importing repro, so load the
    # 0.4.x API backfill first (a no-op on jax that has the real APIs)
    prelude = "import repro.jaxcompat\n"
    out = subprocess.run([sys.executable, "-c",
                          prelude + textwrap.dedent(code)],
                         env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_part_reduce_broadcast_equals_psum():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType, PartitionSpec as P
        from repro.core.collectives import part_reduce, part_broadcast, \\
            part_reduce_broadcast
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        x = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)

        def f(x):
            return part_reduce_broadcast(x, "data", 0)

        def g(x):
            return jax.lax.psum(x, "data")

        with jax.set_mesh(mesh):
            a = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P(),
                                      out_specs=P(), check_vma=False))(x)
            b = jax.jit(jax.shard_map(g, mesh=mesh, in_specs=P(),
                                      out_specs=P(), check_vma=False))(x)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
        print("OK")
    """)


def test_part_reduce_strips_sum_to_full_reduction():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType, PartitionSpec as P
        from repro.core.collectives import part_reduce
        mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
        x = jnp.arange(16, dtype=jnp.float32)

        def f(x):
            return part_reduce(x, "data", 0)

        with jax.set_mesh(mesh):
            strips = jax.jit(jax.shard_map(
                f, mesh=mesh, in_specs=P(), out_specs=P("data"),
                check_vma=False))(x)
        np.testing.assert_allclose(np.asarray(strips), np.asarray(x) * 8)
        print("OK")
    """)


def test_distributed_sgd_equals_serial_multi_axis():
    """The paper's §3.4 update over ("pod","data") == serial SGD."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.optim import MomentumSGD
        from repro.optim.dist import make_distributed_update
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
        opt = MomentumSGD(momentum=0.9, weight_decay=0.01)
        params = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) / 7,
                  "b": jnp.ones((5,), jnp.float32)}
        grads = jax.tree.map(lambda p: jnp.cos(p), params)
        ref_p, ref_s = opt.update(grads, opt.init(params), params, 0.05)
        init_fn, update_fn = make_distributed_update(
            opt, mesh, data_axes=("pod", "data"))
        with jax.set_mesh(mesh):
            st = init_fn(params)
            new_p, st = jax.jit(update_fn)(params, grads, st, 0.05)
            ref_p2, ref_s2 = opt.update(grads, ref_s, ref_p, 0.05)
            new_p2, st = jax.jit(update_fn)(new_p, grads, st, 0.05)
        for k in params:
            np.testing.assert_allclose(np.asarray(new_p2[k]),
                                       np.asarray(ref_p2[k]), rtol=1e-5)
        print("OK")
    """)


def test_bucketed_update_equals_per_tensor_and_serial():
    """The comm-subsystem equivalence matrix: the bucketed §3.4 update ==
    the seed per-tensor update == the serial optimizer, across bucket sizes
    (smaller than one tensor, mid, larger than the whole tree), both wire
    dtypes, and both the flat and hierarchical ("pod","data") schedules."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.comm import CommConfig
        from repro.optim import MomentumSGD
        from repro.optim.dist import make_distributed_update
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
        opt = MomentumSGD(momentum=0.9, weight_decay=0.01)
        params = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) / 7,
                  "b": jnp.ones((5,), jnp.float32),
                  "c": jnp.cos(jnp.arange(40, dtype=jnp.float32))}
        grads = jax.tree.map(lambda p: jnp.cos(p), params)

        # serial reference: two optimizer steps
        ref_p1, ref_s = opt.update(grads, opt.init(params), params, 0.05)
        ref_p2, _ = opt.update(grads, ref_s, ref_p1, 0.05)

        def run(comm):
            init_fn, update_fn = make_distributed_update(
                opt, mesh, data_axes=("pod", "data"), comm=comm)
            with jax.set_mesh(mesh):
                st = init_fn(params)
                p1, st = jax.jit(update_fn)(params, grads, st, 0.05)
                p2, st = jax.jit(update_fn)(p1, grads, st, 0.05)
            return p2

        # per-tensor (seed) path
        pt = run(None)
        for k in params:
            np.testing.assert_allclose(np.asarray(pt[k]),
                                       np.asarray(ref_p2[k]), rtol=1e-5)

        # bucket sizes: 8 B < any tensor; 64 B mid; 1 MiB > whole tree
        for bucket_bytes in (8, 64, 1 << 20):
            for hier in (False, True):
                got = run(CommConfig(bucket_bytes=bucket_bytes,
                                     hierarchical=hier))
                for k in params:
                    np.testing.assert_allclose(
                        np.asarray(got[k]), np.asarray(ref_p2[k]),
                        rtol=1e-5, err_msg=f"{bucket_bytes}/{hier}/{k}")

        # bf16 wire: same update within bf16 rounding of the gradients
        for hier in (False, True):
            got = run(CommConfig(bucket_bytes=64, reduce_dtype="bfloat16",
                                 hierarchical=hier))
            for k in params:
                np.testing.assert_allclose(
                    np.asarray(got[k]), np.asarray(ref_p2[k]),
                    rtol=2e-2, atol=2e-3, err_msg=f"bf16/{hier}/{k}")
        print("OK")
    """)


def test_hierarchical_init_state_lands_on_owner_strips():
    """Value-initialized optimizer state must be laid out in OWNER order:
    under the hierarchical schedule member (p, d) owns strip d*G_out + p,
    not its flat mesh index p*G_in + d.  Zeros-init optimizers mask this,
    so probe with state initialized FROM the parameter strips and an update
    that consumes it."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.comm import CommConfig
        from repro.optim.dist import make_distributed_update

        class StatefulOpt:
            # state = the parameter values themselves (an EMA-like init);
            # update mixes the state in, so misaligned strips change params
            def init(self, params):
                return jax.tree.map(lambda p: p + 0.0, params)
            def update(self, grads, state, params, lr):
                new_p = jax.tree.map(
                    lambda p, g, s: p - lr * g + 0.5 * (s - p),
                    params, grads, state)
                return new_p, state

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
        opt = StatefulOpt()
        params = {"w": jnp.arange(24, dtype=jnp.float32).reshape(4, 6) / 11,
                  "b": jnp.cos(jnp.arange(7, dtype=jnp.float32))}
        grads = jax.tree.map(jnp.sin, params)
        ref_p, _ = opt.update(grads, opt.init(params), params, 0.05)
        for hier in (False, True):
            comm = CommConfig(bucket_bytes=1 << 20, hierarchical=hier)
            init_fn, update_fn = make_distributed_update(
                opt, mesh, data_axes=("pod", "data"), comm=comm)
            with jax.set_mesh(mesh):
                st = init_fn(params)
                p, st = jax.jit(update_fn)(params, grads, st, 0.05)
            for k in params:
                np.testing.assert_allclose(
                    np.asarray(p[k]), np.asarray(ref_p[k]), rtol=1e-6,
                    err_msg=f"hier={hier}/{k}")
        print("OK")
    """)


def test_zero1_train_step_through_bucketer():
    """make_train_step(dist_update=...) — the explicit ZeRO-1 path through
    the bucketed fusion-buffer collectives — matches the serial train step
    (loss, grad clip and all)."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.comm import CommConfig
        from repro.optim import AdamW
        from repro.optim.dist import make_distributed_update
        from repro.optim.schedule import constant
        from repro.train import make_train_step
        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.normal(size=(6, 3)), jnp.float32),
                  "b": jnp.zeros((3,), jnp.float32)}
        batch = {"x": jnp.asarray(rng.normal(size=(16, 6)), jnp.float32),
                 "y": jnp.asarray(rng.normal(size=(16, 3)), jnp.float32)}
        def loss(p, b):
            return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2)
        opt = AdamW(weight_decay=0.1)
        sched = constant(1e-2)

        step_serial = make_train_step(loss, opt, sched)
        p1, s1, m1 = jax.jit(step_serial)(params, opt.init(params), 0, batch)
        p1, s1, m1 = jax.jit(step_serial)(p1, s1, 1, batch)

        mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
        init_fn, update_fn = make_distributed_update(
            opt, mesh, comm=CommConfig(bucket_bytes=64))
        step_dist = make_train_step(loss, opt, sched, dist_update=update_fn)
        with jax.set_mesh(mesh):
            p2, s2, m2 = jax.jit(step_dist)(params, init_fn(params), 0, batch)
            p2, s2, m2 = jax.jit(step_dist)(p2, s2, 1, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-6)
        for k in params:
            np.testing.assert_allclose(np.asarray(p1[k]), np.asarray(p2[k]),
                                       rtol=1e-5, atol=1e-6)
        print("OK")
    """)


def test_overlapped_train_step_matches_serial():
    """The §3.1 backprop-overlapped zero1 step — bucket part-reduces issued
    inside the backward pass via the comm hooks — matches the serial train
    step (loss, grad clip, params) to float tolerance, for the flat and the
    hierarchical ("pod","data") schedules across bucket sizes."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.comm import CommConfig
        from repro.optim import AdamW
        from repro.optim.dist import make_overlapped_update
        from repro.optim.schedule import constant
        from repro.train import make_overlapped_train_step, make_train_step
        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.normal(size=(6, 3)), jnp.float32),
                  "b": jnp.zeros((3,), jnp.float32),
                  "v": jnp.asarray(rng.normal(size=(40,)), jnp.float32)}
        batch = {"x": jnp.asarray(rng.normal(size=(16, 6)), jnp.float32),
                 "y": jnp.asarray(rng.normal(size=(16, 3)), jnp.float32)}
        def loss(p, b):
            pred = b["x"] @ p["w"] + p["b"] + jnp.mean(p["v"])
            return jnp.mean((pred - b["y"]) ** 2)
        opt = AdamW(weight_decay=0.1)
        sched = constant(1e-2)

        step_serial = make_train_step(loss, opt, sched)
        p1, s1, m1 = jax.jit(step_serial)(params, opt.init(params), 0, batch)
        p1, s1, m1 = jax.jit(step_serial)(p1, s1, 1, batch)

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
        for bucket_bytes in (8, 64, 1 << 20):
            for hier in (False, True):
                comm = CommConfig(bucket_bytes=bucket_bytes,
                                  hierarchical=hier, overlap=True)
                init_fn, local_update = make_overlapped_update(
                    opt, mesh, data_axes=("pod", "data"), comm=comm)
                step_ov = make_overlapped_train_step(
                    loss, sched, mesh, ("pod", "data"), comm, local_update)
                with jax.set_mesh(mesh):
                    p2, s2, m2 = jax.jit(step_ov)(params, init_fn(params),
                                                  0, batch)
                    p2, s2, m2 = jax.jit(step_ov)(p2, s2, 1, batch)
                tag = f"{bucket_bytes}/{hier}"
                np.testing.assert_allclose(float(m1["loss"]),
                                           float(m2["loss"]),
                                           rtol=1e-5, err_msg=tag)
                np.testing.assert_allclose(float(m1["grad_norm"]),
                                           float(m2["grad_norm"]),
                                           rtol=1e-4, err_msg=tag)
                for k in params:
                    np.testing.assert_allclose(
                        np.asarray(p1[k]), np.asarray(p2[k]),
                        rtol=1e-5, atol=1e-6, err_msg=f"{tag}/{k}")
        print("OK")
    """)


def test_sharded_train_step_matches_single_device():
    """pjit train step on a 2x2 mesh == single-device step (same loss)."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.configs import get_config, smoke_variant
        from repro.core.sharding import ShardingCtx, ShardingRules
        from repro.core.params import Spec
        from repro.models import transformer
        from repro.optim import AdamW
        from repro.optim.schedule import constant
        from repro.train import make_train_step

        cfg = smoke_variant(get_config("llama3-8b"))
        key = jax.random.PRNGKey(0)
        params = transformer.init_params(cfg, key)
        tokens = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
        opt = AdamW()
        sched = constant(1e-3)

        # single device
        ctx1 = ShardingCtx()
        step1 = make_train_step(
            lambda p, b: transformer.lm_loss(p, cfg, ctx1, b), opt, sched)
        p1, s1, m1 = jax.jit(step1)(params, opt.init(params), 0,
                                    {"tokens": tokens})

        # 2x2 mesh
        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        rules = ShardingRules()
        ctx2 = ShardingCtx(mesh, rules)
        sp = transformer.param_specs(cfg)
        shardings = jax.tree.map(
            lambda s: rules.sharding(s.axes, s.shape, mesh), sp,
            is_leaf=lambda x: isinstance(x, Spec))
        params2 = jax.tree.map(jax.device_put, params, shardings)
        step2 = make_train_step(
            lambda p, b: transformer.lm_loss(p, cfg, ctx2, b), opt, sched)
        with jax.set_mesh(mesh):
            p2, s2, m2 = jax.jit(step2)(params2, opt.init(params2), 0,
                                        {"tokens": tokens})
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=2e-3)
        # updated params agree
        la, lb = jax.tree.leaves(p1), jax.tree.leaves(p2)
        for a, b in zip(la, lb):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-2, atol=2e-3)
        print("OK")
    """)


def test_moe_arch_sharded_forward():
    """MoE forward under a mesh keeps loss equal to single-device."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.configs import get_config, smoke_variant
        from repro.core.sharding import ShardingCtx, ShardingRules
        from repro.core.params import Spec
        from repro.models import transformer
        cfg = smoke_variant(get_config("qwen2-moe-a2.7b"))
        key = jax.random.PRNGKey(0)
        params = transformer.init_params(cfg, key)
        batch = {"tokens": jax.random.randint(key, (4, 32), 0,
                                              cfg.vocab_size)}
        l1 = transformer.lm_loss(params, cfg, ShardingCtx(), batch)
        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        rules = ShardingRules()
        ctx = ShardingCtx(mesh, rules)
        sp = transformer.param_specs(cfg)
        sh = jax.tree.map(lambda s: rules.sharding(s.axes, s.shape, mesh),
                          sp, is_leaf=lambda x: isinstance(x, Spec))
        params2 = jax.tree.map(jax.device_put, params, sh)
        with jax.set_mesh(mesh):
            l2 = jax.jit(lambda p, b: transformer.lm_loss(p, cfg, ctx, b))(
                params2, batch)
        np.testing.assert_allclose(float(l1), float(l2), rtol=2e-3)
        print("OK")
    """)


def test_explicit_expert_parallel_matches_tensor_parallel():
    """§Perf V7: the shard_map+all_to_all expert-parallel MoE block equals
    the TP block (dropless capacities) on a 2x4 mesh."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.configs import get_config, smoke_variant
        from repro.core.sharding import ShardingCtx, ShardingRules
        from repro.core.params import init_tree
        from repro.models import moe
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        cfg = smoke_variant(get_config("mixtral-8x22b")).replace(
            moe_capacity_factor=4.0)
        p = init_tree(moe.moe_specs(cfg), jax.random.PRNGKey(0))
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(4, 16, cfg.d_model)), jnp.float32)
        ref, aux_ref = moe.moe_block(p, x, cfg, ShardingCtx())
        ctx = ShardingCtx(mesh, ShardingRules())
        with jax.set_mesh(mesh):
            out, aux = jax.jit(lambda p, x: moe.moe_ep_block(
                p, x, cfg, ctx))(p, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)
        print("OK")
    """)


def test_seq_shard_carry_preserves_loss():
    """§Perf L4: sequence-sharded residual carries change memory layout,
    not math."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.configs import get_config, smoke_variant
        from repro.core.sharding import ShardingCtx, ShardingRules
        from repro.core.params import Spec
        from repro.models import transformer
        cfg = smoke_variant(get_config("llama3-8b"))
        key = jax.random.PRNGKey(0)
        params = transformer.init_params(cfg, key)
        batch = {"tokens": jax.random.randint(key, (4, 32), 0,
                                              cfg.vocab_size)}
        l0 = transformer.lm_loss(params, cfg, ShardingCtx(), batch)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        rules = ShardingRules()
        ctx = ShardingCtx(mesh, rules)
        cfg2 = cfg.replace(seq_shard_carry=True, remat="block")
        with jax.set_mesh(mesh):
            l1 = jax.jit(lambda p, b: transformer.lm_loss(
                p, cfg2, ctx, b))(params, batch)
        np.testing.assert_allclose(float(l0), float(l1), rtol=2e-3)
        print("OK")
    """)


def test_sharded_decode_attention_matches_reference():
    """§Perf D1: shard_map partial-softmax decode == unsharded decode."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.configs import get_config, smoke_variant
        from repro.core.sharding import ShardingCtx, ShardingRules
        from repro.core.params import init_tree
        from repro.models import layers
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        cfg = smoke_variant(get_config("gemma2-2b")).replace(
            attn_logit_softcap=50.0)
        p = init_tree(layers.attn_specs(cfg), jax.random.PRNGKey(0))
        B, C = 4, 32
        rng = np.random.default_rng(0)
        shp = (B, C, cfg.num_kv_heads, cfg.head_dim)
        cache = layers.AttnCache(
            jnp.asarray(rng.normal(size=shp), jnp.float32),
            jnp.asarray(rng.normal(size=shp), jnp.float32),
            jnp.asarray(20, jnp.int32))
        x = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)
        pos = jnp.full((B, 1), 20, jnp.int32)
        ref_out, ref_c = layers.attention_block(
            p, x, cfg, ShardingCtx(), pos, window=0, cache=cache)
        rules = ShardingRules().with_overrides(cache_seq=("model",))
        ctx = ShardingCtx(mesh, rules)
        with jax.set_mesh(mesh):
            out, nc = jax.jit(lambda p, x, c: layers.attention_block(
                p, x, cfg, ctx, pos, window=0, cache=c))(p, x, cache)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(nc.k), np.asarray(ref_c.k),
                                   rtol=1e-5, atol=1e-5)
        assert int(nc.length) == int(ref_c.length) == 21
        print("OK")
    """)


def test_ep_training_end_to_end_matches_tp():
    """A full train step through the EP MoE path (shard_map all_to_all under
    scan + remat + grad) matches the single-device TP path."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.configs import get_config, smoke_variant
        from repro.core.sharding import ShardingCtx, ShardingRules
        from repro.core.params import Spec
        from repro.models import transformer
        from repro.optim import AdamW
        from repro.optim.schedule import constant
        from repro.train import make_train_step
        cfg0 = smoke_variant(get_config("mixtral-8x22b")).replace(
            moe_capacity_factor=4.0)
        key = jax.random.PRNGKey(0)
        params = transformer.init_params(cfg0, key)
        batch = {"tokens": jax.random.randint(key, (4, 32), 0,
                                              cfg0.vocab_size)}
        opt = AdamW()
        step0 = make_train_step(lambda p, b: transformer.lm_loss(
            p, cfg0, ShardingCtx(), b), opt, constant(1e-3))
        p0, _, m0 = jax.jit(step0)(params, opt.init(params), 0, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        # E=4 experts divisible by model=4 -> EP path (pad=0+gate override)
        cfg1 = cfg0.replace(moe_expert_pad=4, remat="block")
        # pad params to Ep=8
        def pad_fix(path, a):
            ks = jax.tree_util.keystr(path)
            if any(w in ks for w in ["w_gate", "w_up", "w_down"]):
                return jnp.pad(a, [(0, 0), (0, 4)] + [(0, 0)] * (a.ndim - 2))
            return a
        params1 = jax.tree_util.tree_map_with_path(pad_fix, params)
        rules = ShardingRules()
        ctx = ShardingCtx(mesh, rules)
        step1 = make_train_step(lambda p, b: transformer.lm_loss(
            p, cfg1, ctx, b), opt, constant(1e-3))
        with jax.set_mesh(mesh):
            p1, _, m1 = jax.jit(step1)(params1, opt.init(params1), 0, batch)
        np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                                   rtol=3e-3)
        np.testing.assert_allclose(float(m0["grad_norm"]),
                                   float(m1["grad_norm"]), rtol=2e-2)
        print("OK")
    """)


def test_pallas_ring_backend_matches_lax_collectives():
    """Backend interchangeability at the primitive level: PallasRingBackend's
    part_reduce / part_broadcast / psum agree with LaxBackend (same strip
    OWNERS, same values) over a single axis and a composed ("pod","data")
    group, in fp32 and the bf16 wire dtype."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType, PartitionSpec as P
        from repro.comm import LaxBackend, PallasRingBackend
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
        lax_b, ring_b = LaxBackend(), PallasRingBackend()
        rng = np.random.default_rng(0)
        for axes, spec in (("data", P("data")),
                           (("pod", "data"), P(("pod", "data")))):
            for dtype in (jnp.float32, jnp.bfloat16):
                x = jnp.asarray(rng.normal(size=(32,)), dtype)

                def f(b):
                    def inner(x):
                        strip = b.part_reduce(x, axes)
                        full = b.part_broadcast(strip, axes)
                        return strip, full, b.psum(x, axes)
                    return inner

                with jax.set_mesh(mesh):
                    outs = {}
                    for name, b in (("lax", lax_b), ("ring", ring_b)):
                        outs[name] = jax.jit(jax.shard_map(
                            f(b), mesh=mesh, in_specs=P(),
                            out_specs=(spec, P(), P()),
                            check_vma=False))(x)
                tol = 1e-6 if dtype == jnp.float32 else 3e-2
                for a, b2, what in zip(outs["lax"], outs["ring"],
                                       ("strips", "full", "psum")):
                    np.testing.assert_allclose(
                        np.asarray(a, np.float32), np.asarray(b2, np.float32),
                        rtol=tol, atol=tol, err_msg=f"{axes}/{dtype}/{what}")
        print("OK")
    """)


def test_pallas_ring_zero1_matches_serial():
    """The backend-equivalence matrix for training: zero1 through the
    pallas-ring collectives == the serial optimizer — monolithic and
    backprop-overlapped, flat and hierarchical ("pod","data"), across
    bucket sizes."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.comm import CommConfig
        from repro.optim import AdamW
        from repro.optim.dist import make_distributed_update, \\
            make_overlapped_update
        from repro.optim.schedule import constant
        from repro.train import make_overlapped_train_step, make_train_step
        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.normal(size=(6, 3)), jnp.float32),
                  "b": jnp.zeros((3,), jnp.float32),
                  "v": jnp.asarray(rng.normal(size=(40,)), jnp.float32)}
        batch = {"x": jnp.asarray(rng.normal(size=(16, 6)), jnp.float32),
                 "y": jnp.asarray(rng.normal(size=(16, 3)), jnp.float32)}
        def loss(p, b):
            pred = b["x"] @ p["w"] + p["b"] + jnp.mean(p["v"])
            return jnp.mean((pred - b["y"]) ** 2)
        opt = AdamW(weight_decay=0.1)
        sched = constant(1e-2)

        step_serial = make_train_step(loss, opt, sched)
        p1, s1, m1 = jax.jit(step_serial)(params, opt.init(params), 0, batch)
        p1, s1, m1 = jax.jit(step_serial)(p1, s1, 1, batch)

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
        for bucket_bytes in (64, 1 << 20):
            for hier in (False, True):
                for overlap in (False, True):
                    comm = CommConfig(bucket_bytes=bucket_bytes,
                                      hierarchical=hier, overlap=overlap,
                                      backend="pallas-ring")
                    if overlap:
                        init_fn, local_update = make_overlapped_update(
                            opt, mesh, data_axes=("pod", "data"), comm=comm)
                        step = make_overlapped_train_step(
                            loss, sched, mesh, ("pod", "data"), comm,
                            local_update)
                    else:
                        init_fn, update_fn = make_distributed_update(
                            opt, mesh, data_axes=("pod", "data"), comm=comm)
                        step = make_train_step(loss, opt, sched,
                                               dist_update=update_fn)
                    with jax.set_mesh(mesh):
                        p2, s2, m2 = jax.jit(step)(params, init_fn(params),
                                                   0, batch)
                        p2, s2, m2 = jax.jit(step)(p2, s2, 1, batch)
                    tag = f"{bucket_bytes}/hier={hier}/overlap={overlap}"
                    np.testing.assert_allclose(float(m1["loss"]),
                                               float(m2["loss"]),
                                               rtol=1e-5, err_msg=tag)
                    for k in params:
                        np.testing.assert_allclose(
                            np.asarray(p1[k]), np.asarray(p2[k]),
                            rtol=1e-5, atol=1e-6, err_msg=f"{tag}/{k}")
        print("OK")
    """)


def test_phase_pipeline_bit_exact_vs_seed_builders():
    """The refactor contract: the UpdatePlan phase pipeline is BIT-equal to
    the pre-refactor builders for every existing mode.  The seed
    implementations (per-tensor schedule, bucketed monolithic update,
    bucketed apply+broadcast tail) are copied verbatim below and both
    stacks run two momentum steps from the same start; params and state
    leaves must match with assert_array_equal — no tolerance."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax import lax
        from jax.sharding import AxisType, NamedSharding, PartitionSpec as P
        from repro.comm import CommConfig
        from repro.comm.bucketer import pack_bucket, plan_buckets, \\
            unpack_buckets
        from repro.comm.schedule import group_axes, make_schedule
        from repro.core.collectives import flatten_pad, strip_broadcast, \\
            strip_reduce
        from repro.optim import MomentumSGD
        from repro.optim.dist import _state_spec, make_distributed_update, \\
            make_overlapped_update, owner_perm

        # ---- seed builders, verbatim from the pre-refactor module ----
        def seed_bucketed_init(optimizer, mesh, axes, axis_arg, G, comm):
            perm = owner_perm(comm.hierarchical,
                              [mesh.shape[a] for a in axes])
            def _strip_init(params):
                plan = plan_buckets(params, G, comm.bucket_bytes)
                flat = jax.tree.leaves(params)
                strips = [pack_bucket(flat, b).reshape(G, -1)
                          for b in plan.buckets]
                if perm is not None:
                    strips = [s[perm] for s in strips]
                return optimizer.init(strips)
            def init_fn(params):
                with jax.set_mesh(mesh):
                    state = jax.jit(_strip_init)(params)
                sh = jax.tree.map(
                    lambda s: NamedSharding(mesh, _state_spec(s, axis_arg)),
                    state)
                return jax.tree.map(jax.device_put, state, sh)
            return init_fn

        def seed_apply(optimizer, sched, plan, G, params, g_strips,
                       opt_state, lr):
            flat_params, treedef = jax.tree.flatten(params)
            i = sched.owner_index()
            p_strips = []
            for b in plan.buckets:
                pbuf = pack_bucket(flat_params, b)
                n = b.padded_size // G
                p_strips.append(lax.dynamic_slice(pbuf, (i * n,), (n,)))
            s_local = jax.tree.map(
                lambda s: s[0] if s.ndim >= 2 else s, opt_state)
            new_p_strips, new_state = optimizer.update(g_strips, s_local,
                                                       p_strips, lr)
            bufs = [sched.broadcast(ps)
                    for ps in jax.tree.leaves(new_p_strips)]
            new_params = jax.tree.unflatten(treedef,
                                            unpack_buckets(bufs, plan))
            new_state = jax.tree.map(
                lambda s: s[None] if s.ndim >= 1 else s, new_state)
            return new_params, new_state

        def seed_bucketed(optimizer, mesh, data_axes, comm):
            axes, axis_arg, G = group_axes(mesh, data_axes)
            init_fn = seed_bucketed_init(optimizer, mesh, axes, axis_arg,
                                         G, comm)
            def _update(params, grads, opt_state, lr):
                plan = plan_buckets(params, G, comm.bucket_bytes)
                sched = make_schedule(axis_arg, comm.hierarchical,
                                      comm.backend, comm.cross_backend)
                flat_grads = jax.tree.leaves(grads)
                g_strips = [sched.reduce(pack_bucket(flat_grads, b),
                                         comm.wire_dtype) / G
                            for b in plan.buckets]
                return seed_apply(optimizer, sched, plan, G, params,
                                  g_strips, opt_state, lr)
            def update_fn(params, grads, opt_state, lr):
                pspec = jax.tree.map(lambda _: P(), params)
                sspec = jax.tree.map(
                    lambda s: _state_spec(s, axis_arg), opt_state)
                fn = jax.shard_map(_update, mesh=mesh,
                                   in_specs=(pspec, pspec, sspec, P()),
                                   out_specs=(pspec, sspec),
                                   check_vma=False)
                return fn(params, grads, opt_state, lr)
            return init_fn, update_fn

        def seed_per_tensor(optimizer, mesh, data_axes):
            axes, axis_arg, G = group_axes(mesh, data_axes)
            def _strip_init(params):
                def per_tensor(p):
                    return flatten_pad(p, G).reshape(G, -1)
                return optimizer.init(jax.tree.map(per_tensor, params))
            def init_fn(params):
                with jax.set_mesh(mesh):
                    state = jax.jit(_strip_init)(params)
                sh = jax.tree.map(
                    lambda s: NamedSharding(mesh, _state_spec(s, axis_arg)),
                    state)
                return jax.tree.map(jax.device_put, state, sh)
            def _update(params, grads, opt_state, lr):
                flat_params, treedef = jax.tree.flatten(params)
                flat_grads = jax.tree.leaves(grads)
                g_strips = [strip_reduce(g, axis_arg) for g in flat_grads]
                i = make_schedule(axis_arg).owner_index()
                p_strips = []
                for p in flat_params:
                    flat = flatten_pad(p, G)
                    n = flat.size // G
                    p_strips.append(lax.dynamic_slice(flat, (i * n,), (n,)))
                g_tree = jax.tree.unflatten(treedef, g_strips)
                p_tree = jax.tree.unflatten(treedef, p_strips)
                s_local = jax.tree.map(
                    lambda s: s[0] if s.ndim >= 2 else s, opt_state)
                new_p_strips, new_state = optimizer.update(
                    g_tree, s_local, p_tree, lr)
                new_flat = [strip_broadcast(ps, axis_arg, p.shape)
                            for p, ps in zip(flat_params,
                                             jax.tree.leaves(new_p_strips))]
                new_params = jax.tree.unflatten(treedef, new_flat)
                new_state = jax.tree.map(
                    lambda s: s[None] if s.ndim >= 1 else s, new_state)
                return new_params, new_state
            def update_fn(params, grads, opt_state, lr):
                pspec = jax.tree.map(lambda _: P(), params)
                sspec = jax.tree.map(
                    lambda s: _state_spec(s, axis_arg), opt_state)
                fn = jax.shard_map(_update, mesh=mesh,
                                   in_specs=(pspec, pspec, sspec, P()),
                                   out_specs=(pspec, sspec),
                                   check_vma=False)
                return fn(params, grads, opt_state, lr)
            return init_fn, update_fn

        # ---- the matrix ----
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
        opt = MomentumSGD(momentum=0.9, weight_decay=0.01)
        params = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) / 7,
                  "b": jnp.ones((5,), jnp.float32),
                  "c": jnp.cos(jnp.arange(40, dtype=jnp.float32))}
        g1 = jax.tree.map(jnp.cos, params)
        g2 = jax.tree.map(jnp.sin, params)

        def two_steps(init_fn, update_fn):
            with jax.set_mesh(mesh):
                st = init_fn(params)
                p, st = jax.jit(update_fn)(params, g1, st, 0.05)
                p, st = jax.jit(update_fn)(p, g2, st, 0.05)
            return p, st

        def check(tag, seed_pair, new_pair):
            ps, ss = two_steps(*seed_pair)
            pn, sn = two_steps(*new_pair)
            for k in params:
                np.testing.assert_array_equal(
                    np.asarray(ps[k]), np.asarray(pn[k]),
                    err_msg=f"{tag}/params/{k}")
            # seed per-tensor state is tree-shaped, the pipeline's is a
            # strip list — leaves match positionally
            for a, b in zip(jax.tree.leaves(ss), jax.tree.leaves(sn)):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b), err_msg=f"{tag}/state")

        check("per-tensor",
              seed_per_tensor(opt, mesh, ("pod", "data")),
              make_distributed_update(opt, mesh, data_axes=("pod", "data"),
                                      comm=None))
        for comm in (CommConfig(bucket_bytes=64),
                     CommConfig(bucket_bytes=64, hierarchical=True),
                     CommConfig(bucket_bytes=64, backend="pallas-ring"),
                     CommConfig(bucket_bytes=1 << 20, hierarchical=True,
                                reduce_dtype="bfloat16")):
            tag = (f"bkt{comm.bucket_bytes}/hier={comm.hierarchical}"
                   f"/{comm.backend}/{comm.reduce_dtype}")
            check(tag,
                  seed_bucketed(opt, mesh, ("pod", "data"), comm),
                  make_distributed_update(opt, mesh,
                                          data_axes=("pod", "data"),
                                          comm=comm))

        # overlapped tail (apply + broadcast on pre-reduced strips): seed
        # _apply_strip_update vs the pipeline's local_update, same inputs
        comm = CommConfig(bucket_bytes=64, hierarchical=True, overlap=True)
        axes, axis_arg, G = group_axes(mesh, ("pod", "data"))
        init_new, local_new = make_overlapped_update(
            opt, mesh, data_axes=("pod", "data"), comm=comm)
        init_seed = seed_bucketed_init(opt, mesh, axes, axis_arg, G, comm)

        def driver(local_update):
            def _inner(params, grads, opt_state, lr):
                plan = plan_buckets(params, G, comm.bucket_bytes)
                sched = make_schedule(axis_arg, comm.hierarchical,
                                      comm.backend, comm.cross_backend)
                flat_grads = jax.tree.leaves(grads)
                g_strips = [sched.reduce(pack_bucket(flat_grads, b),
                                         comm.wire_dtype) / G
                            for b in plan.buckets]
                return local_update(params, g_strips, opt_state, lr)
            def update_fn(params, grads, opt_state, lr):
                pspec = jax.tree.map(lambda _: P(), params)
                sspec = jax.tree.map(
                    lambda s: _state_spec(s, axis_arg), opt_state)
                fn = jax.shard_map(_inner, mesh=mesh,
                                   in_specs=(pspec, pspec, sspec, P()),
                                   out_specs=(pspec, sspec),
                                   check_vma=False)
                return fn(params, grads, opt_state, lr)
            return update_fn

        def seed_local(params, g_strips, opt_state, lr):
            plan = plan_buckets(params, G, comm.bucket_bytes)
            sched = make_schedule(axis_arg, comm.hierarchical,
                                  comm.backend, comm.cross_backend)
            return seed_apply(opt, sched, plan, G, params, g_strips,
                              opt_state, lr)

        check("overlap-tail",
              (init_seed, driver(seed_local)),
              (init_new, driver(local_new)))
        print("OK")
    """)


def test_gossip_backend_pair_exchange_rotation():
    """comm.backends.gossip semantics at the primitive level: at step t
    member i's part_reduce strip is (own chunk i + chunk i of partner
    (i - s) % G) * G/2 with the GossipGraD shift s = 1 + t % (G-1) — so
    the schedule's /G yields the PAIR mean, every member is in exactly one
    exchange per step, and the rotation sweeps all G-1 partners before
    repeating."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType, PartitionSpec as P
        from repro.comm.backends import get_backend
        from repro.comm.schedule import bind_step

        G, n = 8, 16
        mesh = jax.make_mesh((G,), ("data",), axis_types=(AxisType.Auto,))
        x = np.arange(G * n, dtype=np.float32).reshape(G, n) / 3.0
        chunks = x.reshape(G, G, n // G)      # [member, chunk, elems]

        for step in range(2 * (G - 1) + 1):
            b = bind_step(get_backend("gossip"), jnp.asarray(step))
            def f(row):
                return b.part_reduce(row[0], "data")[None]
            with jax.set_mesh(mesh):
                got = jax.jit(jax.shard_map(
                    f, mesh=mesh, in_specs=P("data"),
                    out_specs=P("data"), check_vma=False))(jnp.asarray(x))
            s = 1 + step % (G - 1)
            want = np.stack([(chunks[i, i] + chunks[(i - s) % G, i])
                             * (G / 2.0) for i in range(G)])
            np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6,
                                       err_msg=f"step={step}")
            # symmetry: i's partner (i-s) has i as ITS partner at the same
            # step iff shifts cancel mod G — verified implicitly by the
            # ppermute pair construction; check every member appears once
            partners = {(i, (i - s) % G) for i in range(G)}
            assert len({p for p, _ in partners}) == G
        print("OK")
    """)


def test_gossip_g2_matches_zero1_bitwise():
    """At G=2 the rotation is degenerate (the only partner is the other
    member), so gossip IS full synchronous data parallelism: the gossip
    update must be bitwise identical to zero1, params and state."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.comm import CommConfig
        from repro.optim import MomentumSGD
        from repro.optim.dist import make_distributed_update
        mesh = jax.make_mesh((2,), ("data",), axis_types=(AxisType.Auto,))
        opt = MomentumSGD(momentum=0.9)
        params = {"w": jnp.linspace(-1, 1, 37, dtype=jnp.float32),
                  "b": jnp.cos(jnp.arange(11, dtype=jnp.float32))}
        grads = [jax.tree.map(lambda p: jnp.sin(p + t), params)
                 for t in range(3)]

        def run(backend):
            comm = CommConfig(bucket_bytes=64, backend=backend)
            init_fn, update_fn = make_distributed_update(
                opt, mesh, comm=comm)
            with jax.set_mesh(mesh):
                p, st = params, init_fn(params)
                for t, g in enumerate(grads):
                    p, st = jax.jit(update_fn)(p, g, st, 0.05, t)
            return p, st

        pz, sz = run("lax")
        pg, sg = run("gossip")
        for k in params:
            np.testing.assert_array_equal(np.asarray(pz[k]),
                                          np.asarray(pg[k]), err_msg=k)
        for a, b in zip(jax.tree.leaves(sz), jax.tree.leaves(sg)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("OK")
    """, devices=2)


def test_stale_sync_applies_previous_steps_gradient():
    """make_stale_sync_update semantics: step 0 applies its OWN reduce
    (empty carry), step t>0 applies step t-1's — so feeding gradients
    [g0, g1, g2] must land exactly where the serial optimizer lands on
    [g0, g0, g1], and the carried buffer always holds the LAST reduce."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.comm import CommConfig
        from repro.optim import MomentumSGD
        from repro.optim.dist import make_stale_sync_update
        mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
        opt = MomentumSGD(momentum=0.9, weight_decay=0.01)
        params = {"w": jnp.arange(24, dtype=jnp.float32).reshape(4, 6) / 11,
                  "b": jnp.ones((7,), jnp.float32)}
        gs = [jax.tree.map(lambda p: jnp.cos(p + t), params)
              for t in range(3)]

        init_fn, update_fn = make_stale_sync_update(
            opt, mesh, comm=CommConfig(bucket_bytes=64))
        with jax.set_mesh(mesh):
            p, st = params, init_fn(params)
            assert int(st["synced"]) == 0
            for t, g in enumerate(gs):
                p, st = jax.jit(update_fn)(p, g, st, 0.05, t)
                assert int(st["synced"]) == 1

        # serial reference on the staleness-shifted gradient sequence
        rp, rs = params, opt.init(params)
        for g in [gs[0], gs[0], gs[1]]:
            rp, rs = opt.update(g, rs, rp, 0.05)
        for k in params:
            np.testing.assert_allclose(np.asarray(p[k]), np.asarray(rp[k]),
                                       rtol=1e-5, atol=1e-7, err_msg=k)
        print("OK")
    """, devices=4)
