"""Roofline analyzer tests: HLO collective parsing + ring cost model."""
import pytest

from repro.configs import TPU_V5E
from repro.core import roofline

HLO = """
HloModule test
  %all-reduce = f32[1024,512]{1,0} all-reduce(%x), channel_id=1, replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%add
  %ag = bf16[4096]{0} all-gather(%y), channel_id=2, replica_groups=[16,16]<=[256], dimensions={0}
  %rs = f32[128,128]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[1,256]<=[256], dimensions={0}, to_apply=%add
  %a2a = f32[64]{0} all-to-all(%w), channel_id=4, replica_groups=[16,16]<=[256]
  %cp = f32[32,32]{1,0} collective-permute(%v), channel_id=5, source_target_pairs={{0,1}}
  %ard = f32[8] all-reduce-done(%ar_start)
"""


def test_parse_collectives_kinds_and_bytes():
    st = roofline.parse_collectives(HLO)
    assert st.count_by_kind["all-reduce"] == 1
    assert st.count_by_kind["all-gather"] == 1
    assert st.count_by_kind["reduce-scatter"] == 1
    assert st.count_by_kind["all-to-all"] == 1
    assert st.count_by_kind["collective-permute"] == 1
    assert st.bytes_by_kind["all-reduce"] == 1024 * 512 * 4
    assert st.bytes_by_kind["all-gather"] == 4096 * 2


def test_ring_model():
    st = roofline.parse_collectives(HLO)
    ar = 2 * (15 / 16) * 1024 * 512 * 4
    ag = (15 / 16) * 4096 * 2
    rs = (255 / 256) * 128 * 128 * 4
    a2a = (15 / 16) * 64 * 4
    cp = 32 * 32 * 4
    assert st.ring_bytes == pytest.approx(ar + ag + rs + a2a + cp)


def test_report_terms_and_dominant():
    st_cost = {"flops": 1e15, "bytes accessed": 1e11}
    rep = roofline.analyze("a", "s", "16x16", 256, st_cost, HLO,
                           model_flops_total=2.56e17, hw=TPU_V5E)
    assert rep.compute_s == pytest.approx(1e15 / 197e12)
    assert rep.memory_s == pytest.approx(1e11 / 819e9)
    assert rep.dominant == "compute"
    assert rep.useful_flops_ratio == pytest.approx(1.0)
    assert 0 < rep.mfu <= 1.0


def test_async_start_ops_not_double_counted():
    txt = """
    %ag-start = (f32[128], f32[512]) all-gather-start(%p), replica_groups=[2,4]<=[8]
    %ag-done = f32[512] all-gather-done(%ag-start)
    """
    st = roofline.parse_collectives(txt)
    assert st.count_by_kind.get("all-gather", 0) == 1
