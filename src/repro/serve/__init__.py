from repro.serve.decode import decode_step, generate, prefill  # noqa: F401
from repro.serve.kvcache import (  # noqa: F401
    PagedKVCache,
    cache_bytes,
    paged_cache_bytes,
)
