from repro.serve.decode import decode_step, generate, prefill  # noqa: F401
