"""Serving: batched prefill + incremental decode over ring-buffered caches.

``prefill`` runs the full-sequence forward and fills the caches;
``decode_step`` consumes ONE token per request (this is what decode_32k /
long_500k lower in the dry-run); ``generate`` drives greedy/temperature
sampling for the examples."""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sharding import ShardingCtx
from repro.models import transformer


def prefill(params, cfg: ModelConfig, ctx: ShardingCtx, tokens: jax.Array,
            capacity: int, *, embeds: Optional[jax.Array] = None,
            long_ctx: bool = False):
    """tokens: (B, S).  Returns (last_logits (B, V), caches)."""
    B = tokens.shape[0] if tokens is not None else embeds.shape[0]
    caches = transformer.init_caches(cfg, B, capacity, long_ctx=long_ctx)
    logits, _, caches = transformer.forward(
        params, cfg, ctx, tokens=tokens, embeds=embeds, caches=caches,
        update_cache=True, long_ctx=long_ctx)
    return logits[:, -1], caches


def decode_step(params, cfg: ModelConfig, ctx: ShardingCtx,
                tokens: jax.Array, pos: jax.Array, caches, *,
                long_ctx: bool = False):
    """tokens: (B, 1) the latest sampled token; pos: () or (B,) absolute
    position.  Returns (logits (B, V), new_caches)."""
    B = tokens.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos).reshape(-1, 1), (B, 1))
    if cfg.mrope:
        pos_b = jnp.repeat(pos_b[..., None], 3, axis=-1)
    logits, _, caches = transformer.forward(
        params, cfg, ctx, tokens=tokens, positions=pos_b, caches=caches,
        long_ctx=long_ctx)
    return logits[:, -1], caches


# generate() used to wrap prefill/decode_step in a FRESH jax.jit per call,
# recompiling both executables every invocation.  The compiled pairs are now
# cached here, keyed by everything that shapes the computation (the frozen
# cfg hashes; ShardingRules holds a dict, so the ctx is keyed by VALUE).
# Server (repro.api.serve) owns its executables directly, same idea.
_JIT_CACHE: Dict[Tuple, Tuple] = {}


def _ctx_key(ctx: ShardingCtx) -> Tuple:
    return (ctx.mesh, tuple(sorted(ctx.rules.rules.items())))


def _compiled_pair(cfg: ModelConfig, ctx: ShardingCtx, capacity: int,
                   long_ctx: bool = False):
    """(jitted prefill, jitted decode_step) for one (cfg, ctx, capacity)."""
    key = (cfg, _ctx_key(ctx), capacity, long_ctx)
    if key not in _JIT_CACHE:
        _JIT_CACHE[key] = (
            jax.jit(functools.partial(prefill, cfg=cfg, ctx=ctx,
                                      capacity=capacity, long_ctx=long_ctx)),
            jax.jit(functools.partial(decode_step, cfg=cfg, ctx=ctx,
                                      long_ctx=long_ctx)))
    return _JIT_CACHE[key]


def generate(params, cfg: ModelConfig, ctx: ShardingCtx, prompt: jax.Array,
             max_new_tokens: int, *, temperature: float = 0.0,
             key: Optional[jax.Array] = None,
             capacity: Optional[int] = None) -> jax.Array:
    """Greedy (temperature=0) or sampled generation.  prompt: (B, S)."""
    B, S = prompt.shape
    capacity = capacity or (S + max_new_tokens)
    prefill_jit, step_jit = _compiled_pair(cfg, ctx, capacity)
    logits, caches = prefill_jit(params, tokens=prompt)

    def sample(lg, k):
        if temperature <= 0:
            return jnp.argmax(lg, axis=-1)
        return jax.random.categorical(k, lg / temperature, axis=-1)

    key = key if key is not None else jax.random.PRNGKey(0)
    toks = []
    cur = sample(logits, key)[:, None]
    toks.append(cur)
    for i in range(1, max_new_tokens):
        key, sub = jax.random.split(key)
        logits, caches = step_jit(params, tokens=cur,
                                  pos=jnp.asarray(S + i - 1), caches=caches)
        cur = sample(logits, sub)[:, None]
        toks.append(cur)
    return jnp.concatenate(toks, axis=1)
