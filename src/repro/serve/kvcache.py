"""KV/SSM cache utilities: sizes, shardings, and budget accounting.

The cache *layout* lives with the blocks (models/layers.py AttnCache ring
buffer, models/ssm.py recurrent states); this module provides the serving-
level bookkeeping used by launch/dryrun and the benchmarks."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import (
    ATTN_GLOBAL,
    ATTN_LOCAL,
    BLOCK_MAMBA,
    BLOCK_MLSTM,
    BLOCK_SHARED_ATTN,
    BLOCK_SLSTM,
    ModelConfig,
)
from repro.models import transformer
from repro.models.ssm import mamba_dims, mlstm_dims


def cache_bytes(cfg: ModelConfig, batch: int, context_len: int,
                long_ctx: bool = False, bytes_per_el: int = 2) -> int:
    """Total cache bytes across all layers (analytic, matches init_caches)."""
    total = 0
    R = cfg.pattern_repeats
    for kind in cfg.block_pattern:
        if kind in (ATTN_GLOBAL, ATTN_LOCAL, BLOCK_SHARED_ATTN):
            w = transformer.effective_window(cfg, kind, long_ctx)
            cap = min(w, context_len) if w else context_len
            total += R * 2 * batch * cap * cfg.kv_dim * bytes_per_el
        elif kind == BLOCK_MAMBA:
            din, H, P = mamba_dims(cfg)
            N = cfg.ssm_state
            total += R * batch * (H * P * N + (cfg.ssm_conv_width - 1)
                                  * (din + 2 * N)) * 4
        elif kind == BLOCK_MLSTM:
            din, H, P = mlstm_dims(cfg)
            total += R * batch * (H * P * P + H * P + H) * 4
        elif kind == BLOCK_SLSTM:
            total += R * batch * 4 * cfg.d_model * 4
    return total


def describe(cfg: ModelConfig, batch: int, context_len: int,
             long_ctx: bool = False) -> Dict[str, float]:
    b = cache_bytes(cfg, batch, context_len, long_ctx)
    return {"cache_gb": b / 2**30,
            "cache_gb_per_chip_256": b / 2**30 / 256,
            "long_ctx": long_ctx}
