"""KV/SSM cache utilities: sizes, shardings, and budget accounting.

The cache *layout* lives with the blocks (models/layers.py AttnCache ring
buffer / PagedKVState page pools, models/ssm.py recurrent states); this
module provides the serving-level bookkeeping: analytic byte budgets
(``cache_bytes`` / ``paged_cache_bytes``, test-pinned to the actual
``init_caches`` / ``init_paged_caches`` buffer sizes) and the
:class:`PagedKVCache` free-list allocator the serving engine schedules
against."""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import (
    ATTN_GLOBAL,
    ATTN_LOCAL,
    BLOCK_MAMBA,
    BLOCK_MLSTM,
    BLOCK_SHARED_ATTN,
    BLOCK_SLSTM,
    ModelConfig,
)
from repro.models import transformer
from repro.models.ssm import mamba_dims, mlstm_dims


def cache_bytes(cfg: ModelConfig, batch: int, context_len: int,
                long_ctx: bool = False, bytes_per_el: int = 2) -> int:
    """Total cache bytes across all layers (analytic, matches init_caches)."""
    total = 0
    R = cfg.pattern_repeats
    for kind in cfg.block_pattern:
        if kind in (ATTN_GLOBAL, ATTN_LOCAL, BLOCK_SHARED_ATTN):
            w = transformer.effective_window(cfg, kind, long_ctx)
            cap = min(w, context_len) if w else context_len
            total += R * 2 * batch * cap * cfg.kv_dim * bytes_per_el
        elif kind == BLOCK_MAMBA:
            din, H, P = mamba_dims(cfg)
            N = cfg.ssm_state
            total += R * batch * (H * P * N + (cfg.ssm_conv_width - 1)
                                  * (din + 2 * N)) * 4
        elif kind == BLOCK_MLSTM:
            din, H, P = mlstm_dims(cfg)
            total += R * batch * (H * P * P + H * P + H) * 4
        elif kind == BLOCK_SLSTM:
            total += R * batch * 4 * cfg.d_model * 4
    return total


def paged_cache_bytes(cfg: ModelConfig, num_pages: int, page_size: int,
                      bytes_per_el: int = 2) -> int:
    """Total bytes of the physical page pools across all attention layers
    (analytic, matches ``transformer.init_paged_caches`` pool buffers —
    the page-table/length bookkeeping is excluded, same as the dense
    ``cache_bytes`` excludes ``AttnCache.length``)."""
    n_attn = sum(1 for kind in cfg.block_pattern
                 if kind in (ATTN_GLOBAL, ATTN_LOCAL, BLOCK_SHARED_ATTN))
    return (n_attn * cfg.pattern_repeats * 2 * num_pages * page_size
            * cfg.kv_dim * bytes_per_el)


def describe(cfg: ModelConfig, batch: int, context_len: int,
             long_ctx: bool = False) -> Dict[str, float]:
    b = cache_bytes(cfg, batch, context_len, long_ctx)
    return {"cache_gb": b / 2**30,
            "cache_gb_per_chip_256": b / 2**30 / 256,
            "long_ctx": long_ctx}


# ---------------------------------------------------------------------------
# paged KV cache: free-list page allocator (host-side bookkeeping)
# ---------------------------------------------------------------------------
class PagedKVCache:
    """Free-list allocator over a pool of ``num_pages`` KV pages.

    This is the HOST side of the paged cache: it hands out physical page
    ids and tracks per-request page lists; the device side (the actual
    pools, one per attention layer) is ``models.layers.PagedKVState``,
    whose page tables the serving engine refreshes from this bookkeeping
    every step.

    Page 0 is reserved as the NULL page: idle batch slots point their whole
    page-table row at it, so their (masked, never-attended) decode writes
    land somewhere harmless.  Eviction is cooperative — the engine picks a
    victim and calls :meth:`free`; the freed pages return to the free list
    immediately (restart-on-preempt semantics, so no copy-out is needed).
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError(f"need >= 2 pages (1 reserved), got {num_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free = deque(range(1, num_pages))      # page 0 = null page
        self._owned: Dict[int, List[int]] = {}       # rid -> page ids

    # ---- queries ----
    @property
    def n_free(self) -> int:
        return len(self._free)

    def n_owned(self, rid: int) -> int:
        return len(self._owned.get(rid, ()))

    def utilization(self) -> float:
        usable = self.num_pages - 1
        return (usable - len(self._free)) / max(usable, 1)

    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` positions."""
        return -(-tokens // self.page_size)

    # ---- allocation ----
    def alloc(self, rid: int, n: int) -> Optional[List[int]]:
        """Grab ``n`` fresh pages for ``rid``; None (nothing allocated) when
        the free list can't cover it."""
        if n > len(self._free):
            return None
        got = [self._free.popleft() for _ in range(n)]
        self._owned.setdefault(rid, []).extend(got)
        return got

    def ensure(self, rid: int, n_total: int) -> bool:
        """Grow ``rid``'s allocation to ``n_total`` pages (no-op when it
        already owns enough).  False (and no change) when the pool is dry."""
        need = n_total - self.n_owned(rid)
        if need <= 0:
            return True
        return self.alloc(rid, need) is not None

    def free(self, rid: int) -> int:
        """Return all of ``rid``'s pages to the free list."""
        pages = self._owned.pop(rid, [])
        self._free.extend(pages)
        return len(pages)

    def page_row(self, rid: int, width: int) -> np.ndarray:
        """``rid``'s page-table row, padded to ``width`` with the null
        page (prefill/decode writes past the allocated tail land there)."""
        pages = self._owned.get(rid, [])
        row = np.zeros((width,), np.int32)
        row[:len(pages)] = pages[:width]
        return row
