"""``repro.api`` — the declarative run-assembly layer.

Three lines from spec to training (the paper's §4 framework composition of
data handling, compute and synchronous communication behind one interface):

    from repro.api import RunSpec, compile_run
    run = compile_run(RunSpec(arch="vgg-a", smoke=True, parallel="zero1"))
    run.fit()

``RunSpec`` declares the run (arch, mesh topology, parallelism mode, comm
knobs, optimizer, trainer settings); ``compile_run`` resolves the model
family through the adapter registry, builds the mesh, places params, picks
the update path (serial / dp / explicit-bucketed zero1 / GSPMD zero1 /
stale-sync / gossip — what each mode accepts is the declarative
``MODE_CAPS`` table) and returns a ready :class:`Run`.  New model families plug in with
``register_family``; the stable low-level layer (``make_train_step``,
``make_distributed_update``) is unchanged underneath.

Serving mirrors the same seam:

    from repro.api import ServeSpec, compile_serve
    server = compile_serve(ServeSpec(arch="llama3-8b", smoke=True))
    rid = server.submit([1, 2, 3]); out = server.drain()

``ServeSpec`` declares the deployment (arch, batch/page/capacity budgets,
scheduler policy, sampling); ``compile_serve`` validates the arch, builds
the paged KV pools and returns a live continuous-batching :class:`Server`.
"""
from repro.api.assemble import compile_run, compile_serve  # noqa: F401
from repro.api.families import FamilyAdapter, adapter_for, families, register_family  # noqa: F401
from repro.api.run import Run  # noqa: F401
from repro.api.serve import Request, Server  # noqa: F401
from repro.api.spec import (  # noqa: F401
    MIB,
    MODE_CAPS,
    OPTIMIZERS,
    PAGED_ATTN_IMPLS,
    PARALLEL_MODES,
    SCHEDULER_POLICIES,
    SCHEDULES,
    MeshSpec,
    ModeCaps,
    RunSpec,
    ServeSpec,
    TelemetrySpec,
)
