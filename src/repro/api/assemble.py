"""``compile_run``: RunSpec -> Run, ``compile_serve``: ServeSpec -> Server.
The one place run/deployment assembly happens.

Resolution order:

1. arch id -> config (``configs.get_config``), optionally reduced to the
   family smoke variant;
2. config -> :class:`~repro.api.families.FamilyAdapter` (the registry that
   replaced the per-call-site ``isinstance`` dispatch);
3. mesh from the ``MeshSpec`` topology (none for ``serial``), params
   initialized and placed by the logical-axis sharding rules;
4. parallelism mode -> update path: plain ``optimizer.update`` (serial/dp),
   with ``comm="auto"`` resolved FIRST — the telemetry autotuner times the
   real per-bucket collectives on the live mesh and picks bucket size /
   backend from the §3.2 balance model with measured constants
   (``repro.telemetry.autotune``; it must run before ``init_fn`` because
   the ZeRO-1 strip layout depends on the bucket plan) — then
   the explicit bucketed §3.4 phase pipeline of ``repro.comm`` +
   ``optim.dist.UpdatePlan`` (``zero1`` — monolithic reduce/apply/broadcast,
   or the §3.1 backprop-overlapped bubble schedule when
   ``CommConfig.overlap`` is set; ``stale-sync`` — the same pipeline with
   the reduce consumed one step late; ``gossip`` — the same pipeline with
   the reduce phase on the GossipGraD partner-exchange backend, flat
   schedule by default so the rotation spans the whole group; in every
   case the schedules drive the collective backend named by
   ``CommConfig.backend``), or GSPMD-sharded optimizer state
   (``zero1-gspmd``);
5. ``make_train_step`` (or ``make_overlapped_train_step``) glues loss ->
   grads -> update into the jit-ready step the returned
   :class:`~repro.api.run.Run` carries.

ROADMAP follow-ons (async modes, multi-backend collectives) plug in at
step 4 without touching any launcher — the bucket-autotuning hook already
does (``comm="auto"``).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.api.families import FamilyAdapter, adapter_for
from repro.api.run import Run
from repro.api.serve import Server
from repro.api.spec import MODE_CAPS, RunSpec, ServeSpec
from repro.comm.bucketer import CommConfig
from repro.configs import get_config, smoke_variant
from repro.core.params import Spec
from repro.core.sharding import ShardingCtx, ShardingRules
from repro.launch.mesh import make_cluster_mesh, make_host_mesh
from repro.optim import AdamW, MomentumSGD, constant, linear_scale_warmup, warmup_cosine
from repro.optim.dist import (
    make_distributed_update,
    make_overlapped_update,
    make_stale_sync_update,
    make_topk_ef_update,
)
from repro.telemetry import autotune_comm, make_recorder
from repro.train import make_overlapped_train_step, make_train_step, zero1_state_shardings


def _resolve_config(spec: RunSpec):
    cfg = get_config(spec.arch) if isinstance(spec.arch, str) else spec.arch
    return smoke_variant(cfg) if spec.smoke else cfg


def _make_optimizer(spec: RunSpec, family: FamilyAdapter):
    name = spec.optimizer or family.default_optimizer
    wd = spec.weight_decay
    if name == "adamw":
        return AdamW(weight_decay=0.01 if wd is None else wd)
    return MomentumSGD(momentum=spec.momentum,
                       weight_decay=0.0 if wd is None else wd)


def _make_schedule(spec: RunSpec, data_ways: int = 1):
    if spec.schedule == "constant":
        return constant(spec.lr)
    warmup = spec.warmup_steps if spec.warmup_steps is not None \
        else max(spec.steps // 20, 1)
    if spec.schedule == "linear-scale-warmup":
        # Goyal et al.: peak LR scales with the global data-parallel ways
        # (the G members splitting the global batch), gradual warmup from
        # the unscaled base LR
        return linear_scale_warmup(spec.lr, data_ways, warmup, spec.steps)
    return warmup_cosine(spec.lr, warmup, spec.steps)


def _place_params(params, family: FamilyAdapter, cfg, mesh: Mesh,
                  rules: ShardingRules):
    shardings = jax.tree.map(
        lambda s: rules.sharding(s.axes, s.shape, mesh),
        family.param_specs(cfg),
        is_leaf=lambda x: isinstance(x, Spec))
    return jax.tree.map(jax.device_put, params, shardings)


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel group axes actually present on the mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def compile_run(spec: RunSpec, rules: Optional[ShardingRules] = None) -> Run:
    """Assemble a ready-to-train :class:`Run` from a declarative ``spec``.

    ``rules`` overrides the logical-axis sharding rule table (defaults to
    the paper-faithful hybrid-parallel rules).
    """
    cfg = _resolve_config(spec)
    family = adapter_for(cfg)
    telemetry = make_recorder(spec.telemetry)

    mesh = None
    if spec.parallel != "serial":
        if spec.mesh.cluster:
            mesh = make_cluster_mesh(spec.mesh.model_ways)
        else:
            mesh = make_host_mesh(spec.mesh.model_ways, pods=spec.mesh.pods)
    rules = rules if rules is not None else ShardingRules()
    ctx = ShardingCtx(mesh, rules)
    loss_fn = family.make_loss(cfg, ctx)

    params = family.init(cfg, jax.random.PRNGKey(spec.seed))
    if mesh is not None:
        params = _place_params(params, family, cfg, mesh, rules)

    optimizer = _make_optimizer(spec, family)
    data_ways = 1
    if mesh is not None:
        for a in _data_axes(mesh):
            data_ways *= mesh.shape[a]
    lr_schedule = _make_schedule(spec, data_ways)

    dist_update = None
    train_step = None
    comm = None
    if spec.parallel in ("zero1", "stale-sync", "gossip"):
        axes = _data_axes(mesh)
        if spec.parallel == "gossip":
            # flat on purpose: hierarchical would scope the partner
            # rotation to each pod (and the in-pod group of a 1-pod-per-
            # host cluster is a single member — full sync, no gossip)
            default = CommConfig(backend="gossip", hierarchical=False)
        else:
            default = CommConfig(hierarchical=len(axes) == 2)
        if spec.comm == "auto":
            # measured-feedback autotune — BEFORE init_fn: the ZeRO-1
            # strip layout depends on the bucket plan and
            # checkpoint.replan refuses mid-run bucket changes
            reps = getattr(spec.telemetry, "autotune_reps", 2)
            import os as _os

            from repro.telemetry.autotune import ENV_AUTOTUNE_CACHE
            with telemetry.span("autotune", mode=spec.parallel):
                comm = autotune_comm(
                    params, mesh, axes, default, recorder=telemetry,
                    backends=MODE_CAPS[spec.parallel].backends, reps=reps,
                    wire_formats=MODE_CAPS[spec.parallel].wire_formats,
                    cache_path=_os.environ.get(ENV_AUTOTUNE_CACHE))
        elif spec.comm is not None:
            comm = spec.comm
        else:
            comm = default
        if spec.parallel == "stale-sync":
            init_fn, dist_update = make_stale_sync_update(
                optimizer, mesh, data_axes=axes, comm=comm)
            opt_state = init_fn(params)
        elif comm.wire_format == "topk":
            # spec validation pinned this to the monolithic zero1 pipeline
            # (no overlap, no stale-sync, no gossip): the error-feedback
            # residual needs the strip-state carry of the EF composition
            init_fn, dist_update = make_topk_ef_update(
                optimizer, mesh, data_axes=axes, comm=comm)
            opt_state = init_fn(params)
        elif comm.overlap:
            # §3.1 bubble schedule: the whole step runs in one shard_map and
            # each bucket's part-reduce is issued inside the backward pass
            # (comm hooks), so the loss must be the mesh-free local loss —
            # GSPMD constraints do not apply inside shard_map
            if spec.mesh.model_ways > 1:
                raise ValueError(
                    "CommConfig.overlap runs the whole step inside a "
                    "shard_map over the data axes with a mesh-free loss — "
                    "a model axis would be silently replicated (full "
                    "redundant compute per model member), so overlap "
                    "currently requires model_ways == 1 "
                    f"(got model_ways={spec.mesh.model_ways})")
            init_fn, local_update = make_overlapped_update(
                optimizer, mesh, data_axes=axes, comm=comm)
            opt_state = init_fn(params)
            train_step = make_overlapped_train_step(
                family.make_loss(cfg, ShardingCtx()), lr_schedule, mesh,
                axes, comm, local_update, grad_clip=spec.grad_clip)
        else:
            init_fn, dist_update = make_distributed_update(
                optimizer, mesh, data_axes=axes, comm=comm)
            opt_state = init_fn(params)
    elif spec.parallel == "zero1-gspmd":
        opt_state = optimizer.init(params)
        st_sh = zero1_state_shardings(opt_state, family.param_axes(cfg),
                                      mesh, rules)
        opt_state = jax.tree.map(jax.device_put, opt_state, st_sh)
    else:
        opt_state = optimizer.init(params)

    if train_step is None:
        train_step = make_train_step(loss_fn, optimizer, lr_schedule,
                                     grad_clip=spec.grad_clip,
                                     dist_update=dist_update)
    return Run(spec=spec, cfg=cfg, family=family, mesh=mesh, rules=rules,
               ctx=ctx, loss_fn=loss_fn, optimizer=optimizer,
               lr_schedule=lr_schedule, train_step=train_step,
               params=params, opt_state=opt_state, comm=comm,
               telemetry=telemetry)


def compile_serve(spec: ServeSpec, params=None,
                  rules: Optional[ShardingRules] = None,
                  recorder=None) -> Server:
    """Assemble a live :class:`~repro.api.serve.Server` from a declarative
    ``spec`` (the serving twin of ``compile_run``).

    ``params`` lets a caller serve trained weights (e.g. ``run.params``
    after training); ``None`` initializes fresh ones from ``spec.seed``.
    ``recorder`` attaches a telemetry Recorder — prefill/decode/preempt
    become spans; latency histograms are always on regardless.
    Paged decode covers the attention block kinds only, so non-transformer
    families, modality frontends, M-RoPE, and codebook heads are rejected
    here — before any buffer is allocated.
    """
    from repro.configs.base import ModelConfig
    from repro.models import transformer
    from repro.models.transformer import ATTN_KINDS

    cfg = get_config(spec.arch) if isinstance(spec.arch, str) else spec.arch
    cfg = smoke_variant(cfg) if spec.smoke else cfg
    if not isinstance(cfg, ModelConfig):
        raise ValueError(
            f"compile_serve needs a token LM ModelConfig, got "
            f"{type(cfg).__name__} — serving covers the transformer family "
            "only")
    bad = [k for k in cfg.block_pattern if k not in ATTN_KINDS]
    if bad:
        raise ValueError(
            f"paged decode serves attention blocks only ({ATTN_KINDS}); "
            f"{cfg.name!r} has {bad} in its pattern")
    if cfg.frontend is not None or cfg.num_codebooks or cfg.mrope:
        raise ValueError(
            f"{cfg.name!r} uses a modality frontend / codebook heads / "
            "M-RoPE — token-in/token-out archs only for serving")

    ctx = ShardingCtx(None, rules if rules is not None else ShardingRules())
    if params is None:
        params = transformer.init_params(cfg, jax.random.PRNGKey(spec.seed))
    return Server(spec=spec, cfg=cfg, ctx=ctx, params=params,
                  recorder=recorder)
