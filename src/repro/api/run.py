"""The executable product of ``compile_run``: params, state, step, data, fit.

A :class:`Run` owns everything a training loop needs, already assembled and
placed: the jit-ready ``train_step``, the (mesh-placed) ``params`` and
``opt_state``, a lazily-started prefetching ``data`` iterator, and ``fit()``
— the paper's §4 composition of data handling, compute and communication
behind one object.  The low-level layers (``make_train_step``,
``make_distributed_update``) stay public and stable underneath; a Run is
just their assembly.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
from jax.sharding import Mesh

from repro.core.sharding import ShardingCtx, ShardingRules
from repro.data.pipeline import Prefetcher, make_placer
from repro.train.trainer import Trainer, TrainerConfig


@dataclass
class Run:
    """An assembled training run.  Mutated in place by ``fit`` (params and
    opt_state advance; the train_step buffers are donated)."""
    spec: Any                       # the RunSpec this run was compiled from
    cfg: Any                        # resolved (possibly smoke) family config
    family: Any                     # FamilyAdapter
    mesh: Optional[Mesh]
    rules: ShardingRules
    ctx: ShardingCtx
    loss_fn: Callable
    optimizer: Any
    lr_schedule: Callable
    train_step: Callable            # (params, opt_state, step, batch) -> ...
    params: Any
    opt_state: Any
    _data: Optional[Prefetcher] = field(default=None, repr=False)

    def _mesh_scope(self):
        return (jax.set_mesh(self.mesh) if self.mesh is not None
                else contextlib.nullcontext())

    @property
    def data(self) -> Prefetcher:
        """Background-prefetching batch iterator, device-placed for the
        run's mesh.  Created on first access (so compiling a Run never
        starts threads)."""
        if self._data is None:
            s = self.spec
            stream = self.family.stream(self.cfg, s.batch, s.seq, s.seed)
            self._data = Prefetcher(stream,
                                    place=make_placer(self.mesh, self.rules))
        return self._data

    def step(self, batch, step_idx: int = 0):
        """Run one (jit) train step on an explicit batch; advances the run's
        params/opt_state and returns the metrics dict."""
        with self._mesh_scope():
            self.params, self.opt_state, metrics = jax.jit(self.train_step)(
                self.params, self.opt_state, step_idx, batch)
        return metrics

    def fit(self, start_step: int = 0, log_fn=print):
        """Train for ``spec.steps`` steps; returns the metrics history."""
        s = self.spec
        tcfg = TrainerConfig(total_steps=s.steps, log_every=s.log_every,
                             ckpt_every=s.ckpt_every, ckpt_dir=s.ckpt_dir)
        trainer = Trainer(self.train_step, tcfg)
        with self._mesh_scope():
            self.params, self.opt_state, history = trainer.fit(
                self.params, self.opt_state, self.data,
                start_step=start_step, log_fn=log_fn)
        return history

    def close(self):
        if self._data is not None:
            self._data.close()
            self._data = None

    def __enter__(self) -> "Run":
        return self

    def __exit__(self, *exc):
        self.close()
