"""The executable product of ``compile_run``: params, state, step, data, fit.

A :class:`Run` owns everything a training loop needs, already assembled and
placed: the jit-ready ``train_step``, the (mesh-placed) ``params`` and
``opt_state``, a lazily-started prefetching ``data`` iterator, and ``fit()``
— the paper's §4 composition of data handling, compute and communication
behind one object.  The low-level layers (``make_train_step``,
``make_distributed_update``) stay public and stable underneath; a Run is
just their assembly.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.checkpoint import ckpt as ckpt_lib
from repro.core.sharding import ShardingCtx, ShardingRules
from repro.data.pipeline import Prefetcher, make_placer
from repro.train.trainer import Trainer, TrainerConfig


@dataclass
class Run:
    """An assembled training run.  Mutated in place by ``fit`` (params and
    opt_state advance; the train_step buffers are donated)."""
    spec: Any                       # the RunSpec this run was compiled from
    cfg: Any                        # resolved (possibly smoke) family config
    family: Any                     # FamilyAdapter
    mesh: Optional[Mesh]
    rules: ShardingRules
    ctx: ShardingCtx
    loss_fn: Callable
    optimizer: Any
    lr_schedule: Callable
    train_step: Callable            # (params, opt_state, step, batch) -> ...
    params: Any
    opt_state: Any
    comm: Optional[Any] = None      # the RESOLVED CommConfig of an explicit
    #                                 bucketed run (zero1/stale-sync/gossip;
    #                                 None for other modes) — needed to
    #                                 re-plan strip state across world sizes
    telemetry: Optional[Any] = None  # the run's telemetry Recorder
    #                                 (repro.telemetry): trainer phases are
    #                                 spans, listeners (cluster heartbeat,
    #                                 sinks) ride its events; ``close``
    #                                 finalizes it and exports the Chrome
    #                                 trace when the spec set a trace_dir.
    #                                 None (hand-built Runs) = no-op.
    _data: Optional[Prefetcher] = field(default=None, repr=False)
    _jit_step: Optional[Callable] = field(default=None, repr=False)
    _warm: bool = field(default=False, repr=False)  # jit_step executed once

    def _mesh_scope(self):
        return (jax.set_mesh(self.mesh) if self.mesh is not None
                else contextlib.nullcontext())

    def _make_data(self, skip: int = 0) -> Prefetcher:
        """Fresh prefetcher over the family's seeded stream, with ``skip``
        batches consumed HOST-side first (raw iterator — no device placement
        for batches that are immediately discarded; resume at step 100k must
        not pay 100k device_puts).  A finite stream shorter than ``skip``
        simply ends — the Prefetcher sentinel then stops the training loop
        on its first draw."""
        s = self.spec
        stream = self.family.stream(self.cfg, s.batch, s.seq, s.seed)
        for _ in range(skip):
            try:
                next(stream)
            except StopIteration:
                break
        return Prefetcher(stream, place=make_placer(self.mesh, self.rules))

    @property
    def data(self) -> Prefetcher:
        """Background-prefetching batch iterator, device-placed for the
        run's mesh.  Created on first access (so compiling a Run never
        starts threads)."""
        if self._data is None:
            self._data = self._make_data()
        return self._data

    @property
    def jit_step(self) -> Callable:
        """THE jitted train step — one compile cache, buffers donated.
        ``step()`` and ``fit()`` both go through it: jitting per call site
        (the old ``step`` re-wrapped without ``donate_argnums``) built two
        compile caches and kept an undonated copy of the params alive,
        doubling peak param memory when mixing the two."""
        if self._jit_step is None:
            self._jit_step = jax.jit(self.train_step, donate_argnums=(0, 1))
        return self._jit_step

    def step(self, batch, step_idx: int = 0):
        """Run one (jit) train step on an explicit batch; advances the run's
        params/opt_state and returns the metrics dict."""
        with self._mesh_scope():
            self.params, self.opt_state, metrics = self.jit_step(
                self.params, self.opt_state, step_idx, batch)
        self._warm = True
        return metrics

    def _zero1_world(self):
        """This run's zero1 world layout (the ``checkpoint.replan`` meta
        record), or None when the run has no strip state."""
        if self.comm is None or self.mesh is None:
            return None
        from repro.checkpoint.replan import world_meta
        axes = tuple(a for a in ("pod", "data")
                     if a in self.mesh.axis_names)
        return world_meta([self.mesh.shape[a] for a in axes],
                          self.comm.hierarchical, self.comm.bucket_bytes)

    def _ckpt_meta(self):
        world = self._zero1_world()
        return {"zero1": world} if world is not None else None

    def _restore_replan(self, step: int, template=None):
        """Strict restore failed on shape: the checkpoint was saved at a
        different world size.  Re-plan the strip opt_state for THIS world
        (see ``checkpoint.replan`` for why this is exact); params are
        replicated, so their shapes never depend on G and restore
        strictly.  ``template`` is the opt_state tree to restore into —
        defaults to the run's own; the stale-sync interop path passes the
        INNER zero1 template when the checkpoint has the bare layout."""
        from repro.checkpoint.replan import replan_strip_state
        from repro.comm.bucketer import plan_buckets
        template = self.opt_state if template is None else template
        wrap = None
        if isinstance(template, dict) and set(template) == {"residual",
                                                            "zero1"}:
            # topk error-feedback wrapper: the residual is member-LOCAL
            # unsent gradient mass sized by the OLD world's padded buckets
            # — old members' mass has no owner in the new world, so there
            # is no exact conversion.  Replan only the inner strips (the
            # sub-template keeps the checkpoint's ``opt_state:zero1/...``
            # key paths) and restart the residual at zero: one step of
            # stiffer sparsification, the stale-buffer re-init trade.
            template = {"zero1": template["zero1"]}
            wrap = self._reinit_residual
        new_world = self._zero1_world()
        old_world = ckpt_lib.read_manifest(
            self.spec.ckpt_dir, step)["meta"].get("zero1")
        if new_world is None or old_world is None:
            raise ValueError(
                f"checkpoint step {step} does not match this run's shapes "
                "and carries no zero1 world meta to re-plan from")
        trees, _ = ckpt_lib.restore(self.spec.ckpt_dir, step,
                                    params=self.params)
        old_leaves = ckpt_lib.restore_loose(self.spec.ckpt_dir, step,
                                            "opt_state", template)
        plan = plan_buckets(self.params, new_world["G"],
                            self.comm.bucket_bytes)
        trees["opt_state"] = replan_strip_state(
            template, old_leaves, plan, old_world, new_world)
        if wrap is not None:
            trees["opt_state"] = wrap(trees["opt_state"]["zero1"])
        return trees

    def _stale_wrapped(self) -> bool:
        """True when this run's opt_state is the stale-sync wrapper dict
        around the inner zero1 strip state."""
        return (isinstance(self.opt_state, dict)
                and set(self.opt_state) == {"stale", "synced", "zero1"})

    def _reinit_stale(self, inner):
        """Wrap a restored INNER zero1 strip state for a stale-sync run:
        fresh zero staleness buffer, ``synced=0`` so the first resumed step
        applies its own reduce instead of garbage (see
        ``optim.dist.make_stale_sync_update``)."""
        return {"stale": tuple(jnp.zeros_like(s)
                               for s in self.opt_state["stale"]),
                "synced": jnp.zeros((), jnp.int32),
                "zero1": inner}

    def _ef_wrapped(self) -> bool:
        """True when this run's opt_state is the topk error-feedback
        wrapper dict around the inner zero1 strip state."""
        return (isinstance(self.opt_state, dict)
                and set(self.opt_state) == {"residual", "zero1"})

    def _reinit_residual(self, inner):
        """Wrap a restored INNER zero1 strip state for a topk EF run with a
        zero residual (this world's bucket shapes — the carried mass of a
        bare or foreign-world checkpoint is unrecoverable; see
        ``optim.dist.make_topk_ef_update``)."""
        return {"residual": tuple(jnp.zeros_like(r)
                                  for r in self.opt_state["residual"]),
                "zero1": inner}

    def restore(self, step: int):
        """Load checkpoint ``step`` from ``spec.ckpt_dir`` and place the
        restored trees back onto this run's shardings (zero1 strip
        opt_state lands on its data-axis strips, not unplaced on device 0).
        A zero1 checkpoint saved at a DIFFERENT world size is re-planned
        (``checkpoint.replan``) instead of rejected — the elastic
        shrink-and-resume path.  A stale-sync or topk-EF run additionally
        accepts a BARE zero1 checkpoint (the strip layouts are identical by
        construction): the inner state restores and the wrapper buffer
        (staleness carry / error-feedback residual) re-initializes, costing
        one synchronous / one stiffer-sparsified step on resume."""
        opt_tpl, wrap = self.opt_state, None
        if self._stale_wrapped() or self._ef_wrapped():
            keys = ckpt_lib.read_manifest(
                self.spec.ckpt_dir, step)["trees"].get("opt_state", ())
            if not any(k.startswith("opt_state:zero1/") for k in keys):
                opt_tpl = self.opt_state["zero1"]
                wrap = (self._reinit_stale if self._stale_wrapped()
                        else self._reinit_residual)
        try:
            trees, _ = ckpt_lib.restore(self.spec.ckpt_dir, step,
                                        params=self.params,
                                        opt_state=opt_tpl)
        except ValueError:
            trees = self._restore_replan(step, template=opt_tpl)
        if wrap is not None:
            trees["opt_state"] = wrap(trees["opt_state"])
        placed = jax.tree.map(
            lambda cur, new: jax.device_put(new, cur.sharding),
            {"params": self.params, "opt_state": self.opt_state}, trees)
        self.params, self.opt_state = placed["params"], placed["opt_state"]

    def fit(self, start_step: Optional[int] = None, log_fn=print):
        """Train for ``spec.steps`` steps; returns the metrics history.

        ``start_step=None`` (the default) resumes from the latest checkpoint
        in ``spec.ckpt_dir`` when one exists — params and opt_state are
        restored onto the run's shardings and the (deterministic, seeded)
        data stream is fast-forwarded one batch per completed step so the
        trajectory continues exactly where the interrupted run left off.
        Pass ``start_step=0`` to force a fresh run.  Per-step hooks attach
        to ``run.telemetry`` (``add_listener``) — every trainer phase is an
        event; the cluster launcher's heartbeat listens for the "step"
        span, which replaced the old bare ``on_step`` callback."""
        s = self.spec
        if start_step is None:
            start_step = 0
            if s.ckpt_dir:
                latest = ckpt_lib.latest_step(s.ckpt_dir)
                if latest is not None:
                    self.restore(latest)
                    start_step = latest
                    log_fn(f"resuming from checkpoint step {latest} "
                           f"({s.ckpt_dir})")
                    if latest < s.steps:
                        # re-align the data stream: drop any cached
                        # (already advanced) prefetcher and rebuild with
                        # one host-side skip per completed step.  Close the
                        # prefetcher directly — ``self.close()`` would also
                        # finalize the telemetry recorder mid-fit.
                        if self._data is not None:
                            self._data.close()
                        self._data = self._make_data(skip=latest)
        if start_step >= s.steps:
            # nothing to train (checkpoint at or past --steps): don't spin
            # up the prefetch thread / device-place batches for a no-op
            return []
        tcfg = TrainerConfig(total_steps=s.steps, log_every=s.log_every,
                             ckpt_every=s.ckpt_every, ckpt_dir=s.ckpt_dir,
                             ckpt_meta=self._ckpt_meta(),
                             recorder=self.telemetry)
        trainer = Trainer(self.jit_step, tcfg, jit=False, warm=self._warm)
        with self._mesh_scope():
            self.params, self.opt_state, history = trainer.fit(
                self.params, self.opt_state, self.data,
                start_step=start_step, log_fn=log_fn)
        if history:
            # the first executed step always logs, so non-empty history ==
            # jit_step has really run (a source that dies before step one
            # must NOT mark the cache warm)
            self._warm = True
        return history

    def close(self):
        if self._data is not None:
            self._data.close()
            self._data = None
        rec = self.telemetry
        if rec is not None and getattr(rec, "enabled", False):
            rec.close()
            if rec.trace_dir:
                # single-process runs merge their own Chrome trace; cluster
                # workers leave the merge to the supervisor, which sees every
                # process's trace_p*.jsonl
                from repro.cluster.spec import in_worker
                if not in_worker():
                    from repro.telemetry import merge_process_traces
                    merge_process_traces(rec.trace_dir)

    def __enter__(self) -> "Run":
        return self

    def __exit__(self, *exc):
        self.close()
