"""The executable product of ``compile_serve``: a continuous-batching server.

A :class:`Server` owns everything request serving needs, already assembled:
ONE compiled decode executable over ``max_batch`` slots of paged KV pools
(following ``Run.jit_step`` — the seed ``generate()`` re-jitted prefill and
decode per call), one compiled prefill per padded prompt bucket, the
host-side :class:`~repro.serve.kvcache.PagedKVCache` free-list the scheduler
admits and preempts against, and the request queue.

The engine loop is ``submit() -> step() -> ... -> drain()``:

``submit``
    Admission control: bounded queue (``ServeSpec.max_queue``), prompt and
    decode budgets validated against the spec.
``step``
    One scheduler iteration.  Under the ``continuous`` policy every free
    slot is refilled from the queue whenever the page pool can hold the
    newcomer (in-flight batching); under ``static`` a wave is admitted only
    once the whole previous wave finished.  Newly admitted requests are
    prefilled (dense causal prefill, packed into their pages) and every
    active slot then advances one token through the single jitted paged
    decode step.  If a slot's next token needs a page the pool can't
    provide, the YOUNGEST active request is preempted — its pages return to
    the free list and it restarts from the queue front
    (restart-on-preempt; deterministic sampling regenerates its tokens).
``drain``
    Step until queue and slots are empty; returns the completed requests.

Idle slots point their page-table row at the reserved null page and their
(discarded) decode writes land there — the decode executable's shape never
changes, so continuous batching costs zero recompiles.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.sharding import ShardingCtx
from repro.models import layers, transformer
from repro.serve.kvcache import PagedKVCache
from repro.telemetry.events import NULL_RECORDER
from repro.telemetry.metrics import Histogram


def _sample(logits: jax.Array, temperature: float, key: jax.Array):
    """Greedy (temperature <= 0) or categorical over (..., V) logits."""
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature, axis=-1
    ).astype(jnp.int32)


@dataclass
class Request:
    """One generation request and its lifecycle bookkeeping (wall-clock
    times from ``time.perf_counter``; ``None`` until reached)."""
    rid: int
    prompt: np.ndarray                   # (L,) int32
    max_new: int
    submit_t: float
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    tokens: List[int] = field(default_factory=list)
    preemptions: int = 0
    admit_seq: int = -1                  # admission order (preempt youngest)

    @property
    def output(self) -> np.ndarray:
        return np.asarray(self.tokens, np.int32)

    @property
    def done(self) -> bool:
        return self.finish_t is not None

    @property
    def latency(self) -> Optional[float]:
        return None if self.finish_t is None else self.finish_t - self.submit_t


class Server:
    """An assembled serving deployment (see module docstring).  Built by
    ``repro.api.assemble.compile_serve``; not meant to be constructed by
    hand."""

    def __init__(self, spec: Any, cfg: Any, ctx: ShardingCtx, params: Any,
                 recorder: Any = None):
        self.spec = spec
        self.cfg = cfg
        self.ctx = ctx
        self.params = params
        self.telemetry = recorder if recorder is not None else NULL_RECORDER
        # per-request latency histograms, always live (cheap appends):
        # TTFT = submit -> first sampled token, e2e = submit -> finish
        self._lat = {"ttft": Histogram(), "e2e": Histogram()}

        B = spec.max_batch
        n = spec.pages_per_request
        self.alloc = PagedKVCache(spec.num_pages, spec.page_size)
        self._pools = [
            (c.pages_k, c.pages_v) for c in transformer.init_paged_caches(
                cfg, B, spec.num_pages, spec.page_size, n,
                impl=spec.attn_impl)]
        self._pt = np.zeros((B, n), np.int32)
        self._lengths = np.zeros((B,), np.int32)
        self._last_tok = np.zeros((B,), np.int32)
        self._slots: List[Optional[Request]] = [None] * B
        self._queue: deque = deque()
        self._key = jax.random.PRNGKey(spec.seed)
        self._next_rid = 0
        self._admit_seq = 0
        self._decode_jit = None
        self._prefill_jits: Dict[int, Any] = {}
        self.stats = {"steps": 0, "decode_tokens": 0, "prefill_tokens": 0,
                      "preemptions": 0, "completed": 0}

    # ------------------------------------------------------------------
    # compiled executables
    # ------------------------------------------------------------------
    def _split(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    @property
    def decode_jit(self):
        """THE jitted decode step: (params, toks, lengths, page_table,
        pools, key) -> (next_tokens, pools).  One executable for the
        server's lifetime; pools are donated (replaced every step)."""
        if self._decode_jit is None:
            cfg, ctx = self.cfg, self.ctx
            impl, temp = self.spec.attn_impl, self.spec.temperature
            R = cfg.pattern_repeats

            def fn(params, toks, lengths, pt, pools, key):
                pt_s = jnp.broadcast_to(pt[None], (R,) + pt.shape)
                len_s = jnp.broadcast_to(lengths[None], (R,) + lengths.shape)
                caches = tuple(
                    layers.PagedKVState(k, v, pt_s, len_s, impl)
                    for (k, v) in pools)
                logits, _, new_caches = transformer.forward(
                    params, cfg, ctx, tokens=toks,
                    positions=lengths[:, None], caches=caches)
                tok = _sample(logits[:, -1], temp, key)
                return tok, [(c.pages_k, c.pages_v) for c in new_caches]

            self._decode_jit = jax.jit(fn, donate_argnums=(4,))
        return self._decode_jit

    def _bucket(self, length: int) -> int:
        b = self.spec.prefill_bucket
        while b < length:
            b *= 2
        return b

    def _prefill_jit(self, bucket: int):
        """Compiled prefill for one padded prompt bucket: dense causal
        prefill, pack the KV into the request's pages, sample the first
        token.  Cached per bucket — repeated prompts of similar length
        reuse the executable."""
        if bucket not in self._prefill_jits:
            cfg, ctx = self.cfg, self.ctx
            ps, temp = self.spec.page_size, self.spec.temperature
            n = self.spec.pages_per_request

            def fn(params, toks, length, page_row, pools, key):
                caches = transformer.init_caches(cfg, 1, bucket)
                logits, _, dense = transformer.forward(
                    params, cfg, ctx, tokens=toks, caches=caches,
                    update_cache=True)
                last = jax.lax.dynamic_index_in_dim(
                    logits, length - 1, axis=1, keepdims=False)   # (1, V)
                tok = _sample(last, temp, key)[0]
                pos = jnp.arange(bucket)
                lp = pos // ps
                # positions past the page-table span go to the null page;
                # garbage past `length` inside allocated pages is either
                # overwritten by decode or masked (pos < length)
                phys = jnp.where(lp < n, page_row[jnp.minimum(lp, n - 1)], 0)
                off = pos % ps
                new_pools = []
                for (kp, vp), dc in zip(pools, dense):
                    C_e = dc.k.shape[2]      # dense ring capacity this entry
                    src_k = dc.k[:, 0, pos % C_e]         # (R, bucket, H, D)
                    src_v = dc.v[:, 0, pos % C_e]
                    new_pools.append((
                        kp.at[:, phys, off].set(src_k.astype(kp.dtype)),
                        vp.at[:, phys, off].set(src_v.astype(vp.dtype))))
                return tok, new_pools

            self._prefill_jits[bucket] = jax.jit(fn, donate_argnums=(4,))
        return self._prefill_jits[bucket]

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: Optional[int] = None) -> int:
        """Queue one prompt; returns the request id.  Raises RuntimeError
        when admission control rejects (queue at ``max_queue``) and
        ValueError for prompts/budgets beyond the spec."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if not 1 <= prompt.shape[0] <= self.spec.max_prompt:
            raise ValueError(
                f"prompt length {prompt.shape[0]} outside "
                f"[1, max_prompt={self.spec.max_prompt}]")
        max_new = (self.spec.max_new_tokens if max_new_tokens is None
                   else max_new_tokens)
        if not 1 <= max_new <= self.spec.max_new_tokens:
            raise ValueError(
                f"max_new_tokens {max_new} outside "
                f"[1, max_new_tokens={self.spec.max_new_tokens}]")
        if len(self._queue) >= self.spec.max_queue:
            raise RuntimeError(
                f"admission rejected: queue at max_queue="
                f"{self.spec.max_queue}")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid=rid, prompt=prompt, max_new=max_new,
                                   submit_t=time.perf_counter()))
        return rid

    @property
    def active(self) -> List[Request]:
        return [r for r in self._slots if r is not None]

    @property
    def pending(self) -> int:
        return len(self._queue)

    def step(self) -> List[Request]:
        """One scheduler iteration: admit + prefill newcomers, advance every
        active slot one decode token.  Returns requests completed during
        this step."""
        completed: List[Request] = []
        self._admit(completed)
        active = [(b, r) for b, r in enumerate(self._slots) if r is not None]
        if not active:
            return completed
        self._ensure_pages()
        active = [(b, r) for b, r in enumerate(self._slots) if r is not None]
        with self.telemetry.span("decode", active=len(active)):
            tok, self._pools = self.decode_jit(
                self.params, jnp.asarray(self._last_tok[:, None]),
                jnp.asarray(self._lengths), jnp.asarray(self._pt),
                self._pools, self._split())
            tok = np.asarray(tok)
        self.stats["steps"] += 1
        self.stats["decode_tokens"] += len(active)
        for b, req in active:
            req.tokens.append(int(tok[b]))
            self._lengths[b] += 1
            self._last_tok[b] = tok[b]
            if len(req.tokens) >= req.max_new:
                self._finish(b, req, completed)
        return completed

    def drain(self, max_steps: Optional[int] = None) -> List[Request]:
        """Step until the queue and all slots are empty; returns every
        request completed during the drain."""
        limit = max_steps if max_steps is not None else (
            10_000 + self.spec.max_new_tokens * (
                len(self._queue) + self.spec.max_batch) * 4)
        done: List[Request] = []
        for _ in range(limit):
            if not self._queue and not self.active:
                return done
            done.extend(self.step())
        raise RuntimeError(f"drain did not converge in {limit} steps "
                           f"({len(self._queue)} queued, "
                           f"{len(self.active)} active)")

    # ------------------------------------------------------------------
    # scheduler internals
    # ------------------------------------------------------------------
    def _free_slot(self) -> Optional[int]:
        for b, r in enumerate(self._slots):
            if r is None:
                return b
        return None

    def _admit(self, completed: List[Request]):
        if self.spec.scheduler == "static" and self.active:
            return                       # wave still running: no admission
        while self._queue:
            slot = self._free_slot()
            if slot is None:
                return
            req = self._queue[0]
            L = len(req.prompt)
            if self.alloc.alloc(req.rid, self.alloc.pages_for(L + 1)) is None:
                return                   # pool can't hold it yet: wait
            self._queue.popleft()
            self._prefill_into(slot, req)
            if len(req.tokens) >= req.max_new:
                self._finish(slot, req, completed)

    def _prefill_into(self, slot: int, req: Request):
        L = len(req.prompt)
        bucket = self._bucket(L)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :L] = req.prompt
        row = self.alloc.page_row(req.rid, self.spec.pages_per_request)
        with self.telemetry.span("prefill", rid=req.rid, tokens=L,
                                 bucket=bucket):
            tok, self._pools = self._prefill_jit(bucket)(
                self.params, jnp.asarray(toks), jnp.asarray(L, jnp.int32),
                jnp.asarray(row), self._pools, self._split())
        req.admit_seq = self._admit_seq
        self._admit_seq += 1
        req.tokens = [int(tok)]
        req.first_token_t = time.perf_counter()
        self._slots[slot] = req
        self._pt[slot] = row
        self._lengths[slot] = L
        self._last_tok[slot] = req.tokens[0]
        self.stats["prefill_tokens"] += L

    def _ensure_pages(self):
        """Every active slot gets the page its next decode write needs;
        preempt the youngest active request when the pool runs dry."""
        for b in sorted((b for b, r in enumerate(self._slots)
                         if r is not None),
                        key=lambda b: self._slots[b].admit_seq):
            req = self._slots[b]
            if req is None:              # preempted by an earlier iteration
                continue
            need = self.alloc.pages_for(int(self._lengths[b]) + 1)
            while not self.alloc.ensure(req.rid, need):
                victims = [(r.admit_seq, s) for s, r in
                           enumerate(self._slots)
                           if r is not None and s != b]
                if not victims:
                    raise RuntimeError(
                        "page pool exhausted by a single request — "
                        "ServeSpec validation should have prevented this")
                self._preempt(max(victims)[1])
            self._pt[b] = self.alloc.page_row(
                req.rid, self.spec.pages_per_request)

    def _preempt(self, slot: int):
        req = self._slots[slot]
        self.alloc.free(req.rid)
        req.tokens = []
        req.first_token_t = None
        req.preemptions += 1
        req.admit_seq = -1
        self._clear_slot(slot)
        self._queue.appendleft(req)
        self.stats["preemptions"] += 1
        self.telemetry.event("preempt", rid=req.rid,
                             preemptions=req.preemptions)

    def _finish(self, slot: int, req: Request, completed: List[Request]):
        req.finish_t = time.perf_counter()
        self.alloc.free(req.rid)
        self._clear_slot(slot)
        self.stats["completed"] += 1
        # observed at finish (not at first token) so a preempted-and-
        # restarted request contributes exactly one TTFT sample — that of
        # its successful run
        if req.first_token_t is not None:
            self._lat["ttft"].observe(req.first_token_t - req.submit_t)
        self._lat["e2e"].observe(req.finish_t - req.submit_t)
        completed.append(req)

    def latency_stats(self) -> Dict[str, Optional[float]]:
        """Per-request latency aggregates over every request finished since
        the last ``reset_latency_stats``: TTFT (submit -> first token) and
        end-to-end p50/p99 in seconds, plus the sample count.  ``None``
        percentiles when nothing has finished."""
        ttft, e2e = self._lat["ttft"], self._lat["e2e"]
        return {"n": e2e.count,
                "ttft_p50_s": ttft.percentile(50),
                "ttft_p99_s": ttft.percentile(99),
                "e2e_p50_s": e2e.percentile(50),
                "e2e_p99_s": e2e.percentile(99)}

    def reset_latency_stats(self):
        """Drop accumulated latency samples (e.g. after a warmup drain)."""
        self._lat = {"ttft": Histogram(), "e2e": Histogram()}

    def _clear_slot(self, slot: int):
        self._slots[slot] = None
        self._pt[slot] = 0
        self._lengths[slot] = 0
        self._last_tok[slot] = 0
