"""Declarative run description: ``RunSpec`` is WHAT to train, not HOW.

A ``RunSpec`` names an architecture (by registry id or as a concrete config
object), a mesh topology, a parallelism mode, the communication knobs, the
optimizer/schedule choice and the trainer/data settings.  ``compile_run``
(``repro.api.assemble``) turns it into an executable :class:`~repro.api.run.Run`.

Parallelism modes (the paper's §3/§4 composition points):

``serial``
    Single-program baseline: no mesh, plain ``optimizer.update``.  The
    reference every distributed mode is property-tested against.
``dp``
    pjit/GSPMD data parallelism: batch sharded over the ("pod","data") axes,
    gradient all-reduce implicit, optimizer state replicated.
``zero1``
    The paper's §3.4 part-reduce / part-broadcast strip update, explicit:
    gradients flow through the bucketed fusion-buffer collectives of
    ``repro.comm`` (``make_distributed_update`` under ``shard_map``) and each
    member updates only its 1/G strip.  ``comm`` carries bucket size, wire
    dtype, the hierarchical two-level schedule, ``overlap`` — the §3.1
    bubble schedule that issues each bucket's part-reduce inside the
    backward pass (``make_overlapped_train_step``) instead of after
    ``value_and_grad`` returns — and ``backend``, the collective
    implementation the schedules drive (lax or the explicit Pallas ring;
    ``repro.comm.backends``).
``zero1-gspmd``
    Same strip scheme through the compiler instead: optimizer state is
    sharded over the data axes (``zero1_state_shardings``) and XLA
    factorizes the all-reduce into reduce-scatter + all-gather.
``stale-sync``
    Bounded staleness over the same strip update: step t applies the
    mean-gradient strips reduced at step t-1 from a carried buffer
    (``make_stale_sync_update``), so a full step of compute is available
    to hide the reduce.  Same layout and ``comm`` knobs as ``zero1``
    except ``overlap`` (the staleness carry IS the overlap mechanism).
``gossip``
    GossipGraD partner exchange: the same pipeline with the reduce
    phase's collectives on the ``gossip`` backend — one rotating
    chunk-sized ``lax.ppermute`` partner message per step instead of the
    full ring reduction (``repro.comm.backends.gossip``).  Params stay
    replicated (the strip all-gather is unchanged); only the gradient
    estimator weakens to a rotating pair mean.

What each mode accepts (``comm`` / ``overlap`` / which backends) lives in
the declarative :data:`MODE_CAPS` table — validation reads it, so a new
mode registers capabilities instead of growing an ``if`` chain.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Optional, Tuple, Union

from repro.comm.bucketer import CommConfig


@dataclass(frozen=True)
class ModeCaps:
    """What one parallel mode supports, declaratively: does it take the
    explicit-path ``comm`` knobs at all, does it run the §3.1 overlapped
    train step, WHICH collective backends its reduce phase accepts
    (``None`` = comm is rejected outright, so backends are moot), and which
    gradient wire formats (``CommConfig.wire_format``) it can move.
    ``default_backend`` overrides the ``CommConfig`` default for modes
    whose semantics live in a specific backend (gossip)."""
    comm: bool = False
    overlap: bool = False
    backends: Optional[Tuple[str, ...]] = None
    default_backend: Optional[str] = None
    wire_formats: Optional[Tuple[str, ...]] = None


MODE_CAPS = {
    "serial": ModeCaps(),
    "dp": ModeCaps(),
    "zero1": ModeCaps(comm=True, overlap=True,
                      backends=("lax", "pallas-ring"),
                      wire_formats=("fp32", "bf16", "int8", "topk")),
    "zero1-gspmd": ModeCaps(),
    # topk's error-feedback residual semantics are defined only for the
    # synchronous zero1 pipeline: under stale-sync the compensation would
    # lag the staleness carry, and the gossip pair exchange never moves a
    # ring message at all (int8 is stateless, so stale-sync takes it)
    "stale-sync": ModeCaps(comm=True, backends=("lax", "pallas-ring"),
                           wire_formats=("fp32", "bf16", "int8")),
    "gossip": ModeCaps(comm=True, backends=("gossip",),
                       default_backend="gossip",
                       wire_formats=("fp32", "bf16")),
}

PARALLEL_MODES = tuple(MODE_CAPS)
OPTIMIZERS = ("adamw", "sgd")
SCHEDULES = ("warmup_cosine", "constant", "linear-scale-warmup")


@dataclass(frozen=True)
class TelemetrySpec:
    """Telemetry knobs of a run (``RunSpec.telemetry``).

    trace_dir:       write per-process JSONL event files here (one
                     ``trace_p<i>.jsonl`` per cluster process) and export a
                     merged Chrome trace ``trace.json`` at ``Run.close``
                     (supervisor-side for cluster runs).  ``None`` keeps
                     telemetry in-memory only — events still fire (the
                     cluster heartbeat rides them) but nothing hits disk.
    autotune_reps:   timed repetitions per probe buffer when
                     ``RunSpec.comm="auto"`` measures the collectives.
    """
    trace_dir: Optional[str] = None
    autotune_reps: int = 2

    def __post_init__(self):
        if self.autotune_reps < 1:
            raise ValueError(
                f"autotune_reps must be >= 1, got {self.autotune_reps}")

SCHEDULER_POLICIES = ("static", "continuous")
PAGED_ATTN_IMPLS = ("gather", "pallas")

MIB = 2 ** 20


@dataclass(frozen=True)
class MeshSpec:
    """Host-mesh topology: ``("pod", "data", "model")`` when ``pods > 1``,
    ``("data", "model")`` otherwise; the data extent is whatever remains of
    the visible devices after pods x model_ways.

    ``cluster=True`` builds the mesh over a live ``jax.distributed``
    process group instead (``launch.mesh.make_cluster_mesh``): the "pod"
    axis becomes the PROCESS (host) boundary — one pod per process, so the
    hierarchical schedule's cross-pod hop runs over the genuine cross-host
    link.  ``pods`` is ignored in that case (the process count decides);
    the caller must have run ``repro.cluster.initialize`` first."""
    pods: int = 1
    model_ways: int = 1
    cluster: bool = False

    def __post_init__(self):
        assert self.pods >= 1 and self.model_ways >= 1, (
            f"pods/model_ways must be >= 1, got {self.pods}/{self.model_ways}")

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return (("pod", "data", "model") if self.pods > 1
                else ("data", "model"))

    @property
    def data_axes(self) -> Tuple[str, ...]:
        """The paper's G data-parallel group axes."""
        return ("pod", "data") if self.pods > 1 else ("data",)


@dataclass(frozen=True)
class RunSpec:
    """Declarative description of one training run.

    arch:       registry id (``configs.ALL_ARCHS``) or a concrete config
                object of any registered family.
    smoke:      reduce the config to the family's CPU-sized smoke variant.
    parallel:   one of ``PARALLEL_MODES`` (see module docstring).
    mesh:       topology for the non-serial modes (ignored for ``serial``).
    comm:       communication knobs for the explicit bucketed modes
                (``MODE_CAPS[mode].comm``); ``None`` picks the mode's
                default ``CommConfig`` (hierarchical iff the mesh has a
                pod axis; flat + gossip backend for ``parallel="gossip"``).
                The string ``"auto"`` closes the §3.2 loop instead: at
                assembly time the real per-bucket collectives are timed on
                the run's mesh and the bucket size / backend come from
                ``core.balance.optimal_bucket_bytes`` with the MEASURED
                latency/bandwidth (``repro.telemetry.autotune``).
    optimizer:  ``"adamw"`` / ``"sgd"``; ``None`` = family default (momentum
                SGD for the paper's CNN/DNN workloads, AdamW otherwise).
    telemetry:  :class:`TelemetrySpec` (or a plain trace-dir string, coerced)
                — ``None`` = in-memory telemetry only, no trace files.
    """
    arch: Union[str, Any]
    smoke: bool = False
    parallel: str = "serial"
    mesh: MeshSpec = field(default_factory=MeshSpec)
    comm: Union[CommConfig, str, None] = None
    # optimizer + schedule
    optimizer: Optional[str] = None
    lr: float = 1e-3
    weight_decay: Optional[float] = None   # None = optimizer default
    momentum: float = 0.9
    schedule: str = "warmup_cosine"
    warmup_steps: Optional[int] = None     # None = steps // 20 (min 1)
    grad_clip: float = 1.0
    # trainer / data
    steps: int = 50
    batch: int = 8
    seq: int = 128
    seed: int = 0
    log_every: int = 5
    ckpt_every: int = 0                    # 0 = disabled
    ckpt_dir: Optional[str] = None
    telemetry: Union[TelemetrySpec, str, None] = None

    def __post_init__(self):
        if self.parallel not in PARALLEL_MODES:
            raise ValueError(f"parallel must be one of {PARALLEL_MODES}, "
                             f"got {self.parallel!r}")
        if self.optimizer is not None and self.optimizer not in OPTIMIZERS:
            raise ValueError(f"optimizer must be one of {OPTIMIZERS}, "
                             f"got {self.optimizer!r}")
        if self.schedule not in SCHEDULES:
            raise ValueError(f"schedule must be one of {SCHEDULES}, "
                             f"got {self.schedule!r}")
        if self.steps < 1:
            raise ValueError(f"steps must be >= 1, got {self.steps}")
        caps = MODE_CAPS[self.parallel]
        if isinstance(self.telemetry, str):
            # a bare trace-dir string is the common hand-written case
            object.__setattr__(self, "telemetry",
                               TelemetrySpec(trace_dir=self.telemetry))
        elif self.telemetry is not None and not isinstance(self.telemetry,
                                                           TelemetrySpec):
            raise ValueError(
                "telemetry must be a TelemetrySpec, a trace-dir string or "
                f"None, got {type(self.telemetry).__name__}")
        if isinstance(self.comm, str):
            if self.comm != "auto":
                raise ValueError(
                    f"comm accepts a CommConfig, None, or the string "
                    f"'auto', got {self.comm!r}")
            if not caps.comm:
                commful = tuple(m for m, c in MODE_CAPS.items() if c.comm)
                raise ValueError(
                    "comm='auto' measures the explicit bucketed collectives "
                    f"— only the comm-capable modes {commful} run them; "
                    f"parallel={self.parallel!r} does not")
        elif self.comm is not None:
            if not caps.comm:
                commful = tuple(m for m, c in MODE_CAPS.items() if c.comm)
                raise ValueError(
                    "comm (bucket size / wire dtype / hierarchical) only "
                    "applies to the explicit bucketed modes "
                    f"{commful} — parallel={self.parallel!r} does not take "
                    "it")
            if self.comm.overlap and not caps.overlap:
                overlappy = tuple(m for m, c in MODE_CAPS.items()
                                  if c.overlap)
                raise ValueError(
                    "comm.overlap (the §3.1 backward-pass reduce schedule) "
                    f"is only supported by {overlappy} — "
                    f"parallel={self.parallel!r} does not run the "
                    "overlapped train step")
            backend = self.comm.backend
            name = backend if isinstance(backend, str) else getattr(
                backend, "name", type(backend).__name__)
            if caps.backends is not None and name not in caps.backends:
                raise ValueError(
                    f"collective backend {name!r} is not valid under "
                    f"parallel={self.parallel!r}; this mode supports "
                    f"{caps.backends}. The gossip backend changes the "
                    "consistency model, so it is selected by "
                    "parallel='gossip', not as a zero1 backend swap")
            fmt = self.comm.wire_format
            if caps.wire_formats is not None and fmt not in caps.wire_formats:
                raise ValueError(
                    f"wire_format {fmt!r} is not valid under "
                    f"parallel={self.parallel!r}; this mode supports "
                    f"{caps.wire_formats}. The topk format carries an "
                    "error-feedback residual whose semantics are defined "
                    "only for the synchronous zero1 pipeline")
            if fmt == "topk" and self.comm.overlap:
                raise ValueError(
                    "wire_format='topk' cannot run under comm.overlap: the "
                    "backward-pass reduce taps are stateless, so the "
                    "error-feedback residual has nowhere to live (int8 and "
                    "the dense formats overlap fine)")

    def replace(self, **kw) -> "RunSpec":
        return replace(self, **kw)


@dataclass(frozen=True)
class ServeSpec:
    """Declarative description of one serving deployment: WHAT to serve and
    under WHICH budgets, mirroring ``RunSpec`` — ``compile_serve``
    (``repro.api.assemble``) turns it into a live
    :class:`~repro.api.serve.Server` with ``.submit()/.step()/.drain()``.

    arch:            registry id (``configs.ALL_ARCHS``) or a concrete
                     ``ModelConfig``.  Must be a token-in/token-out
                     attention-block transformer (no frontends/SSM blocks —
                     paged decode covers global/local/shared attention).
    smoke:           reduce to the family's CPU-sized smoke variant.
    max_batch:       concurrent decode slots (the continuous-batching width).
    page_size:       tokens per KV page.
    num_pages:       physical pages in each layer's pool (page 0 is the
                     reserved null page) — THE cache budget; the scheduler
                     admits/preempts against its free list.
    max_prompt:      longest admissible prompt.
    max_new_tokens:  per-request decode budget (requests may ask for less).
    max_queue:       admission control — ``submit`` beyond this backlog
                     raises instead of queueing unboundedly.
    scheduler:       ``"continuous"`` (refill free slots every step — in-
                     flight batching) or ``"static"`` (admit a wave, decode
                     until ALL of it finishes, then admit the next — the
                     baseline the load benchmark compares against).
    attn_impl:       paged decode attention math: ``"gather"`` (jnp page
                     gather, runs anywhere) or ``"pallas"`` (the
                     scalar-prefetch page-gather kernel; interpret off-TPU).
    temperature:     0 = greedy, else categorical sampling.
    prefill_bucket:  prompts are right-padded to the next power-of-two
                     bucket >= this, so prefill compiles once per bucket
                     instead of once per prompt length.
    """
    arch: Union[str, Any]
    smoke: bool = False
    max_batch: int = 4
    page_size: int = 16
    num_pages: int = 128
    max_prompt: int = 64
    max_new_tokens: int = 32
    max_queue: int = 1024
    scheduler: str = "continuous"
    attn_impl: str = "gather"
    temperature: float = 0.0
    seed: int = 0
    prefill_bucket: int = 16

    def __post_init__(self):
        if self.scheduler not in SCHEDULER_POLICIES:
            raise ValueError(f"scheduler must be one of {SCHEDULER_POLICIES},"
                             f" got {self.scheduler!r}")
        if self.attn_impl not in PAGED_ATTN_IMPLS:
            raise ValueError(f"attn_impl must be one of {PAGED_ATTN_IMPLS}, "
                             f"got {self.attn_impl!r}")
        for fld in ("max_batch", "page_size", "max_prompt", "max_new_tokens",
                    "max_queue", "prefill_bucket"):
            if getattr(self, fld) < 1:
                raise ValueError(f"{fld} must be >= 1, "
                                 f"got {getattr(self, fld)}")
        if self.num_pages - 1 < self.pages_per_request:
            raise ValueError(
                f"num_pages={self.num_pages} (1 reserved null page) cannot "
                f"hold even one max-length request "
                f"({self.pages_per_request} pages for "
                f"{self.max_context} tokens @ page_size={self.page_size})")

    @property
    def max_context(self) -> int:
        """Positions one request can occupy: prompt + decode budget."""
        return self.max_prompt + self.max_new_tokens

    @property
    def pages_per_request(self) -> int:
        """Page-table width: logical pages covering ``max_context``."""
        return -(-self.max_context // self.page_size)

    def replace(self, **kw) -> "ServeSpec":
        return replace(self, **kw)
