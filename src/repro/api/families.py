"""Family-adapter registry: each model family declares its glue ONCE.

Before this module existed, every assembly site (``launch/train.py``, the
examples, ``data/pipeline.py``, ``configs/registry.py``) re-implemented the
same ``isinstance(cfg, CNNConfig/DNNConfig)`` ladder to pick init/loss/
specs/stream for a config.  The registry inverts that: a family registers a
:class:`FamilyAdapter` keyed by its config class, and ``adapter_for(cfg)``
resolves it by MRO — one dispatch point for the whole repo, and the place a
NEW family (diffusion, retrieval, ...) plugs in without touching any
launcher.

The three built-in families mirror the paper's workloads plus the
beyond-paper substrate: ``cnn`` (VGG-A, OverFeat-FAST), ``dnn`` (CD-DNN)
and ``transformer`` (the ten assigned LM/VLM/audio architectures).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Type

import jax

from repro.configs.base import CNNConfig, ConvLayerSpec, DNNConfig, ModelConfig
from repro.core.params import axes_tree
from repro.core.sharding import ShardingCtx
from repro.data.pipeline import (
    asr_frame_stream,
    audio_stream,
    image_stream,
    lm_token_stream,
    vlm_stream,
)
from repro.models import cnn, dnn, transformer


@dataclass(frozen=True)
class FamilyAdapter:
    """Everything ``compile_run`` needs to assemble a family's training run.

    init:         (cfg, key) -> param pytree
    make_loss:    (cfg, ctx) -> loss_fn(params, batch) -> scalar
    param_specs:  cfg -> pytree of ``core.params.Spec`` (shapes + logical axes)
    stream:       (cfg, batch, seq, seed) -> iterator of host batches
    smoke:        cfg -> reduced CPU-sized variant of the same family
    default_optimizer: "sgd" (the paper's CNN/DNN optimizer) or "adamw"
    """
    family: str
    config_cls: Type
    init: Callable[[Any, jax.Array], Any]
    make_loss: Callable[[Any, ShardingCtx], Callable]
    param_specs: Callable[[Any], Any]
    stream: Callable[[Any, int, int, int], Iterator]
    smoke: Callable[[Any], Any]
    default_optimizer: str = "adamw"

    def param_axes(self, cfg) -> Any:
        """Logical-axes pytree matching the param tree (for ZeRO-1 GSPMD
        state sharding and rules-based placement)."""
        return axes_tree(self.param_specs(cfg))


_REGISTRY: Dict[Type, FamilyAdapter] = {}


def register_family(adapter: FamilyAdapter) -> FamilyAdapter:
    """Register ``adapter`` for its config class (last registration wins,
    so downstream code can override a built-in family)."""
    _REGISTRY[adapter.config_cls] = adapter
    return adapter


def adapter_for(cfg) -> FamilyAdapter:
    """Resolve the family adapter for a config instance by MRO."""
    for cls in type(cfg).__mro__:
        if cls in _REGISTRY:
            return _REGISTRY[cls]
    raise TypeError(
        f"no family adapter registered for {type(cfg).__name__}; "
        f"known families: {sorted(a.family for a in _REGISTRY.values())}")


def families() -> Dict[str, FamilyAdapter]:
    return {a.family: a for a in _REGISTRY.values()}


# ---------------------------------------------------------------------------
# smoke variants (moved from configs/registry.py — the family owns its
# reduction recipe, the registry just dispatches)
# ---------------------------------------------------------------------------
def _cnn_smoke(cfg: CNNConfig) -> CNNConfig:
    # keep first two convs + last fc, shrink maps
    L = ConvLayerSpec
    return CNNConfig(
        name=cfg.name + "-smoke", source=cfg.source, image_size=32,
        num_classes=16,
        layers=(
            L("conv", ifm=3, ofm=16, kernel=3, stride=1, pad=1, out_hw=32),
            L("pool", out_hw=16),
            L("conv", ifm=16, ofm=32, kernel=3, stride=1, pad=1, out_hw=16),
            L("pool", out_hw=8),
            L("fc", ifm=32 * 8 * 8, ofm=64, out_hw=1),
            L("fc", ifm=64, ofm=16, out_hw=1),
        ),
    )


def _dnn_smoke(cfg: DNNConfig) -> DNNConfig:
    return DNNConfig(name=cfg.name + "-smoke", source=cfg.source,
                     input_dim=40, hidden_dim=64, num_hidden=3,
                     output_dim=32)


def _transformer_smoke(cfg: ModelConfig) -> ModelConfig:
    unit = cfg.block_pattern
    # keep the heterogeneity of the unit but only 1-2 repeats
    repeats = 1 if len(unit) > 2 else 2
    d_model = min(cfg.d_model, 256)
    head_dim = 32
    heads = max(2, min(4, cfg.num_heads))
    kv = max(1, min(heads, cfg.num_kv_heads))
    while heads % kv:
        kv -= 1
    # rescale M-RoPE sections to the reduced head_dim (keep 1/4:3/8:3/8)
    mrope_sections = cfg.mrope_sections
    if cfg.mrope:
        half = head_dim // 2
        a = half // 4
        b = (half - a) // 2
        mrope_sections = (a, b, half - a - b)
    return cfg.replace(
        num_layers=repeats * len(unit),
        pattern_repeats=repeats,
        mrope_sections=mrope_sections,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2)
        if cfg.num_experts else 0,
        # dropless in smoke tests so decode == train-path routing exactly
        moe_capacity_factor=(min(cfg.num_experts, 4)
                             / max(1, min(cfg.num_experts_per_tok, 2))
                             if cfg.num_experts else 1.25),
        moe_d_ff=min(cfg.moe_d_ff, 128) if cfg.moe_d_ff else 0,
        num_shared_experts=min(cfg.num_shared_experts, 1),
        shared_expert_d_ff=min(cfg.shared_expert_d_ff, 128),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_heads=min(cfg.ssm_heads, 8) if cfg.ssm_heads else 0,
        sliding_window=min(cfg.sliding_window, 64),
        long_context_window=64,
        vision_tokens=16,
        remat="none",
        fsdp=False,
    )


# ---------------------------------------------------------------------------
# built-in families
# ---------------------------------------------------------------------------
def _transformer_stream(cfg: ModelConfig, batch: int, seq: int, seed: int):
    # modality dispatch WITHIN the family (frontend is a family concept)
    if cfg.frontend == "vision":
        return vlm_stream(cfg, batch, seq - cfg.vision_tokens, seed)
    if cfg.frontend == "audio":
        return audio_stream(cfg, batch, seq, seed)
    return lm_token_stream(cfg.vocab_size, batch, seq, seed)


CNN_FAMILY = register_family(FamilyAdapter(
    family="cnn", config_cls=CNNConfig,
    init=cnn.init_params,
    make_loss=lambda cfg, ctx: lambda p, b: cnn.loss_fn(p, cfg, b, ctx),
    param_specs=cnn.param_specs,
    stream=lambda cfg, batch, seq, seed: image_stream(
        cfg.image_size, cfg.num_classes, batch, seed),
    smoke=_cnn_smoke,
    default_optimizer="sgd",
))

DNN_FAMILY = register_family(FamilyAdapter(
    family="dnn", config_cls=DNNConfig,
    init=dnn.init_params,
    make_loss=lambda cfg, ctx: lambda p, b: dnn.loss_fn(p, cfg, b, ctx),
    param_specs=dnn.param_specs,
    stream=lambda cfg, batch, seq, seed: asr_frame_stream(
        cfg.input_dim, cfg.output_dim, batch, seed),
    smoke=_dnn_smoke,
    default_optimizer="sgd",
))

TRANSFORMER_FAMILY = register_family(FamilyAdapter(
    family="transformer", config_cls=ModelConfig,
    init=transformer.init_params,
    make_loss=lambda cfg, ctx: lambda p, b: transformer.lm_loss(
        p, cfg, ctx, b),
    param_specs=transformer.param_specs,
    stream=_transformer_stream,
    smoke=_transformer_smoke,
    default_optimizer="adamw",
))
