"""Elastic supervisor: detect a dead worker, re-form over survivors, resume.

Synchronous SGD has no partial-failure mode — one dead member wedges every
survivor inside the next collective (gloo blocks waiting for the missing
peer).  So the recovery unit is the whole group: the supervisor detects the
failure (process exit via ``poll`` within one poll interval, or heartbeat
staleness for the wedged-but-alive case), kills the survivors, and
relaunches the SAME worker command at the smaller world size on a fresh
coordinator port.  The relaunched workers re-plan the mesh and bucket
layout for the new world size themselves (``MeshSpec(cluster=True)`` sizes
the pod axis from the live process group) and auto-resume from the latest
checkpoint — ``checkpoint.replan`` re-strips the zero1 optimizer state
from the old world's layout, so nothing is lost beyond the last
checkpoint interval.

The §3.4 strip decomposition is what makes this cheap: the update rule is
G-invariant (property-tested against the serial optimizer), so a run that
loses a node mid-flight converges to the same trajectory as one launched
at the surviving world size from the start.  The chaos test asserts
exactly that equality.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.cluster.launcher import (
    WorkerHandle,
    invalidate_autotune_cache,
    kill_workers,
    result_path,
    sigkill,
    spawn_workers,
)


@dataclass
class ChaosSpec:
    """Fault injection for the chaos harness: SIGKILL ``worker`` once its
    heartbeat reaches ``at_step`` (first attempt only — the point is to
    watch the recovery, not to kill the cluster forever)."""
    at_step: int
    worker: int = 1


@dataclass
class ElasticResult:
    """What the supervisor saw across a run's life."""
    final_world: int
    attempts: int
    result: Optional[dict]          # worker 0's result.json (final attempt)
    history: List[dict] = field(default_factory=list)


def _read_result(run_dir: str) -> Optional[dict]:
    try:
        with open(result_path(run_dir)) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def _failure(handles: Sequence[WorkerHandle], spawned_at: float,
             heartbeat_timeout: float) -> Optional[dict]:
    """None while the group is healthy; else {dead: [...], reason: str}."""
    dead = [h.process_id for h in handles
            if not h.alive() and h.proc.returncode != 0]
    if dead:
        return {"dead": dead, "reason": "exit"}
    # heartbeat fallback: every process alive, but someone stopped making
    # progress (wedged in a collective whose peer is gone, deadlock, ...).
    # WorkerHandle.staleness tracks payload-content change on the
    # supervisor's own monotonic clock (NTP-immune; measured from spawn
    # until the first beat so jit warm-up doesn't count as a hang).
    now = time.monotonic()
    stale = []
    for h in handles:
        if not h.alive():   # clean exit (returncode 0): not a beat source
            continue
        if h.staleness(now, spawned_at) > heartbeat_timeout:
            stale.append(h.process_id)
    if stale and len(stale) == sum(h.alive() for h in handles):
        # only declare a hang when the WHOLE live group is stale —
        # synchronous SGD means one straggler stalls everyone, so a
        # genuine hang is always collective
        return {"dead": [], "reason": "heartbeat"}
    return None


def run_elastic(worker_argv: Sequence[str], run_dir: str,
                num_processes: int, local_devices: int = 1,
                max_restarts: int = 2, heartbeat_timeout: float = 120.0,
                poll_interval: float = 0.25,
                chaos: Optional[ChaosSpec] = None,
                grow_back: bool = False,
                log=print) -> ElasticResult:
    """Supervise ``worker_argv`` at ``num_processes``, shrinking the world
    and relaunching on failure (at most ``max_restarts`` times).
    ``grow_back`` relaunches every failed attempt at the FULL
    ``num_processes`` instead of shrinking — the recovery policy for
    transient failures (preempted-then-returned hosts) rather than lost
    ones.

    Either way, a relaunch whose world size differs from the attempt that
    failed invalidates the persisted comm=auto plan
    (``launcher.autotune_cache_path``): the cached ring constants and the
    bucket/wire-format choice they justified describe the OLD group size,
    so the new group must re-probe.

    Returns the :class:`ElasticResult` on success; raises ``RuntimeError``
    when the restart budget is exhausted or the final attempt fails.
    """
    world = num_processes
    history: List[dict] = []
    chaos_armed = chaos is not None
    for attempt in range(max_restarts + 1):
        log(f"[elastic] attempt {attempt}: world={world}")
        handles = spawn_workers(world, worker_argv, run_dir,
                                attempt=attempt,
                                local_devices=local_devices)
        spawned_at = time.monotonic()
        fail = None
        try:
            while True:
                if chaos_armed:
                    target = handles[min(chaos.worker, world - 1)]
                    hb = target.heartbeat()
                    if hb is not None and hb.step >= chaos.at_step:
                        log(f"[elastic] chaos: SIGKILL worker "
                            f"{target.process_id} at step {hb.step}")
                        sigkill(target)
                        chaos_armed = False
                if all(not h.alive() and h.proc.returncode == 0
                       for h in handles):
                    break   # clean group exit
                fail = _failure(handles, spawned_at, heartbeat_timeout)
                if fail is not None:
                    break
                time.sleep(poll_interval)
        finally:
            kill_workers(handles)
        if fail is None:
            res = _read_result(run_dir)
            history.append({"attempt": attempt, "world": world,
                            "outcome": "ok"})
            return ElasticResult(final_world=world, attempts=attempt + 1,
                                 result=res, history=history)
        log(f"[elastic] attempt {attempt} failed: {fail['reason']} "
            f"(dead workers: {fail['dead'] or 'none detected'})")
        for h in handles:
            if h.process_id in fail["dead"] and h.log_file:
                tail = h.tail_log()
                if tail:
                    log(f"[elastic] -- worker {h.process_id} log tail --\n"
                        f"{tail}")
        history.append({"attempt": attempt, "world": world,
                        "outcome": fail["reason"], "dead": fail["dead"]})
        # re-form over the survivors (or back at full strength under
        # grow_back); a pure hang (no dead process) keeps the world size —
        # there is no one to exclude
        new_world = num_processes if grow_back \
            else max(1, world - len(fail["dead"]))
        if new_world != world and invalidate_autotune_cache(run_dir):
            log(f"[elastic] world {world} -> {new_world}: invalidated "
                f"stale autotune plan cache")
        world = new_world
    raise RuntimeError(
        f"elastic run failed after {max_restarts + 1} attempts: "
        f"{history}")
