"""Multi-host cluster subsystem: ``jax.distributed`` launch + elastic
fault tolerance.

The paper's headline results are multi-NODE — 90X on 128 nodes for VGG-A,
~14X on the 16-node Ethernet AWS cluster (§5) — and everything below this
package runs unchanged across real processes:

``cluster.spec``
    :class:`ClusterSpec` — coordinator address / world size / process id /
    local device count, resolved from env vars (the cluster-spec-from-env
    pattern of YARN-style runners), and :func:`initialize`, the one call
    that brings ``jax.distributed`` up before any device is touched.

``cluster.launcher``
    A localhost multi-process launcher: spawns N worker processes (each a
    fresh ``python -m repro.launch.cluster`` with the cluster env vars
    set), streams their output, and watches their heartbeats.

``cluster.elastic``
    The elastic supervisor: detects a dead worker (process exit or
    heartbeat timeout), tears down the now-unusable collective group,
    re-forms the cluster over the survivors at the smaller world size, and
    relaunches — workers then re-plan the mesh + bucket plan for the new
    world size and resume from the latest checkpoint
    (``checkpoint.replan`` re-strips the zero1 optimizer state, so no
    progress is lost beyond the last checkpoint).

Training itself needs NO cluster-specific code: ``RunSpec(mesh=
MeshSpec(cluster=True))`` makes ``compile_run`` build the mesh over the
live process group (``launch.mesh.make_cluster_mesh`` — the "pod" axis IS
the host boundary, so ``HierarchicalSchedule``'s cross-pod hop runs over
the genuine cross-host link), and every existing knob (buckets, wire
dtype, overlap, backends) composes with it.
"""
from repro.cluster.elastic import ElasticResult, run_elastic  # noqa: F401
from repro.cluster.launcher import (  # noqa: F401
    WorkerHandle,
    free_port,
    spawn_workers,
)
from repro.cluster.spec import (  # noqa: F401
    ENV_COORDINATOR,
    ENV_LOCAL_DEVICES,
    ENV_NUM_PROCESSES,
    ENV_PROCESS_ID,
    ClusterSpec,
    initialize,
)
