"""ClusterSpec: WHO this process is in the cluster, resolved from env vars.

The launcher (``cluster.launcher``) sets these variables in each worker's
environment; a worker calls :func:`ClusterSpec.from_env` +
:func:`initialize` before touching any jax device state.  On managed
clusters (SLURM/YARN/k8s) the same variables are set by the scheduler's
wrapper script — the spec-from-env seam is exactly the shifu/YARN runner
pattern, so nothing in the training path knows how processes were placed.

``REPRO_COORDINATOR``     host:port of the jax.distributed coordinator
                          (process 0 binds it).
``REPRO_NUM_PROCESSES``   world size.
``REPRO_PROCESS_ID``      this process's rank in [0, num_processes).
``REPRO_LOCAL_DEVICES``   devices this process contributes.  On the CPU
                          containers this is realized by forcing
                          ``--xla_force_host_platform_device_count`` (the
                          launcher exports it BEFORE the worker imports
                          jax); on an accelerator host it is informative
                          only (the local chips are what they are).
"""
from __future__ import annotations

import os
from dataclasses import dataclass, replace
from typing import Mapping, Optional

ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"
ENV_LOCAL_DEVICES = "REPRO_LOCAL_DEVICES"

DEFAULT_COORDINATOR = "localhost:29400"


@dataclass(frozen=True)
class ClusterSpec:
    """One process's view of the cluster."""
    coordinator: str = DEFAULT_COORDINATOR
    num_processes: int = 1
    process_id: int = 0
    local_devices: int = 1

    def __post_init__(self):
        if self.num_processes < 1:
            raise ValueError(
                f"num_processes must be >= 1, got {self.num_processes}")
        if not 0 <= self.process_id < self.num_processes:
            raise ValueError(
                f"process_id must be in [0, {self.num_processes}), "
                f"got {self.process_id}")
        if self.local_devices < 1:
            raise ValueError(
                f"local_devices must be >= 1, got {self.local_devices}")
        if ":" not in self.coordinator:
            raise ValueError(
                f"coordinator must be host:port, got {self.coordinator!r}")

    @classmethod
    def from_env(cls, env: Optional[Mapping[str, str]] = None,
                 ) -> "ClusterSpec":
        """Resolve the spec from ``env`` (default ``os.environ``); missing
        variables keep their single-process defaults, so code that calls
        this unconditionally still works outside any launcher."""
        env = os.environ if env is None else env
        return cls(
            coordinator=env.get(ENV_COORDINATOR, DEFAULT_COORDINATOR),
            num_processes=int(env.get(ENV_NUM_PROCESSES, "1")),
            process_id=int(env.get(ENV_PROCESS_ID, "0")),
            local_devices=int(env.get(ENV_LOCAL_DEVICES, "1")))

    def env(self) -> dict:
        """The env-var dict the launcher exports into a worker (inverse of
        ``from_env``)."""
        return {
            ENV_COORDINATOR: self.coordinator,
            ENV_NUM_PROCESSES: str(self.num_processes),
            ENV_PROCESS_ID: str(self.process_id),
            ENV_LOCAL_DEVICES: str(self.local_devices),
        }

    def replace(self, **kw) -> "ClusterSpec":
        return replace(self, **kw)

    @property
    def is_multiprocess(self) -> bool:
        return self.num_processes > 1


def in_worker(env: Optional[Mapping[str, str]] = None) -> bool:
    """True when this process was spawned by the cluster launcher (the
    worker env vars are present)."""
    env = os.environ if env is None else env
    return ENV_PROCESS_ID in env


def initialize(spec: ClusterSpec) -> None:
    """Bring up ``jax.distributed`` for this process.

    Must run before any jax computation (device state is fixed once the
    backend initializes).  CPU processes talk gloo — the runtime's
    cross-host CPU collectives — so the lax backend's collectives cross
    process boundaries transparently.  A ``num_processes == 1`` spec is a
    no-op: a single process needs no coordination service, and skipping it
    keeps the degenerate world-size-1 path (the elastic floor) free of a
    dangling coordinator port.
    """
    if not spec.is_multiprocess:
        return
    import jax
    # CPU cross-process collectives go through gloo; guarded because
    # accelerator builds may not carry the option (they use NCCL/ICI).
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):  # pragma: no cover - non-CPU jaxlib
        pass
    jax.distributed.initialize(
        coordinator_address=spec.coordinator,
        num_processes=spec.num_processes,
        process_id=spec.process_id)
