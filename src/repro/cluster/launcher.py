"""Localhost multi-process launcher for the cluster subsystem.

Spawns N worker processes, each a fresh ``python -m repro.launch.cluster``
interpreter with the :class:`~repro.cluster.spec.ClusterSpec` env vars set
(and ``XLA_FLAGS=--xla_force_host_platform_device_count=<local>`` exported
BEFORE the worker imports jax — device counts are fixed at backend init, so
they can only be chosen from outside the process).  Worker 0 inherits the
launcher's stdout (live progress); the others log to files in the run
directory, printed back on failure.

Liveness is tracked two ways, consumed by ``cluster.elastic``:

  * the OS process itself (``Popen.poll`` — a crash or a SIGKILL chaos
    injection is detected within one poll interval);
  * a per-worker heartbeat file, written by a telemetry listener riding the
    training loop's "step" span (``make_heartbeat_listener`` attached to
    ``run.telemetry``), which catches the nastier failure mode of a worker
    that is alive but wedged in a collective whose peer died.

The heartbeat payload is JSON ``{"step": n, "mono": t}`` carrying the
worker's OWN monotonic timestamp alongside the step.  The supervisor never
compares that timestamp to its own clock (monotonic clocks aren't shared
across processes); it tracks when the payload CONTENT last changed against
its own monotonic clock (``WorkerHandle.staleness``), so an NTP wall-clock
jump on the host can neither false-trigger nor mask a staleness timeout.
Legacy plain-int heartbeat files still parse (step only) and fall back to
the old mtime comparison.
"""
from __future__ import annotations

import json
import os
import signal
import socket
import subprocess
import sys
import time
from dataclasses import dataclass
from typing import Callable, List, NamedTuple, Optional, Sequence

from repro.cluster.spec import ClusterSpec
from repro.telemetry.autotune import ENV_AUTOTUNE_CACHE

ENV_HEARTBEAT_FILE = "REPRO_HEARTBEAT_FILE"
ENV_RESULT_FILE = "REPRO_RESULT_FILE"


class Heartbeat(NamedTuple):
    """One parsed heartbeat: last completed step, the worker's own monotonic
    timestamp (None for legacy plain-int files), and the file mtime (the
    legacy fallback liveness signal)."""
    step: int
    mono: Optional[float]
    mtime: float


def write_heartbeat(path: str, step: int, mono: float) -> None:
    """Atomically publish a heartbeat (tmp + rename — a reader never sees a
    half-written payload)."""
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        f.write(json.dumps({"step": step, "mono": mono}))
    os.replace(tmp, path)


def parse_heartbeat(path: str) -> Optional[Heartbeat]:
    """Read ``path`` as a :class:`Heartbeat`; None before the first beat.
    Accepts both the JSON payload and the legacy bare-int format."""
    try:
        with open(path) as f:
            txt = f.read().strip()
        mtime = os.path.getmtime(path)
    except OSError:
        return None
    try:
        d = json.loads(txt or "0")
    except ValueError:
        return None
    if isinstance(d, dict):
        try:
            return Heartbeat(int(d["step"]), float(d["mono"]), mtime)
        except (KeyError, TypeError, ValueError):
            return None
    if isinstance(d, (int, float)):
        return Heartbeat(int(d), None, mtime)
    return None


def make_heartbeat_listener(path: str) -> Callable[[dict], None]:
    """A telemetry listener that beats ``path`` on every completed "step"
    span — attach to ``run.telemetry.add_listener``.  The beat carries the
    span's end timestamp (``t1``, the worker's monotonic clock) and step."""
    def listener(ev: dict) -> None:
        if ev.get("kind") == "step" and ev.get("ph") == "span":
            try:
                write_heartbeat(path, int(ev.get("step", 0)),
                                float(ev["t1"]))
            except OSError:
                pass   # a failed beat must never kill the training step
    return listener


def free_port() -> int:
    """An OS-assigned free TCP port for the coordinator (bind-to-0 probe)."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


@dataclass
class WorkerHandle:
    """One spawned worker: its process, identity, and liveness files."""
    proc: subprocess.Popen
    process_id: int
    hb_file: str
    log_file: Optional[str]
    _seen_beat: Optional[tuple] = None   # last observed (step, mono) payload
    _seen_at: Optional[float] = None     # SUPERVISOR monotonic time of that
    #                                      observation — staleness compares
    #                                      like-with-like on one clock

    def heartbeat(self) -> Optional[Heartbeat]:
        """The worker's last published :class:`Heartbeat`, or None before
        the first beat."""
        return parse_heartbeat(self.hb_file)

    def staleness(self, now: float, spawned_at: float) -> float:
        """Seconds since this worker last demonstrably made progress, as of
        supervisor-monotonic ``now``.  New-format beats are judged by when
        their (step, mono) payload last CHANGED on the supervisor's own
        clock — immune to NTP wall-clock jumps on either side.  Legacy
        bare-int files fall back to the mtime comparison (wall clock
        offset-corrected).  Never negative; measured from ``spawned_at``
        until the first beat so jit warm-up doesn't count as a hang."""
        hb = self.heartbeat()
        if hb is None:
            return max(0.0, now - spawned_at)
        if hb.mono is not None:
            beat = (hb.step, hb.mono)
            if beat != self._seen_beat:
                self._seen_beat = beat
                self._seen_at = now
            return max(0.0, now - max(spawned_at, self._seen_at))
        # legacy path: hb files carry wall-clock mtimes
        wall_off = time.time() - now
        return max(0.0, now - max(spawned_at, hb.mtime - wall_off))

    def alive(self) -> bool:
        return self.proc.poll() is None

    def kill(self, grace: float = 3.0) -> None:
        """Terminate (then SIGKILL) this worker and reap it."""
        if self.proc.poll() is not None:
            return
        self.proc.terminate()
        deadline = time.monotonic() + grace
        while time.monotonic() < deadline and self.proc.poll() is None:
            time.sleep(0.05)
        if self.proc.poll() is None:
            self.proc.kill()
        self.proc.wait()

    def tail_log(self, nbytes: int = 4000) -> str:
        if not self.log_file or not os.path.exists(self.log_file):
            return ""
        with open(self.log_file, "rb") as f:
            f.seek(0, os.SEEK_END)
            size = f.tell()
            f.seek(max(0, size - nbytes))
            return f.read().decode(errors="replace")


def _worker_env(spec: ClusterSpec, hb_file: str,
                result_file: Optional[str],
                run_dir: Optional[str] = None) -> dict:
    env = dict(os.environ)
    env.update(spec.env())
    env[ENV_HEARTBEAT_FILE] = hb_file
    if result_file:
        env[ENV_RESULT_FILE] = result_file
    if run_dir:
        # every worker shares one per-run comm=auto plan cache; an elastic
        # relaunch at the same topology skips the probe (telemetry.autotune)
        env[ENV_AUTOTUNE_CACHE] = autotune_cache_path(run_dir)
    # the forced host device count must be in place before the worker's
    # first jax import; append so user-set XLA flags survive
    flag = (f"--xla_force_host_platform_device_count="
            f"{spec.local_devices}")
    prev = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = f"{prev} {flag}".strip()
    return env


def result_path(run_dir: str) -> str:
    return os.path.join(run_dir, "result.json")


def autotune_cache_path(run_dir: str) -> str:
    return os.path.join(run_dir, "autotune_cache.json")


def invalidate_autotune_cache(run_dir: str) -> bool:
    """Drop the persisted comm=auto plan (True if one was removed) — the
    elastic supervisor calls this whenever the world size changes, since
    the cached ring constants describe the OLD topology."""
    try:
        os.remove(autotune_cache_path(run_dir))
        return True
    except OSError:
        return False


def spawn_workers(num_processes: int, worker_argv: Sequence[str],
                  run_dir: str, attempt: int = 0,
                  local_devices: int = 1,
                  coordinator: Optional[str] = None,
                  ) -> List[WorkerHandle]:
    """Spawn ``num_processes`` workers of ``python -m repro.launch.cluster
    <worker_argv>`` and return their handles.  ``attempt`` namespaces the
    heartbeat files so a relaunched cluster never reads a dead
    generation's beats."""
    os.makedirs(run_dir, exist_ok=True)
    coordinator = coordinator or f"localhost:{free_port()}"
    handles: List[WorkerHandle] = []
    for pid in range(num_processes):
        spec = ClusterSpec(coordinator=coordinator,
                           num_processes=num_processes,
                           process_id=pid, local_devices=local_devices)
        hb = os.path.join(run_dir, f"hb_a{attempt}_w{pid}")
        env = _worker_env(spec, hb,
                          result_path(run_dir) if pid == 0 else None,
                          run_dir=run_dir)
        log = None
        out = None
        if pid != 0:
            # worker 0 narrates to the launcher's stdout; the rest log to
            # files (printed back on failure)
            log = os.path.join(run_dir, f"worker_a{attempt}_w{pid}.log")
            out = open(log, "wb")
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.cluster"]
            + list(worker_argv),
            env=env, stdout=out, stderr=subprocess.STDOUT if out else None)
        if out is not None:
            out.close()   # the child owns the fd now
        handles.append(WorkerHandle(proc=proc, process_id=pid,
                                    hb_file=hb, log_file=log))
    return handles


def kill_workers(handles: Sequence[WorkerHandle]) -> None:
    for h in handles:
        h.kill()


def sigkill(handle: WorkerHandle) -> None:
    """Hard-kill one worker (the chaos injection: no cleanup, no goodbye —
    exactly what a node loss looks like to the rest of the cluster)."""
    if handle.proc.poll() is None:
        handle.proc.send_signal(signal.SIGKILL)
