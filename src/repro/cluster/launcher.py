"""Localhost multi-process launcher for the cluster subsystem.

Spawns N worker processes, each a fresh ``python -m repro.launch.cluster``
interpreter with the :class:`~repro.cluster.spec.ClusterSpec` env vars set
(and ``XLA_FLAGS=--xla_force_host_platform_device_count=<local>`` exported
BEFORE the worker imports jax — device counts are fixed at backend init, so
they can only be chosen from outside the process).  Worker 0 inherits the
launcher's stdout (live progress); the others log to files in the run
directory, printed back on failure.

Liveness is tracked two ways, consumed by ``cluster.elastic``:

  * the OS process itself (``Popen.poll`` — a crash or a SIGKILL chaos
    injection is detected within one poll interval);
  * a per-worker heartbeat file the training loop touches every step
    (``Run.fit(on_step=...)``), which catches the nastier failure mode of
    a worker that is alive but wedged in a collective whose peer died.
"""
from __future__ import annotations

import os
import signal
import socket
import subprocess
import sys
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.cluster.spec import ClusterSpec

ENV_HEARTBEAT_FILE = "REPRO_HEARTBEAT_FILE"
ENV_RESULT_FILE = "REPRO_RESULT_FILE"


def free_port() -> int:
    """An OS-assigned free TCP port for the coordinator (bind-to-0 probe)."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


@dataclass
class WorkerHandle:
    """One spawned worker: its process, identity, and liveness files."""
    proc: subprocess.Popen
    process_id: int
    hb_file: str
    log_file: Optional[str]

    def heartbeat(self) -> Optional[tuple]:
        """(mtime, last completed step) of the worker's heartbeat, or None
        before the first beat."""
        try:
            with open(self.hb_file) as f:
                txt = f.read().strip()
            return os.path.getmtime(self.hb_file), int(txt or "0")
        except (OSError, ValueError):
            return None

    def alive(self) -> bool:
        return self.proc.poll() is None

    def kill(self, grace: float = 3.0) -> None:
        """Terminate (then SIGKILL) this worker and reap it."""
        if self.proc.poll() is not None:
            return
        self.proc.terminate()
        deadline = time.monotonic() + grace
        while time.monotonic() < deadline and self.proc.poll() is None:
            time.sleep(0.05)
        if self.proc.poll() is None:
            self.proc.kill()
        self.proc.wait()

    def tail_log(self, nbytes: int = 4000) -> str:
        if not self.log_file or not os.path.exists(self.log_file):
            return ""
        with open(self.log_file, "rb") as f:
            f.seek(0, os.SEEK_END)
            size = f.tell()
            f.seek(max(0, size - nbytes))
            return f.read().decode(errors="replace")


def _worker_env(spec: ClusterSpec, hb_file: str,
                result_file: Optional[str]) -> dict:
    env = dict(os.environ)
    env.update(spec.env())
    env[ENV_HEARTBEAT_FILE] = hb_file
    if result_file:
        env[ENV_RESULT_FILE] = result_file
    # the forced host device count must be in place before the worker's
    # first jax import; append so user-set XLA flags survive
    flag = (f"--xla_force_host_platform_device_count="
            f"{spec.local_devices}")
    prev = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = f"{prev} {flag}".strip()
    return env


def result_path(run_dir: str) -> str:
    return os.path.join(run_dir, "result.json")


def spawn_workers(num_processes: int, worker_argv: Sequence[str],
                  run_dir: str, attempt: int = 0,
                  local_devices: int = 1,
                  coordinator: Optional[str] = None,
                  ) -> List[WorkerHandle]:
    """Spawn ``num_processes`` workers of ``python -m repro.launch.cluster
    <worker_argv>`` and return their handles.  ``attempt`` namespaces the
    heartbeat files so a relaunched cluster never reads a dead
    generation's beats."""
    os.makedirs(run_dir, exist_ok=True)
    coordinator = coordinator or f"localhost:{free_port()}"
    handles: List[WorkerHandle] = []
    for pid in range(num_processes):
        spec = ClusterSpec(coordinator=coordinator,
                           num_processes=num_processes,
                           process_id=pid, local_devices=local_devices)
        hb = os.path.join(run_dir, f"hb_a{attempt}_w{pid}")
        env = _worker_env(spec, hb,
                          result_path(run_dir) if pid == 0 else None)
        log = None
        out = None
        if pid != 0:
            # worker 0 narrates to the launcher's stdout; the rest log to
            # files (printed back on failure)
            log = os.path.join(run_dir, f"worker_a{attempt}_w{pid}.log")
            out = open(log, "wb")
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.cluster"]
            + list(worker_argv),
            env=env, stdout=out, stderr=subprocess.STDOUT if out else None)
        if out is not None:
            out.close()   # the child owns the fd now
        handles.append(WorkerHandle(proc=proc, process_id=pid,
                                    hb_file=hb, log_file=log))
    return handles


def kill_workers(handles: Sequence[WorkerHandle]) -> None:
    for h in handles:
        h.kill()


def sigkill(handle: WorkerHandle) -> None:
    """Hard-kill one worker (the chaos injection: no cleanup, no goodbye —
    exactly what a node loss looks like to the rest of the cluster)."""
    if handle.proc.poll() is None:
        handle.proc.send_signal(signal.SIGKILL)
