"""qwen2-moe-a2.7b [moe] — Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24 layers, d_model 2048, 16 heads (kv=16, head_dim 128), vocab 151936.
MoE: 60 routed experts (top-4, expert d_ff 1408) + 4 shared experts
(fused shared-expert hidden 4*1408 = 5632) on every layer.
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,                      # every MLP is MoE
    vocab_size=151936,
    block_pattern=(ATTN_GLOBAL,),
    num_experts=60,
    num_experts_per_tok=4,
    moe_d_ff=1408,
    num_shared_experts=4,
    shared_expert_d_ff=5632,
    mlp_kind="swiglu",
    tie_embeddings=True,
    rope_theta=1000000.0,
)
