"""mixtral-8x22b [moe] — Mixtral 8x22B [arXiv:2401.04088 lineage].

56 layers, d_model 6144, 48 heads (GQA kv=8, head_dim 128), vocab 32768.
MoE: 8 experts, top-2, expert d_ff 16384 (SwiGLU).  Sliding-window attention
(per the assigned card), window 4096.  ~141B total / ~39B active params —
the arch where the paper's strip-sharded optimizer state (ZeRO-1 via
part-reduce/part-broadcast) and FSDP weight sharding matter most; fsdp=True.
"""
from repro.configs.base import ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    source="arXiv:2401.04088 (Mixtral)",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=32768,
    block_pattern=(ATTN_LOCAL,),
    sliding_window=4096,
    num_experts=8,
    num_experts_per_tok=2,
    moe_d_ff=16384,
    mlp_kind="swiglu",
    tie_embeddings=False,
    rope_theta=1000000.0,
    fsdp=True,
    remat="block",
)
