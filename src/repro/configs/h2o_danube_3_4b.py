"""h2o-danube-3-4b [dense] — H2O-Danube3 4B [arXiv:2401.16818 lineage].

24 layers, d_model 3840, 32 heads (GQA kv=8, head_dim 120), d_ff 10240
(SwiGLU), vocab 32000.  Llama+Mistral mix with sliding-window attention
(window 4096) — runs long_500k natively (bounded KV cache).
"""
from repro.configs.base import ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    source="arXiv:2401.16818 (H2O-Danube)",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    block_pattern=(ATTN_LOCAL,),
    sliding_window=4096,
    mlp_kind="swiglu",
    tie_embeddings=False,
    rope_theta=10000.0,
)
