"""gemma2-2b [dense] — Gemma 2 2B [arXiv:2408.00118].

26 layers, d_model 2304, 8 heads (GQA kv=4, head_dim 256), d_ff 9216 (GeGLU),
vocab 256000.  Alternating local (sliding-window 4096) / global attention,
attention-logit softcap 50, final-logit softcap 30, tied embeddings.
"""
from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    source="arXiv:2408.00118 (Gemma 2)",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    block_pattern=(ATTN_LOCAL, ATTN_GLOBAL),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_kind="geglu",
    tie_embeddings=True,
    rope_theta=10000.0,
)
