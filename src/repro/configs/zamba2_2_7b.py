"""zamba2-2.7b [hybrid] — Zamba2 2.7B [arXiv:2411.15242].

54 blocks, d_model 2560, Mamba2 (SSD) backbone with a shared
attention(+MLP) block interleaved (here: every 6th block), attention
32 heads (kv=32, head_dim 80), d_ff 10240, vocab 32000, ssm_state 64.
Adaptation note (DESIGN.md): Zamba2 re-uses ONE set of shared-attention
weights at every interleave point; we reproduce that weight sharing via the
scan-over-pattern carry (the shared block's params are passed as a broadcast
argument, not stacked).
"""
from repro.configs.base import BLOCK_MAMBA, BLOCK_SHARED_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242 (Zamba2)",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    block_pattern=(BLOCK_MAMBA,) * 5 + (BLOCK_SHARED_ATTN,),
    ssm_state=64,
    ssm_heads=80,          # d_inner 5120 / ssd head dim 64
    ssm_expand=2,
    mlp_kind="geglu",
    tie_embeddings=True,
    rope_theta=10000.0,
)
