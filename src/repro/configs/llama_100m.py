"""llama-100m — a ~100M-parameter LLaMA-family config for the end-to-end
training example (examples/train_lm_100m.py).  Same block structure as
llama3-8b, scaled to laptop/CPU size [arXiv:2407.21783 lineage]."""
from repro.configs.base import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="llama-100m",
    family="dense",
    source="llama3 family, example-scale",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=32768,
    block_pattern=(ATTN_GLOBAL,),
    mlp_kind="swiglu",
    tie_embeddings=True,
    rope_theta=10000.0,
)
