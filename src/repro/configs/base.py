"""Config system for the PCL-DNN reproduction framework.

Every architecture (the paper's own CNN/DNN workloads and the ten assigned
transformer-family architectures) is described by a frozen dataclass.  Configs
are pure data: models, the launcher, the balance analyzer and the dry-run all
consume them.

Block patterns
--------------
``block_pattern`` is the repeating unit of heterogeneous layers (e.g. gemma-2's
("local", "global") alternation, zamba2's mamba/shared-attention interleave).
``num_layers`` must be ``len(block_pattern) * pattern_repeats``.  The
transformer assembly scans over ``pattern_repeats`` with the unit unrolled,
which keeps HLO size (and compile time) independent of depth.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds understood by models/transformer.py
# ---------------------------------------------------------------------------
ATTN_GLOBAL = "global"        # full causal attention
ATTN_LOCAL = "local"          # sliding-window causal attention
BLOCK_MAMBA = "mamba"         # Mamba2 (SSD) block
BLOCK_SHARED_ATTN = "shared_attn"  # zamba2-style shared attention+MLP block
BLOCK_MLSTM = "mlstm"         # xLSTM matrix-LSTM block
BLOCK_SLSTM = "slstm"         # xLSTM scalar-LSTM block


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | hybrid | ssm | audio
    source: str                      # citation for the config numbers

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # --- attention ---
    block_pattern: Tuple[str, ...] = (ATTN_GLOBAL,)
    pattern_repeats: int = 0
    sliding_window: int = 4096       # window for ATTN_LOCAL blocks
    attn_logit_softcap: float = 0.0  # gemma2: 50.0
    final_logit_softcap: float = 0.0 # gemma2: 30.0
    rope_theta: float = 10000.0
    mrope: bool = False              # qwen2-vl M-RoPE (3 rotary sections)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    qk_norm: bool = False

    # --- mlp ---
    mlp_kind: str = "swiglu"         # swiglu | geglu | gelu
    tie_embeddings: bool = True

    # --- moe ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0                # per-expert hidden size
    num_shared_experts: int = 0
    shared_expert_d_ff: int = 0
    router_aux_loss_coef: float = 0.001
    moe_capacity_factor: float = 1.25
    # --- beyond-paper perf knobs (see EXPERIMENTS.md §Perf) ---
    moe_expert_pad: int = 0       # pad expert DIM to enable expert-parallel
    moe_down_rs: bool = False     # reduce-scatter (not all-reduce) down-proj
    loss_chunk: int = 0           # CE loss computed in seq chunks
    seq_shard_carry: bool = False # store residual stream (and remat carries)
                                  # sequence-sharded on 'model' (Megatron-SP)

    # --- ssm / hybrid ---
    ssm_state: int = 0               # mamba2 state dim per head
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_conv_width: int = 4

    # --- modality frontends (stubs) ---
    frontend: Optional[str] = None   # None | "vision" | "audio"
    num_codebooks: int = 0           # musicgen
    vision_tokens: int = 1024        # qwen2-vl: patch tokens per train sample

    # --- numerics ---
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"

    # --- distribution ---
    fsdp: bool = False               # shard d_model of big weights over "data"
    long_context_window: int = 4096  # SWA window substituted at long_500k decode
    remat: str = "none"              # none | block  (activation checkpointing)

    def __post_init__(self):
        if self.pattern_repeats == 0 and self.num_layers:
            object.__setattr__(
                self, "pattern_repeats", self.num_layers // len(self.block_pattern))
        if self.num_layers:
            assert self.num_layers == self.pattern_repeats * len(self.block_pattern), (
                self.name, self.num_layers, self.block_pattern)
        if self.num_heads and self.num_kv_heads:
            assert self.num_heads % self.num_kv_heads == 0, self.name

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def replace(self, **kw) -> "ModelConfig":
        if "block_pattern" in kw or "num_layers" in kw:
            kw.setdefault("pattern_repeats", 0)
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (used by balance eqs, roofline MODEL_FLOPS) ----
    def param_count(self, active_only: bool = False) -> int:
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        n_mats = {"swiglu": 3, "geglu": 3, "gelu": 2}[self.mlp_kind]
        total = V * d  # embedding
        if not self.tie_embeddings:
            total += V * d
        for kind in self.block_pattern:
            if kind in (ATTN_GLOBAL, ATTN_LOCAL, BLOCK_SHARED_ATTN):
                attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                mlp = n_mats * d * ff if ff else 0
                if self.num_experts and kind != BLOCK_SHARED_ATTN:
                    pass
                total += (attn + mlp) * self.pattern_repeats
            elif kind == BLOCK_MAMBA:
                din = self.ssm_expand * d
                # in_proj (x, z, B, C, dt) + out_proj + conv
                nh = self.ssm_heads or max(1, din // 64)
                blk = d * (2 * din + 2 * self.ssm_state + nh) + din * d
                blk += self.ssm_conv_width * (din + 2 * self.ssm_state)
                total += blk * self.pattern_repeats
            elif kind in (BLOCK_MLSTM, BLOCK_SLSTM):
                dp = self.ssm_expand * d if kind == BLOCK_MLSTM else d
                blk = 4 * d * dp + dp * d
                total += blk * self.pattern_repeats
        if self.num_experts:
            # routed experts (+ router) and shared experts on every attn block
            n_moe_blocks = sum(
                1 for k in self.block_pattern if k in (ATTN_GLOBAL, ATTN_LOCAL)
            ) * self.pattern_repeats
            per_expert = 3 * self.d_model * self.moe_d_ff
            routed = self.num_experts * per_expert
            shared = 3 * self.d_model * self.shared_expert_d_ff
            router = self.d_model * self.num_experts
            total += n_moe_blocks * (routed + shared + router)
            # the dense d_ff path is absent for MoE blocks
            total -= n_moe_blocks * ({"swiglu": 3, "geglu": 3, "gelu": 2}[self.mlp_kind]
                                     * self.d_model * self.d_ff if self.d_ff else 0)
            if active_only:
                total -= n_moe_blocks * (self.num_experts - self.num_experts_per_tok) * per_expert
        return total


@dataclass(frozen=True)
class ConvLayerSpec:
    """One layer of a paper CNN (VGG-A / OverFeat-FAST), for models/cnn.py and
    the §3 balance equations / Table 1 benchmark."""
    kind: str          # conv | pool | fc
    ifm: int = 0
    ofm: int = 0
    kernel: int = 0
    stride: int = 1
    pad: int = 0
    out_hw: int = 0    # output feature-map spatial size (square)


@dataclass(frozen=True)
class CNNConfig:
    name: str
    source: str
    layers: Tuple[ConvLayerSpec, ...]
    image_size: int
    num_classes: int = 1000
    family: str = "cnn"

    def conv_layers(self):
        return [lyr for lyr in self.layers if lyr.kind == "conv"]

    def fc_layers(self):
        return [lyr for lyr in self.layers if lyr.kind == "fc"]


@dataclass(frozen=True)
class DNNConfig:
    """Fully-connected ASR net (paper §5.4 CD-DNN)."""
    name: str
    source: str
    input_dim: int
    hidden_dim: int
    num_hidden: int
    output_dim: int
    family: str = "dnn"


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Hardware models (paper's platforms + our TPU target)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HardwareConfig:
    name: str
    peak_flops: float          # per chip/node, FLOP/s
    mem_bw: float              # bytes/s HBM or DRAM
    link_bw: float             # bytes/s network/ICI per direction
    sw_latency: float = 5e-6   # per-message software overhead (paper's SWlat)
    cache_bytes: int = 0       # on-chip capacity used by the blocking solver


TPU_V5E = HardwareConfig(
    name="tpu-v5e",
    peak_flops=197e12,         # bf16
    mem_bw=819e9,
    link_bw=50e9,              # per ICI link
    cache_bytes=16 * 2**20,    # ~16 MiB VMEM usable half for double buffering
)

# Paper platforms (Table 1 / §5):
XEON_E5_2698V3_FDR = HardwareConfig(
    # 2s16c HSW 2.3GHz: 2 sockets * 16 cores * 32 flops/cycle(FMA AVX2 SP) * 2.3e9
    name="2s16c-E5-2698v3+FDR",
    peak_flops=2 * 16 * 32 * 2.3e9,   # ~2.36 TF SP
    mem_bw=136e9,
    # 56 Gbps FDR = 7 GB/s: with these raw constants the paper's Table-1
    # "comp-to-comms" of 336 is reproduced exactly (2355 GF / 7 GB/s = 336).
    link_bw=56e9 / 8,
    cache_bytes=128 * 1024,           # per-thread budget used in the paper
)
XEON_E5_2666V3_10GBE = HardwareConfig(
    name="2s9c-E5-2666v3+10GbE",
    peak_flops=2 * 9 * 32 * 2.9e9,    # ~1.67 TF SP
    mem_bw=136e9,
    # 10 GbE = 1.25 GB/s: 1670 GF / 1.25 GB/s = 1336 = paper's Table-1 value.
    link_bw=10e9 / 8,
    cache_bytes=128 * 1024,
)
XEON_E5_2697V3 = HardwareConfig(
    name="2s14c-E5-2697v3",
    peak_flops=1.7e12,                # paper: 1.7 TFLOPS/s SP peak
    mem_bw=136e9,
    link_bw=56e9 / 8 * 0.9,
    cache_bytes=128 * 1024,
)
