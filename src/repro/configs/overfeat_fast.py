"""OverFeat-FAST — the paper's second CNN workload
[Sermanet et al. 2013, arXiv:1312.6229]; paper §2.2 uses its C5 layer
(12x12 output, 3x3 kernel, 512 ifm, 1024 ofm) as the blocking case study.
"""
from repro.configs.base import CNNConfig, ConvLayerSpec as L

CONFIG = CNNConfig(
    name="overfeat-fast",
    source="arXiv:1312.6229 (OverFeat, fast model); paper §2.2, §5",
    image_size=231,
    num_classes=1000,
    layers=(
        L("conv", ifm=3,    ofm=96,   kernel=11, stride=4, pad=0, out_hw=56),
        L("pool", out_hw=28),
        L("conv", ifm=96,   ofm=256,  kernel=5,  stride=1, pad=0, out_hw=24),
        L("pool", out_hw=12),
        L("conv", ifm=256,  ofm=512,  kernel=3,  stride=1, pad=1, out_hw=12),
        # paper's "C5": 512 ifm -> 1024 ofm, 3x3, 12x12 output
        L("conv", ifm=512,  ofm=1024, kernel=3,  stride=1, pad=1, out_hw=12),
        L("conv", ifm=1024, ofm=1024, kernel=3,  stride=1, pad=1, out_hw=12),
        L("pool", out_hw=6),
        L("fc", ifm=1024 * 6 * 6, ofm=3072, out_hw=1),
        L("fc", ifm=3072, ofm=4096, out_hw=1),
        L("fc", ifm=4096, ofm=1000, out_hw=1),
    ),
)
