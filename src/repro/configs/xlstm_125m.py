"""xlstm-125m [ssm] — xLSTM 125M [arXiv:2405.04517].

12 blocks, d_model 768, 4 heads (head_dim 192), vocab 50304, d_ff 0 (the
xLSTM blocks carry their own up/down projections, expand 2).  Alternating
mLSTM (matrix memory) / sLSTM (scalar memory) blocks — an xLSTM[1:1]-style
stack.
"""
from repro.configs.base import BLOCK_MLSTM, BLOCK_SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    source="arXiv:2405.04517 (xLSTM)",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    block_pattern=(BLOCK_MLSTM, BLOCK_SLSTM),
    ssm_expand=2,
    mlp_kind="gelu",
    tie_embeddings=True,
)
