"""musicgen-medium [audio] — MusicGen medium [arXiv:2306.05284].

48 layers, d_model 1536, 24 heads (kv=24, head_dim 64), d_ff 6144 (GELU),
vocab 2048 per EnCodec codebook (4 codebooks, delay interleave pattern).
The EnCodec conv codec is a STUB (`frontends.AudioStub`): input_specs supply
(B, S, d_model) frame embeddings (the 4 codebook embeddings summed); the
48-layer decoder-only transformer over those frames is real, with 4 parallel
codebook heads on the output.
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    source="arXiv:2306.05284 (MusicGen)",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    block_pattern=(ATTN_GLOBAL,),
    mlp_kind="gelu",
    tie_embeddings=False,
    frontend="audio",
    num_codebooks=4,
)
