"""CD-DNN — context-dependent DNN-HMM acoustic model, the paper's ASR
workload (§5.4) [Seide et al. 2011].  7 fully-connected hidden layers of
2048 neurons; 440-dim fbank context window input; 9304 tied-triphone
senone outputs.
"""
from repro.configs.base import DNNConfig

CONFIG = DNNConfig(
    name="cd-dnn",
    source="Seide et al. 2011 (CD-DNN-HMM); paper §5.4",
    input_dim=440,
    hidden_dim=2048,
    num_hidden=7,
    output_dim=9304,
)
