"""Architecture registry: ``--arch <id>`` resolution + reduced smoke variants."""
from __future__ import annotations

import importlib
from typing import Union

from repro.configs.base import (
    ModelConfig, CNNConfig, DNNConfig, InputShape, INPUT_SHAPES,
    BLOCK_MAMBA, BLOCK_SHARED_ATTN, BLOCK_MLSTM, BLOCK_SLSTM,
)

# assigned pool (10) + the paper's own workloads (3)
_MODULES = {
    "gemma2-2b": "gemma2_2b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "llama3-8b": "llama3_8b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "zamba2-2.7b": "zamba2_2_7b",
    "xlstm-125m": "xlstm_125m",
    "musicgen-medium": "musicgen_medium",
    "gemma-2b": "gemma_2b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "mixtral-8x22b": "mixtral_8x22b",
    "llama-100m": "llama_100m",
    "vgg-a": "vgg_a",
    "overfeat-fast": "overfeat_fast",
    "cd-dnn": "cd_dnn",
}

ASSIGNED_ARCHS = (
    "gemma2-2b", "qwen2-moe-a2.7b", "llama3-8b", "qwen2-vl-2b",
    "zamba2-2.7b", "xlstm-125m", "musicgen-medium", "gemma-2b",
    "h2o-danube-3-4b", "mixtral-8x22b",
)
PAPER_ARCHS = ("vgg-a", "overfeat-fast", "cd-dnn")
ALL_ARCHS = ASSIGNED_ARCHS + PAPER_ARCHS

AnyConfig = Union[ModelConfig, CNNConfig, DNNConfig]


def get_config(name: str) -> AnyConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_input_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


def smoke_variant(cfg: AnyConfig) -> AnyConfig:
    """Reduced variant of the same family for CPU smoke tests:
    ≤2 pattern repeats, d_model ≤ 512, ≤4 experts, small vocab."""
    if isinstance(cfg, CNNConfig):
        # keep first two convs + last fc, shrink maps
        from repro.configs.base import ConvLayerSpec as L
        return CNNConfig(
            name=cfg.name + "-smoke", source=cfg.source, image_size=32,
            num_classes=16,
            layers=(
                L("conv", ifm=3, ofm=16, kernel=3, stride=1, pad=1, out_hw=32),
                L("pool", out_hw=16),
                L("conv", ifm=16, ofm=32, kernel=3, stride=1, pad=1, out_hw=16),
                L("pool", out_hw=8),
                L("fc", ifm=32 * 8 * 8, ofm=64, out_hw=1),
                L("fc", ifm=64, ofm=16, out_hw=1),
            ),
        )
    if isinstance(cfg, DNNConfig):
        return DNNConfig(name=cfg.name + "-smoke", source=cfg.source,
                         input_dim=40, hidden_dim=64, num_hidden=3,
                         output_dim=32)

    unit = cfg.block_pattern
    # keep the heterogeneity of the unit but only 1-2 repeats
    repeats = 1 if len(unit) > 2 else 2
    d_model = min(cfg.d_model, 256)
    head_dim = 32
    heads = max(2, min(4, cfg.num_heads))
    kv = max(1, min(heads, cfg.num_kv_heads))
    while heads % kv:
        kv -= 1
    # rescale M-RoPE sections to the reduced head_dim (keep 1/4:3/8:3/8)
    mrope_sections = cfg.mrope_sections
    if cfg.mrope:
        half = head_dim // 2
        a = half // 4
        b = (half - a) // 2
        mrope_sections = (a, b, half - a - b)
    return cfg.replace(
        num_layers=repeats * len(unit),
        pattern_repeats=repeats,
        mrope_sections=mrope_sections,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2) if cfg.num_experts else 0,
        # dropless in smoke tests so decode == train-path routing exactly
        moe_capacity_factor=(min(cfg.num_experts, 4)
                             / max(1, min(cfg.num_experts_per_tok, 2))
                             if cfg.num_experts else 1.25),
        moe_d_ff=min(cfg.moe_d_ff, 128) if cfg.moe_d_ff else 0,
        num_shared_experts=min(cfg.num_shared_experts, 1),
        shared_expert_d_ff=min(cfg.shared_expert_d_ff, 128),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_heads=min(cfg.ssm_heads, 8) if cfg.ssm_heads else 0,
        sliding_window=min(cfg.sliding_window, 64),
        long_context_window=64,
        vision_tokens=16,
        remat="none",
        fsdp=False,
    )
