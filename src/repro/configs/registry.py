"""Architecture registry: ``--arch <id>`` resolution + reduced smoke variants."""
from __future__ import annotations

import importlib
from typing import Union

from repro.configs.base import INPUT_SHAPES, CNNConfig, DNNConfig, InputShape, ModelConfig

# assigned pool (10) + the paper's own workloads (3)
_MODULES = {
    "gemma2-2b": "gemma2_2b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "llama3-8b": "llama3_8b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "zamba2-2.7b": "zamba2_2_7b",
    "xlstm-125m": "xlstm_125m",
    "musicgen-medium": "musicgen_medium",
    "gemma-2b": "gemma_2b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "mixtral-8x22b": "mixtral_8x22b",
    "llama-100m": "llama_100m",
    "vgg-a": "vgg_a",
    "overfeat-fast": "overfeat_fast",
    "cd-dnn": "cd_dnn",
}

ASSIGNED_ARCHS = (
    "gemma2-2b", "qwen2-moe-a2.7b", "llama3-8b", "qwen2-vl-2b",
    "zamba2-2.7b", "xlstm-125m", "musicgen-medium", "gemma-2b",
    "h2o-danube-3-4b", "mixtral-8x22b",
)
PAPER_ARCHS = ("vgg-a", "overfeat-fast", "cd-dnn")
ALL_ARCHS = ASSIGNED_ARCHS + PAPER_ARCHS

AnyConfig = Union[ModelConfig, CNNConfig, DNNConfig]


def get_config(name: str) -> AnyConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_input_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


def smoke_variant(cfg: AnyConfig) -> AnyConfig:
    """Reduced variant of the same family for CPU smoke tests:
    ≤2 pattern repeats, d_model ≤ 512, ≤4 experts, small vocab.

    The reduction recipe lives with each family's adapter
    (``repro.api.families``); this stays as the stable entry point."""
    from repro.api.families import adapter_for
    return adapter_for(cfg).smoke(cfg)
