"""llama3-8b [dense] — Llama 3 8B [arXiv:2407.21783].

32 layers, d_model 4096, 32 heads (GQA kv=8, head_dim 128), d_ff 14336
(SwiGLU), vocab 128256, rope theta 500000, untied embeddings.
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    source="arXiv:2407.21783 (Llama 3)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    block_pattern=(ATTN_GLOBAL,),
    mlp_kind="swiglu",
    tie_embeddings=False,
    rope_theta=500000.0,
)
