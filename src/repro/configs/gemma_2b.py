"""gemma-2b [dense] — Gemma 2B [arXiv:2403.08295].

18 layers, d_model 2048, 8 heads with MQA (kv=1, head_dim 256), d_ff 16384
(GeGLU), vocab 256000, tied embeddings.
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    source="arXiv:2403.08295 (Gemma)",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    block_pattern=(ATTN_GLOBAL,),
    mlp_kind="geglu",
    tie_embeddings=True,
    rope_theta=10000.0,
)
