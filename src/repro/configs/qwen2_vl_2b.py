"""qwen2-vl-2b [vlm] — Qwen2-VL 2B [arXiv:2409.12191].

28 layers, d_model 1536, 12 heads (GQA kv=2, head_dim 128), d_ff 8960
(SwiGLU), vocab 151936.  M-RoPE (temporal/height/width rotary sections),
dynamic-resolution vision input.  The ViT/projector frontend is a STUB
(`frontends.VisionStub`): input_specs supply (B, vision_tokens, d_model)
patch embeddings; the language decoder + M-RoPE + interleave are real.
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    source="arXiv:2409.12191 (Qwen2-VL)",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    block_pattern=(ATTN_GLOBAL,),
    mlp_kind="swiglu",
    tie_embeddings=True,
    rope_theta=1000000.0,
    mrope=True,
    mrope_sections=(16, 24, 24),
    frontend="vision",
    vision_tokens=1024,
)
