from repro.configs.base import (  # noqa: F401
    ModelConfig, CNNConfig, DNNConfig, ConvLayerSpec, InputShape, INPUT_SHAPES,
    HardwareConfig, TPU_V5E, XEON_E5_2698V3_FDR, XEON_E5_2666V3_10GBE,
    XEON_E5_2697V3,
)
from repro.configs.registry import (  # noqa: F401
    get_config, get_input_shape, smoke_variant, ALL_ARCHS, ASSIGNED_ARCHS,
    PAPER_ARCHS,
)
