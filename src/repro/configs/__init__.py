from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    TPU_V5E,
    XEON_E5_2666V3_10GBE,
    XEON_E5_2697V3,
    XEON_E5_2698V3_FDR,
    CNNConfig,
    ConvLayerSpec,
    DNNConfig,
    HardwareConfig,
    InputShape,
    ModelConfig,
)
from repro.configs.registry import (  # noqa: F401
    ALL_ARCHS,
    ASSIGNED_ARCHS,
    PAPER_ARCHS,
    get_config,
    get_input_shape,
    smoke_variant,
)
