"""VGG-A (configuration A, 11 weight layers) — the paper's main scaling
workload [Simonyan & Zisserman 2014, arXiv:1409.1556]; paper §5.2.

Spatial sizes follow the 224x224 ImageNet pipeline the paper used.
"""
from repro.configs.base import CNNConfig, ConvLayerSpec as L

CONFIG = CNNConfig(
    name="vgg-a",
    source="arXiv:1409.1556 (VGG, config A); paper §5.2",
    image_size=224,
    num_classes=1000,
    layers=(
        L("conv", ifm=3,   ofm=64,  kernel=3, stride=1, pad=1, out_hw=224),
        L("pool", out_hw=112),
        L("conv", ifm=64,  ofm=128, kernel=3, stride=1, pad=1, out_hw=112),
        L("pool", out_hw=56),
        L("conv", ifm=128, ofm=256, kernel=3, stride=1, pad=1, out_hw=56),
        L("conv", ifm=256, ofm=256, kernel=3, stride=1, pad=1, out_hw=56),
        L("pool", out_hw=28),
        L("conv", ifm=256, ofm=512, kernel=3, stride=1, pad=1, out_hw=28),
        L("conv", ifm=512, ofm=512, kernel=3, stride=1, pad=1, out_hw=28),
        L("pool", out_hw=14),
        L("conv", ifm=512, ofm=512, kernel=3, stride=1, pad=1, out_hw=14),
        L("conv", ifm=512, ofm=512, kernel=3, stride=1, pad=1, out_hw=14),
        L("pool", out_hw=7),
        L("fc", ifm=512 * 7 * 7, ofm=4096, out_hw=1),
        L("fc", ifm=4096, ofm=4096, out_hw=1),
        L("fc", ifm=4096, ofm=1000, out_hw=1),
    ),
)
