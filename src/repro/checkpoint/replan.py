"""Re-plan zero1 strip optimizer state for a DIFFERENT world size.

An elastic restart changes G (the data-parallel group): a checkpoint saved
at G=8 holds strip leaves shaped (8, padded8/8) that a G=4 run cannot load
by shape.  But the §3.4 strip decomposition makes the conversion exact,
not approximate:

  * bucket BOUNDARIES are G-independent — ``plan_buckets`` closes buckets
    on byte capacity and dtype runs over the (world-size-agnostic) param
    tree, so both worlds agree on which elements each bucket holds; only
    ``padded_size`` (round up to a multiple of G) differs;
  * the pad tail holds zeros forever — it is zero at init, the packed
    gradient there is structurally zero (``pack_bucket`` pads with zeros),
    and the optimizer recurrences (momentum, Adam moments) keep zero at
    zero — so truncating the old pad and zero-filling the new one loses
    nothing;
  * under the hierarchical schedule rows sit in OWNER order
    (``optim.dist.owner_perm``); unpermute to value order, reslice, apply
    the new world's perm.

Combined with the G-invariance of the update itself (property-tested
against the serial optimizer), a replanned resume continues the SAME
trajectory the smaller world would have produced — which is exactly what
the chaos test asserts.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

import jax

from repro.comm.bucketer import BucketPlan
from repro.core.collectives import padded_size
from repro.optim.dist import owner_perm


def world_meta(axes_sizes: Sequence[int], hierarchical: bool,
               bucket_bytes: int) -> Dict:
    """The JSON-able world-layout record ``ckpt.save`` stores under
    ``meta["zero1"]`` — everything ``replan_strip_state`` needs to undo
    the saved layout."""
    sizes = [int(s) for s in axes_sizes]
    g = 1
    for s in sizes:
        g *= s
    return {"G": g, "axes_sizes": sizes, "hierarchical": bool(hierarchical),
            "bucket_bytes": int(bucket_bytes)}


def _perm(world: Dict) -> Optional[np.ndarray]:
    return owner_perm(world["hierarchical"], world["axes_sizes"])


def replan_strip_leaf(arr: np.ndarray, payload: int, old_world: Dict,
                      new_world: Dict) -> np.ndarray:
    """One (G_old, padded_old/G_old) strip leaf -> (G_new, padded_new/G_new).

    ``payload`` is the bucket's real element count (G-independent); the
    regions beyond it are the always-zero pad."""
    g_old, g_new = old_world["G"], new_world["G"]
    if arr.ndim != 2 or arr.shape[0] != g_old:
        raise ValueError(
            f"strip leaf has shape {arr.shape}, expected ({g_old}, n) "
            f"for the saved world size {g_old}")
    if arr.size != padded_size(payload, g_old):
        raise ValueError(
            f"strip leaf holds {arr.size} elements, bucket payload "
            f"{payload} at G={g_old} implies {padded_size(payload, g_old)} "
            "— bucket plans disagree (different bucket_bytes or params?)")
    p_old = _perm(old_world)
    if p_old is not None:
        # stored row j is strip p_old[j]; argsort inverts back to value order
        arr = arr[np.argsort(p_old)]
    flat = arr.reshape(-1)[:payload]
    out = np.zeros(padded_size(payload, g_new), dtype=arr.dtype)
    out[:payload] = flat
    out = out.reshape(g_new, -1)
    p_new = _perm(new_world)
    if p_new is not None:
        out = out[p_new]
    return out


def replan_strip_state(template_state, old_leaves: List[np.ndarray],
                       plan: BucketPlan, old_world: Dict, new_world: Dict):
    """Convert a saved opt_state (flattened as ``old_leaves``, the old
    world's shapes) into ``template_state``'s structure and the new world's
    strip shapes.

    The tree STRUCTURE is world-size-invariant (same optimizer, same bucket
    count), so leaves pair up positionally; leaves with ndim >= 2 are strip
    tensors cycling through the buckets in plan order (optimizer state is
    field-major: momentum[b0], momentum[b1], ..., m[b0], m[b1], ...), and
    everything else (e.g. the AdamW step count) passes through unchanged.
    """
    if old_world.get("bucket_bytes") != new_world.get("bucket_bytes"):
        raise ValueError(
            f"cannot replan across bucket_bytes change: checkpoint has "
            f"{old_world.get('bucket_bytes')}, run has "
            f"{new_world.get('bucket_bytes')} (bucket boundaries are only "
            "G-independent for a fixed byte capacity)")
    flat_tpl, treedef = jax.tree.flatten(template_state)
    if len(flat_tpl) != len(old_leaves):
        raise ValueError(
            f"opt_state has {len(flat_tpl)} leaves, checkpoint has "
            f"{len(old_leaves)} — tree structure changed since the save")
    payloads = [b.size for b in plan.buckets]
    out = []
    strip_i = 0
    for tpl, old in zip(flat_tpl, old_leaves):
        old = np.asarray(old)
        if getattr(tpl, "ndim", 0) >= 2:
            new = replan_strip_leaf(old, payloads[strip_i % len(payloads)],
                                    old_world, new_world)
            strip_i += 1
            if tuple(new.shape) != tuple(tpl.shape):
                raise ValueError(
                    f"replanned strip has shape {new.shape}, template "
                    f"expects {tuple(tpl.shape)}")
            out.append(new.astype(np.asarray(tpl).dtype
                                  if not hasattr(tpl, "dtype")
                                  else tpl.dtype))
        else:
            out.append(old.reshape(getattr(tpl, "shape", old.shape)))
    if strip_i and strip_i % len(payloads):
        raise ValueError(
            f"saw {strip_i} strip leaves for {len(payloads)} buckets — "
            "state fields are not whole multiples of the bucket count")
    return jax.tree.unflatten(treedef, out)
