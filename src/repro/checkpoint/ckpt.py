"""Checkpointing: flattened-pytree npz + JSON manifest.

Arrays are gathered to host (fine at example scale; sharded per-host writes
would slot in here on a real cluster — the manifest format already records
per-leaf paths)."""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(directory: str, step: int, **trees) -> str:
    os.makedirs(directory, exist_ok=True)
    payload: Dict[str, np.ndarray] = {}
    manifest: Dict[str, Any] = {"step": step, "trees": {}}
    for name, tree in trees.items():
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        keys = []
        for path, leaf in flat:
            k = f"{name}:{_key_str(path)}"
            payload[k] = np.asarray(jax.device_get(leaf))
            keys.append(k)
        manifest["trees"][name] = keys
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez(path, **payload)
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(manifest, f)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(directory: str, step: int, **templates) -> Tuple[Dict[str, Any], int]:
    """templates: name=pytree-with-matching-structure.  Returns (trees, step)."""
    data = np.load(os.path.join(directory, f"ckpt_{step:08d}.npz"))
    out = {}
    for name, template in templates.items():
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, leaf in flat:
            k = f"{name}:{_key_str(path)}"
            arr = jnp.asarray(data[k])
            assert arr.shape == leaf.shape, (k, arr.shape, leaf.shape)
            leaves.append(arr)
        out[name] = jax.tree_util.tree_unflatten(treedef, leaves)
    return out, step
