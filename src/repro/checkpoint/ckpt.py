"""Checkpointing: flattened-pytree npz + JSON manifest.

Arrays are gathered to host (fine at example scale; sharded per-host writes
would slot in here on a real cluster — the manifest format already records
per-leaf paths)."""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(directory: str, step: int, **trees) -> str:
    os.makedirs(directory, exist_ok=True)
    payload: Dict[str, np.ndarray] = {}
    manifest: Dict[str, Any] = {"step": step, "trees": {}}
    for name, tree in trees.items():
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        keys = []
        for path, leaf in flat:
            k = f"{name}:{_key_str(path)}"
            payload[k] = np.asarray(jax.device_get(leaf))
            keys.append(k)
        manifest["trees"][name] = keys
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez(path, **payload)
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(manifest, f)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(directory: str, step: int, **templates) -> Tuple[Dict[str, Any], int]:
    """templates: name=pytree-with-matching-structure.  Returns (trees, step).

    Raises real exceptions — ``FileNotFoundError`` for a missing checkpoint,
    ``KeyError`` for a leaf absent from the archive (tree structure changed
    since save), ``ValueError`` on shape or dtype mismatch.  ``assert`` is
    not used: shape checks must survive ``python -O``."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    if not os.path.exists(path):
        raise FileNotFoundError(f"no checkpoint at {path}")
    data = np.load(path)
    out = {}
    for name, template in templates.items():
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for leaf_path, leaf in flat:
            k = f"{name}:{_key_str(leaf_path)}"
            if k not in data.files:
                raise KeyError(
                    f"checkpoint {path} has no leaf {k!r} — was the tree "
                    f"structure changed since the save?")
            arr = data[k]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"checkpoint leaf {k!r} has shape {tuple(arr.shape)}, "
                    f"template expects {tuple(leaf.shape)}")
            if np.dtype(arr.dtype) != np.dtype(leaf.dtype):
                raise ValueError(
                    f"checkpoint leaf {k!r} has dtype {np.dtype(arr.dtype)}, "
                    f"template expects {np.dtype(leaf.dtype)}")
            leaves.append(jnp.asarray(arr))
        out[name] = jax.tree_util.tree_unflatten(treedef, leaves)
    return out, step
