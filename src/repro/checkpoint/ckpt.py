"""Checkpointing: flattened-pytree npz + JSON manifest.

Arrays are gathered to host before writing.  On a multi-process cluster a
leaf may not be fully addressable (zero1 optimizer strips live sharded
over the cross-host "pod" axis), so gathering goes through
``multihost_utils.process_allgather`` — a COLLECTIVE, which every process
must enter; only process 0 then touches the filesystem, and it writes
tmp + ``os.replace`` with the ``.npz`` last so a checkpoint either exists
completely or not at all (a worker killed mid-save must never leave a
torn "latest" checkpoint for the elastic restart to trip over).

The manifest carries an optional ``meta`` dict.  The trainer records the
zero1 world layout there (group size, axis sizes, hierarchical flag,
bucket bytes) so a restart at a DIFFERENT world size can re-plan the strip
state instead of failing the shape check — see ``checkpoint.replan``.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _to_host(leaf) -> np.ndarray:
    """Global host value of ``leaf``.  Fully-addressable arrays (every
    single-process array) fetch directly; a multihost-sharded array needs
    the collective allgather — every process must reach this line."""
    if isinstance(leaf, jax.Array) and not leaf.is_fully_addressable:
        from jax.experimental import multihost_utils
        return np.asarray(multihost_utils.process_allgather(leaf,
                                                            tiled=True))
    return np.asarray(jax.device_get(leaf))


def _manifest_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:08d}.json")


def save(directory: str, step: int, meta: Optional[Dict[str, Any]] = None,
         **trees) -> str:
    os.makedirs(directory, exist_ok=True)
    payload: Dict[str, np.ndarray] = {}
    manifest: Dict[str, Any] = {"step": step, "trees": {},
                                "meta": meta or {}}
    for name, tree in trees.items():
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        keys = []
        for path, leaf in flat:
            k = f"{name}:{_key_str(path)}"
            payload[k] = _to_host(leaf)   # collective on a cluster
            keys.append(k)
        manifest["trees"][name] = keys
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    if jax.process_index() != 0:
        return path   # every process gathered; one writes
    # manifest first, npz last: the .npz is what latest_step keys on, so
    # its appearance commits the checkpoint atomically
    mpath = _manifest_path(directory, step)
    with open(mpath + ".tmp", "w") as f:
        json.dump(manifest, f)
    os.replace(mpath + ".tmp", mpath)
    np.savez(path + ".tmp.npz", **payload)
    os.replace(path + ".tmp.npz", path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def read_manifest(directory: str, step: int) -> Dict[str, Any]:
    """The checkpoint's JSON manifest (``step``, ``trees``, ``meta``).
    Pre-meta checkpoints get an empty ``meta`` dict."""
    with open(_manifest_path(directory, step)) as f:
        manifest = json.load(f)
    manifest.setdefault("meta", {})
    return manifest


def restore(directory: str, step: int, **templates) -> Tuple[Dict[str, Any], int]:
    """templates: name=pytree-with-matching-structure.  Returns (trees, step).

    Raises real exceptions — ``FileNotFoundError`` for a missing checkpoint,
    ``KeyError`` for a leaf absent from the archive (tree structure changed
    since save), ``ValueError`` on shape or dtype mismatch.  ``assert`` is
    not used: shape checks must survive ``python -O``."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    if not os.path.exists(path):
        raise FileNotFoundError(f"no checkpoint at {path}")
    data = np.load(path)
    out = {}
    for name, template in templates.items():
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for leaf_path, leaf in flat:
            k = f"{name}:{_key_str(leaf_path)}"
            if k not in data.files:
                raise KeyError(
                    f"checkpoint {path} has no leaf {k!r} — was the tree "
                    f"structure changed since the save?")
            arr = data[k]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"checkpoint leaf {k!r} has shape {tuple(arr.shape)}, "
                    f"template expects {tuple(leaf.shape)}")
            if np.dtype(arr.dtype) != np.dtype(leaf.dtype):
                raise ValueError(
                    f"checkpoint leaf {k!r} has dtype {np.dtype(arr.dtype)}, "
                    f"template expects {np.dtype(leaf.dtype)}")
            leaves.append(jnp.asarray(arr))
        out[name] = jax.tree_util.tree_unflatten(treedef, leaves)
    return out, step


def restore_loose(directory: str, step: int, name: str,
                  template) -> List[np.ndarray]:
    """The saved leaves of tree ``name`` in ``template``'s flatten order,
    as raw host arrays with NO shape/dtype validation — the input to
    ``checkpoint.replan`` when the saved world size differs from the
    current one (strip leaves then legitimately have different shapes).
    Structure must still match (``KeyError`` otherwise)."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    if not os.path.exists(path):
        raise FileNotFoundError(f"no checkpoint at {path}")
    data = np.load(path)
    flat, _ = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for leaf_path, _leaf in flat:
        k = f"{name}:{_key_str(leaf_path)}"
        if k not in data.files:
            raise KeyError(
                f"checkpoint {path} has no leaf {k!r} — was the tree "
                f"structure changed since the save?")
        leaves.append(data[k])
    return leaves
