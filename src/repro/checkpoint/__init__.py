from repro.checkpoint import ckpt, replan  # noqa: F401
from repro.checkpoint.ckpt import (  # noqa: F401
    latest_step,
    read_manifest,
    restore,
    restore_loose,
    save,
)
from repro.checkpoint.replan import (  # noqa: F401
    replan_strip_leaf,
    replan_strip_state,
    world_meta,
)
