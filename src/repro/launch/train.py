"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

A thin argparse shim over the declarative run-assembly API: flags build a
``repro.api.RunSpec``, ``compile_run`` does the assembly (family resolution,
mesh, placement, update-path selection), and ``Run.fit`` trains.

    # the paper's §3.4 strip update through the bucketed comm subsystem,
    # with each bucket's reduce issued inside backprop (§3.1 overlap)
    python -m repro.launch.train --arch vgg-a --smoke \\
        --parallel zero1 --bucket-mb 4 --wire-dtype bf16 --overlap

    # same, on the explicit Pallas ring collectives instead of lax
    python -m repro.launch.train --arch vgg-a --smoke \\
        --parallel zero1 --comm-backend pallas-ring

    # compressed bytes-on-wire: int8-quantized hops fused into the ring
    # (or --wire-format topk for sparsified + error-feedback)
    python -m repro.launch.train --arch vgg-a --smoke \\
        --parallel zero1 --comm-backend pallas-ring --wire-format int8

    # the relaxed-consistency modes on the same pipeline: bounded
    # staleness (apply last step's reduce) / GossipGraD partner exchange
    python -m repro.launch.train --arch vgg-a --smoke --parallel stale-sync
    python -m repro.launch.train --arch vgg-a --smoke --parallel gossip

A ``--ckpt-dir`` run periodically checkpoints AND auto-resumes: relaunching
the same command picks up from the latest saved step (params, optimizer
strips and data-stream position), not from step 0.

On CPU (this container) use --smoke for the reduced config; on a real TPU
slice the full config shards across the detected devices with the same
rules/plan machinery the dry-run exercises."""
from __future__ import annotations

import argparse

from repro.api import (
    MIB,
    MODE_CAPS,
    PARALLEL_MODES,
    SCHEDULES,
    MeshSpec,
    RunSpec,
    compile_run,
)
from repro.comm import COLLECTIVE_BACKENDS, WIRE_FORMATS, CommConfig
from repro.configs import ALL_ARCHS

WIRE_DTYPES = {"fp32": "float32", "bf16": "bfloat16"}


def comm_flags_set(args) -> bool:
    """True when any explicit-bucketed-collectives flag departs from its
    default (these require a comm-capable --parallel mode — see
    ``MODE_CAPS``)."""
    return (args.bucket_mb is not None or args.wire_dtype != "fp32"
            or args.overlap or args.comm_backend != "lax"
            or args.cross_backend is not None
            or args.wire_format is not None)


def spec_from_args(args, cluster: bool = False) -> RunSpec:
    comm = None
    if getattr(args, "comm", None) == "auto":
        # measured-feedback autotune: compile_run times the real per-bucket
        # collectives and picks bucket size/backend (repro.telemetry.autotune)
        comm = "auto"
    elif comm_flags_set(args):
        caps = MODE_CAPS[args.parallel]
        bucket_mb = 4.0 if args.bucket_mb is None else args.bucket_mb
        # the argparse default "lax" means "the mode's default backend" —
        # gossip's semantics live in its backend, so the name maps there
        backend = args.comm_backend
        if backend == "lax" and caps.default_backend is not None:
            backend = caps.default_backend
        # gossip stays flat even multi-pod: a hierarchical schedule would
        # scope the partner rotation to each pod (see api.assemble)
        hierarchical = ((args.pods > 1 or cluster)
                        and args.parallel != "gossip")
        comm = CommConfig(bucket_bytes=int(bucket_mb * MIB),
                          reduce_dtype=WIRE_DTYPES[args.wire_dtype],
                          hierarchical=hierarchical,
                          overlap=args.overlap,
                          backend=backend,
                          cross_backend=args.cross_backend or "lax",
                          wire_format=args.wire_format,
                          topk_ratio=args.topk_ratio)
    ckpt_every = 0
    if args.ckpt_dir:
        ckpt_every = args.ckpt_every if args.ckpt_every \
            else max(args.steps // 5, 1)
    return RunSpec(
        arch=args.arch, smoke=args.smoke, parallel=args.parallel,
        mesh=MeshSpec(pods=args.pods, model_ways=args.model_ways,
                      cluster=cluster),
        comm=comm, optimizer=args.optimizer, lr=args.lr,
        schedule=args.schedule,
        steps=args.steps, batch=args.batch, seq=args.seq, seed=args.seed,
        log_every=5, ckpt_every=ckpt_every, ckpt_dir=args.ckpt_dir,
        telemetry=getattr(args, "trace_dir", None))


def add_run_args(ap: argparse.ArgumentParser, parallel_default: str = "dp"):
    """The training-run flag set, shared with the multi-host launcher
    (``repro.launch.cluster``) so a cluster run is configured with exactly
    the flags a single-process run is."""
    ap.add_argument("--arch", required=True, choices=list(ALL_ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schedule", default="warmup_cosine",
                    choices=list(SCHEDULES),
                    help="LR schedule; linear-scale-warmup is Goyal et "
                         "al.'s large-batch recipe (peak = lr x the "
                         "data-parallel ways, gradual warmup from lr)")
    ap.add_argument("--parallel", default=parallel_default,
                    choices=list(PARALLEL_MODES),
                    help="serial | dp (pjit/GSPMD) | zero1 (explicit "
                         "bucketed §3.4 strips) | zero1-gspmd | stale-sync "
                         "(bounded staleness: apply last step's reduce) | "
                         "gossip (GossipGraD rotating partner exchange)")
    ap.add_argument("--pods", type=int, default=1,
                    help="pod axis extent (>1 adds the cross-pod "
                         "hierarchical hop)")
    ap.add_argument("--model-ways", type=int, default=1)
    ap.add_argument("--bucket-mb", type=float, default=None,
                    help="fusion-buffer size in MiB for --parallel zero1 "
                         "(default 4)")
    ap.add_argument("--wire-dtype", default="fp32", choices=list(WIRE_DTYPES),
                    help="gradient part-reduce wire dtype (zero1)")
    ap.add_argument("--wire-format", default=None,
                    choices=list(WIRE_FORMATS),
                    help="gradient bytes-on-wire encoding: fp32/bf16 "
                         "(dense), int8 (per-message scales, f32 "
                         "accumulate per hop), topk ((values, indices) "
                         "sparse messages + error-feedback residual; "
                         "zero1 only).  Default: derived from --wire-dtype")
    ap.add_argument("--topk-ratio", type=float, default=0.05,
                    help="fraction of entries kept per message under "
                         "--wire-format topk")
    ap.add_argument("--overlap", action="store_true",
                    help="issue each bucket's part-reduce inside the "
                         "backward pass (§3.1 bubble schedule) instead of "
                         "reducing after value_and_grad (zero1)")
    ap.add_argument("--comm-backend", default="lax",
                    choices=list(COLLECTIVE_BACKENDS),
                    help="collective implementation for the zero1 "
                         "schedules: lax (XLA collectives) or pallas-ring "
                         "(the paper's explicit §3.4 ring; in-pod only "
                         "under --pods>1, the cross-pod hop stays lax)")
    ap.add_argument("--cross-backend", default=None,
                    choices=list(COLLECTIVE_BACKENDS),
                    help="collective implementation for the CROSS-POD hop "
                         "of the hierarchical schedule (default lax — the "
                         "right tool on the slow inter-pod/cross-host link)")
    ap.add_argument("--comm", default=None, choices=["auto"],
                    help="comm='auto': measure the real per-bucket "
                         "collectives at assembly time and autotune bucket "
                         "size + backend from the §3.2 balance model "
                         "(replaces the explicit comm flags)")
    ap.add_argument("--trace-dir", default=None,
                    help="write a per-process telemetry trace (JSONL) and a "
                         "merged Chrome trace (trace.json, load in "
                         "chrome://tracing or Perfetto) to this directory")
    ap.add_argument("--optimizer", default=None,
                    choices=["adamw", "sgd"],
                    help="default: family choice (momentum SGD for the "
                         "paper's CNN/DNN, AdamW for transformers)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint period in steps (default: steps/5 "
                         "when --ckpt-dir is set)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def check_run_args(ap: argparse.ArgumentParser, args) -> None:
    """Flag compatibility, read off the declarative ``MODE_CAPS`` table —
    the same source ``RunSpec`` validates against, so the launcher and the
    API can never disagree on what a mode supports."""
    caps = MODE_CAPS[args.parallel]
    if getattr(args, "comm", None) == "auto":
        if comm_flags_set(args):
            ap.error("--comm auto autotunes the bucket size and backend "
                     "from measurement; it cannot be combined with the "
                     "explicit comm flags (--bucket-mb / --wire-dtype / "
                     "--overlap / --comm-backend / --cross-backend)")
        if not caps.comm:
            commful = [m for m, c in MODE_CAPS.items() if c.comm]
            ap.error("--comm auto measures the explicit bucketed "
                     f"collectives, which --parallel {args.parallel} does "
                     f"not use; pick one of {commful}")
    if comm_flags_set(args) and not caps.comm:
        commful = [m for m, c in MODE_CAPS.items() if c.comm]
        ap.error("--bucket-mb / --wire-dtype / --overlap / --comm-backend "
                 "/ --cross-backend configure the explicit bucketed "
                 f"collectives, which --parallel {args.parallel} does not "
                 f"use; pick one of {commful}")
    if args.overlap and not caps.overlap:
        overlappy = [m for m, c in MODE_CAPS.items() if c.overlap]
        ap.error("--overlap (the §3.1 backward-pass reduce schedule) is "
                 f"only supported by {overlappy}, not --parallel "
                 f"{args.parallel}")
    if (caps.backends is not None and args.comm_backend != "lax"
            and args.comm_backend not in caps.backends):
        ap.error(f"--comm-backend {args.comm_backend} is not valid under "
                 f"--parallel {args.parallel}; this mode supports "
                 f"{list(caps.backends)}")
    if (args.wire_format is not None and caps.wire_formats is not None
            and args.wire_format not in caps.wire_formats):
        ap.error(f"--wire-format {args.wire_format} is not valid under "
                 f"--parallel {args.parallel}; this mode supports "
                 f"{list(caps.wire_formats)}")
    if args.wire_format == "topk" and args.overlap:
        ap.error("--wire-format topk cannot run with --overlap: the "
                 "backward-pass reduce taps are stateless, so the "
                 "error-feedback residual has nowhere to live")


def main(argv=None):
    ap = argparse.ArgumentParser()
    add_run_args(ap)
    args = ap.parse_args(argv)
    check_run_args(ap, args)

    run = compile_run(spec_from_args(args))
    # report the RESOLVED comm plan (run.comm), not spec.comm — the spec may
    # say the string "auto", the run carries what the autotuner picked
    print(f"arch: {run.cfg.name}  family={run.family.family}  "
          f"parallel={run.spec.parallel}  "
          f"overlap={run.comm.overlap if run.comm else False}  "
          f"backend={run.comm.backend if run.comm else 'lax'}  "
          f"mesh={dict(run.mesh.shape) if run.mesh is not None else None}")
    hist = run.fit()   # auto-resumes from the latest --ckpt-dir checkpoint
    run.close()
    if hist:
        print(f"final loss: {hist[-1]['loss']:.4f}")
    else:
        print("checkpoint already at or past --steps; nothing to train")
    return hist


if __name__ == "__main__":
    main()
