"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

On CPU (this container) use --smoke for the reduced config; on a real TPU
slice the full config shards across the detected devices with the same
rules/plan machinery the dry-run exercises."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import get_config, smoke_variant, ASSIGNED_ARCHS, PAPER_ARCHS
from repro.configs.base import CNNConfig, DNNConfig
from repro.core.params import Spec
from repro.core.sharding import ShardingCtx, ShardingRules
from repro.data import Prefetcher, make_placer, stream_for
from repro.launch.mesh import make_host_mesh
from repro.models import cnn, dnn, transformer
from repro.optim import AdamW, MomentumSGD, warmup_cosine
from repro.train import Trainer, TrainerConfig, make_train_step


def build(cfg, mesh, rules):
    ctx = ShardingCtx(mesh, rules)
    if isinstance(cfg, CNNConfig):
        init = lambda k: cnn.init_params(cfg, k)
        loss = lambda p, b: cnn.loss_fn(p, cfg, b, ctx)
        sp_tree = cnn.param_specs(cfg)
    elif isinstance(cfg, DNNConfig):
        init = lambda k: dnn.init_params(cfg, k)
        loss = lambda p, b: dnn.loss_fn(p, cfg, b, ctx)
        sp_tree = dnn.param_specs(cfg)
    else:
        init = lambda k: transformer.init_params(cfg, k)
        loss = lambda p, b: transformer.lm_loss(p, cfg, ctx, b)
        sp_tree = transformer.param_specs(cfg)
    return init, loss, sp_tree, ctx


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=list(ASSIGNED_ARCHS) + list(PAPER_ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--model-ways", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "sgd"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    mesh = make_host_mesh(args.model_ways) if len(jax.devices()) > 1 else None
    rules = ShardingRules()
    init, loss, sp_tree, ctx = build(cfg, mesh, rules)

    key = jax.random.PRNGKey(args.seed)
    params = init(key)
    if mesh is not None:
        shardings = jax.tree.map(
            lambda s: rules.sharding(s.axes, s.shape, mesh), sp_tree,
            is_leaf=lambda x: isinstance(x, Spec))
        params = jax.tree.map(jax.device_put, params, shardings)

    opt = AdamW(weight_decay=0.01) if args.optimizer == "adamw" \
        else MomentumSGD(momentum=0.9)
    opt_state = opt.init(params)
    sched = warmup_cosine(args.lr, max(args.steps // 20, 1), args.steps)
    step = make_train_step(loss, opt, sched)

    placer = make_placer(mesh, rules)
    data = Prefetcher(stream_for(cfg, args.batch, args.seq, args.seed),
                      place=placer)
    tcfg = TrainerConfig(total_steps=args.steps, log_every=5,
                         ckpt_every=0 if not args.ckpt_dir else args.steps,
                         ckpt_dir=args.ckpt_dir)
    trainer = Trainer(step, tcfg)
    params, opt_state, hist = trainer.fit(params, opt_state, data)
    data.close()
    print(f"final loss: {hist[-1]['loss']:.4f}")
    return hist


if __name__ == "__main__":
    main()
