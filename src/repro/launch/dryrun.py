import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax ---------------------------------------
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api.families import adapter_for
from repro.configs import (
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    TPU_V5E,
    ModelConfig,
    get_config,
    get_input_shape,
)
from repro.core import hybrid, roofline
from repro.core.roofline import parse_collectives
from repro.core.sharding import ShardingCtx
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh, mesh_devices
from repro.models import transformer
from repro.optim import AdamW, constant
from repro.train import make_train_step, zero1_state_shardings

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def _with_shardings(tree, shardings):
    return jax.tree.map(
        lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype, sharding=s),
        tree, shardings)


def _unstack(tree):
    """Strip the leading per-repeat dim from stacked SDS trees."""
    def one(s):
        spec = s.sharding.spec if s.sharding is not None else None
        sh = None
        if spec is not None:
            sh = NamedSharding(s.sharding.mesh, P(*tuple(spec)[1:]))
        return jax.ShapeDtypeStruct(s.shape[1:], s.dtype, sharding=sh)
    return jax.tree.map(one, tree)


def model_flops(cfg: ModelConfig, kind: str, batch: int, seq: int) -> float:
    n = cfg.param_count(active_only=True)
    if kind == "train":
        return 6.0 * n * batch * seq
    if kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch  # decode: one token per request


def _cost_dict(cost):
    """jax 0.4.x returns cost_analysis() as a one-element list of dicts."""
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost


def _combine(full_cost, unit_cost, full_hlo, unit_hlo, repeats: int):
    """XLA counts a while-loop body once; totals = full + (R-1) * unit."""
    full_cost, unit_cost = _cost_dict(full_cost), _cost_dict(unit_cost)
    r = repeats - 1
    flops = full_cost.get("flops", 0.0) + r * unit_cost.get("flops", 0.0)
    nbytes = full_cost.get("bytes accessed", 0.0) \
        + r * unit_cost.get("bytes accessed", 0.0)
    cf = parse_collectives(full_hlo)
    cu = parse_collectives(unit_hlo)
    cf.ring_bytes += r * cu.ring_bytes
    for k, v in cu.bytes_by_kind.items():
        cf.bytes_by_kind[k] = cf.bytes_by_kind.get(k, 0) + r * v
    for k, v in cu.count_by_kind.items():
        cf.count_by_kind[k] = cf.count_by_kind.get(k, 0) + r * v
    return flops, nbytes, cf


def lower_pair(arch: str, shape_name: str, multi_pod: bool,
               rules_override=None, cfg_override=None, verbose: bool = True):
    """Lower + compile one (arch x shape x mesh); return the report row."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = get_input_shape(shape_name)
    if shape.kind == "train" and cfg.remat == "none":
        # activation checkpointing is required at this scale (baseline policy)
        cfg = cfg.replace(remat="block")
    plan = hybrid.plan(cfg, shape, mesh, TPU_V5E)
    rules = rules_override if rules_override is not None else plan.rules
    ctx = ShardingCtx(mesh, rules)
    long_ctx = shape_name == "long_500k"

    params = sp.abstract_params(cfg, mesh, rules)
    B, S = shape.global_batch, shape.seq_len
    R = cfg.pattern_repeats

    # ---- abstract activations/positions shared by the unit program --------
    x_sds = jax.ShapeDtypeStruct(
        (B, S if shape.kind != "decode" else 1, cfg.d_model), jnp.bfloat16,
        sharding=rules.sharding(("batch", "seq", "embed"),
                                (B, S, cfg.d_model), mesh))
    pos_shape = ((B, x_sds.shape[1], 3) if cfg.mrope
                 else (B, x_sds.shape[1]))
    pos_sds = jax.ShapeDtypeStruct(
        pos_shape, jnp.int32,
        sharding=rules.sharding(("batch", "seq", None)[: len(pos_shape)],
                                pos_shape, mesh))
    shared_sds = params.get("shared")
    blocks_unit = _unstack(params["blocks"])

    t0 = time.perf_counter()
    # ======================= full program ==================================
    if shape.kind == "train":
        # family adapter resolves loss/axes (repro.api registry) — the same
        # seam compile_run uses for the concrete runs
        family = adapter_for(cfg)
        opt = AdamW(weight_decay=0.01)
        opt_state = jax.eval_shape(opt.init, params)
        st_sh = zero1_state_shardings(opt_state, family.param_axes(cfg),
                                      mesh, rules)
        opt_state = _with_shardings(opt_state, st_sh)
        batch = sp.abstract_batch(cfg, shape, mesh, rules)
        step = make_train_step(family.make_loss(cfg, ctx), opt,
                               constant(1e-3))
        step_idx = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = jax.jit(step).lower(params, opt_state, step_idx, batch)
    elif shape.kind == "prefill":
        batch = sp.abstract_batch(cfg, shape, mesh, rules)
        caches = sp.abstract_caches(cfg, shape, mesh, rules, long_ctx)

        def prefill_step(params, batch, caches):
            logits, _, caches = transformer.forward(
                params, cfg, ctx,
                tokens=batch.get("tokens"),
                embeds=batch.get("patch_embeds", batch.get("frame_embeds")),
                positions=batch.get("positions"),
                caches=caches, update_cache=True, long_ctx=long_ctx)
            return logits[:, -1], caches

        lowered = jax.jit(prefill_step).lower(params, batch, caches)
    else:  # decode
        dec = sp.abstract_decode_inputs(cfg, shape, mesh, rules, long_ctx)

        def serve_step(params, batch):
            logits, _, caches = transformer.forward(
                params, cfg, ctx,
                tokens=batch.get("tokens"),
                embeds=batch.get("frame_embeds"),
                positions=batch["positions"],
                caches=batch["caches"], long_ctx=long_ctx)
            return logits[:, -1], caches

        lowered = jax.jit(serve_step).lower(params, dec)
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    # ======================= unit program (one repeat) =====================
    update_cache = shape.kind == "prefill"

    def unit_fwd(block_params, shared_p, x, positions, block_caches):
        body = transformer.make_scan_body(
            cfg, ctx, shared_p, positions, long_ctx=long_ctx,
            update_cache=update_cache, have_cache=block_caches is not None)
        carry = (x, jnp.zeros((), jnp.float32))
        xs = (block_params, block_caches) if block_caches is not None \
            else block_params
        (h, aux), ys = body(carry, xs)
        return h, aux, ys

    if shape.kind == "train":
        def unit_loss(block_params, shared_p, x, positions):
            h, aux, _ = unit_fwd(block_params, shared_p, x, positions, None)
            return jnp.sum(h.astype(jnp.float32)) * 1e-6 + aux

        grad_fn = jax.grad(unit_loss, argnums=(0, 2) if shared_sds is None
                           else (0, 1, 2))
        unit_lowered = jax.jit(grad_fn).lower(
            blocks_unit, shared_sds, x_sds, pos_sds)
    else:
        caches_stacked = (sp.abstract_caches(cfg, shape, mesh, rules,
                                             long_ctx))
        caches_unit = _unstack(caches_stacked)
        unit_lowered = jax.jit(unit_fwd).lower(
            blocks_unit, shared_sds, x_sds, pos_sds, caches_unit)
    unit_compiled = unit_lowered.compile()

    # ======================= combine + roofline ============================
    cost = compiled.cost_analysis()
    unit_cost = unit_compiled.cost_analysis()
    mem = compiled.memory_analysis()
    flops, nbytes, coll = _combine(cost, unit_cost, compiled.as_text(),
                                   unit_compiled.as_text(), R)
    if verbose:
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis (scan-corrected): flops={flops:.3e} "
              f"bytes={nbytes:.3e} coll_ring={coll.ring_bytes:.3e}")
    mem_per_dev = 0.0
    if mem is not None:
        mem_per_dev = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                       + mem.temp_size_in_bytes - mem.alias_size_in_bytes)

    mf = model_flops(cfg, shape.kind, B, S)
    mesh_desc = "2x16x16" if multi_pod else "16x16"
    rep = roofline.RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_desc,
        n_devices=mesh_devices(mesh),
        hlo_flops_per_dev=flops, hlo_bytes_per_dev=nbytes, coll=coll,
        compute_s=flops / TPU_V5E.peak_flops,
        memory_s=nbytes / TPU_V5E.mem_bw,
        collective_s=coll.ring_bytes / TPU_V5E.link_bw,
        model_flops_total=mf, mem_per_dev_bytes=mem_per_dev)
    row = rep.row()
    row.update(t_lower_s=round(t_lower, 2), t_compile_s=round(t_compile, 2),
               plan_G=plan.G, plan_model_ways=plan.model_ways,
               plan_G_opt_head=plan.G_opt_head, plan_G_opt_ff=plan.G_opt_ff,
               plan_notes=list(plan.notes),
               mem_argument_gb=(mem.argument_size_in_bytes / 2**30
                                if mem else None),
               mem_temp_gb=(mem.temp_size_in_bytes / 2**30 if mem else None),
               mem_output_gb=(mem.output_size_in_bytes / 2**30
                              if mem else None))
    return row


def run_one(arch: str, shape_name: str, multi_pod: bool, force: bool = False,
            out_dir: str = RESULTS_DIR) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    mesh_desc = "2x16x16" if multi_pod else "16x16"
    fname = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_desc}.json")
    if os.path.exists(fname) and not force:
        with open(fname) as f:
            return json.load(f)
    print(f"[dryrun] {arch} x {shape_name} x {mesh_desc} ...", flush=True)
    try:
        row = lower_pair(arch, shape_name, multi_pod)
        row["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        traceback.print_exc()
        row = dict(arch=arch, shape=shape_name, mesh=mesh_desc,
                   status="error", error=f"{type(e).__name__}: {e}")
    with open(fname, "w") as f:
        json.dump(row, f, indent=1, default=str)
    print(f"[dryrun] -> {row.get('dominant', row['status'])} "
          f"(compile {row.get('t_compile_s', '-')}s)", flush=True)
    return row


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if (args.all or args.arch is None) \
        else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                row = run_one(arch, shape, mp, force=args.force)
                failures += row["status"] != "ok"
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
