"""Production meshes.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis joins "data" for the paper's G data-parallel groups; gradient
part-reduce runs over ("pod", "data") so the cross-pod hop composes with the
in-pod ring.

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import warnings

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 512 if multi_pod else 256
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for the production mesh, have "
            f"{len(devices)}; the dry-run sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devices[:ndev],
                         axis_types=(AxisType.Auto,) * len(axes))


def _divisible_factorization(n: int, model_ways: int, pods: int):
    """Largest factorization (model_ways', pods') with model_ways' <=
    model_ways and pods' <= pods such that ``pods' * model_ways'`` divides
    ``n`` — i.e. the data axis absorbs EVERY device.  Model ways take
    priority (shrinking the model group changes the math less than silently
    training on fewer devices); always terminates at (1, 1)."""
    for mw in range(model_ways, 0, -1):
        for p in range(min(pods, n // mw), 0, -1):
            if n % (mw * p) == 0:
                return mw, p
    return 1, 1


def make_host_mesh(model_ways: int = 1, pods: int = 1) -> Mesh:
    """Best-effort mesh over whatever devices exist (examples, tests).

    ``pods > 1`` asks for the three-axis ("pod", "data", "model") topology
    (the §3.3 group composition); both counts are clamped to what the host
    actually has, so a 1-device box degrades to a (1, 1) mesh.  A request
    that does not divide the device count (e.g. 6 devices, model_ways=4)
    used to silently train on a subset of ``jax.devices()``; now the
    largest divisible factorization is preferred and a warning names what
    changed."""
    n = len(jax.devices())
    model_ways = max(1, min(model_ways, n))
    pods = max(1, min(pods, n // model_ways))
    if n % (model_ways * pods):
        dropped = n - pods * (n // (model_ways * pods)) * model_ways
        mw2, p2 = _divisible_factorization(n, model_ways, pods)
        warnings.warn(
            f"make_host_mesh: model_ways={model_ways} x pods={pods} does "
            f"not divide the {n} visible devices and would silently drop "
            f"{dropped} of them; using the largest divisible factorization "
            f"model_ways={mw2} x pods={p2} instead (all {n} devices used)",
            stacklevel=2)
        model_ways, pods = mw2, p2
    data = n // (model_ways * pods)
    if pods > 1:
        shape = (pods, data, model_ways)
        axes = ("pod", "data", "model")
    else:
        shape = (data, model_ways)
        axes = ("data", "model")
    ndev = pods * data * model_ways
    return jax.make_mesh(shape, axes, devices=jax.devices()[:ndev],
                         axis_types=(AxisType.Auto,) * len(axes))


def make_cluster_mesh(model_ways: int = 1) -> Mesh:
    """Multi-host mesh for a ``jax.distributed`` cluster: the "pod" axis IS
    the process (host) boundary, so the cross-pod hop of
    ``HierarchicalSchedule`` runs over the genuine cross-host link while the
    in-pod ring stays on each host's local devices.

    Axes ("pod", "data", "model") = (process_count, local//model_ways,
    model_ways); falls back to :func:`make_host_mesh` when there is only one
    process (a 1-process "cluster" is just the host).  Devices are grouped
    by ``process_index`` — jax guarantees equal local device counts are not
    required in general, but this mesh is, so ragged clusters are rejected.
    """
    nproc = jax.process_count()
    if nproc == 1:
        return make_host_mesh(model_ways)
    devs = sorted(jax.devices(), key=lambda d: (d.process_index, d.id))
    local = len(devs) // nproc
    per_proc = {}
    for d in devs:
        per_proc[d.process_index] = per_proc.get(d.process_index, 0) + 1
    if len(set(per_proc.values())) != 1:
        raise RuntimeError(
            f"cluster mesh needs the same local device count on every "
            f"process, got {per_proc}")
    model_ways = max(1, min(model_ways, local))
    if local % model_ways:
        warnings.warn(
            f"make_cluster_mesh: model_ways={model_ways} does not divide "
            f"the {local} local devices per process; dropping to "
            f"model_ways={_divisible_factorization(local, model_ways, 1)[0]}",
            stacklevel=2)
        model_ways = _divisible_factorization(local, model_ways, 1)[0]
    data = local // model_ways
    return jax.make_mesh((nproc, data, model_ways),
                         ("pod", "data", "model"), devices=devs,
                         axis_types=(AxisType.Auto,) * 3)


def mesh_devices(mesh: Mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
