"""Production meshes.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis joins "data" for the paper's G data-parallel groups; gradient
part-reduce runs over ("pod", "data") so the cross-pod hop composes with the
in-pod ring.

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 512 if multi_pod else 256
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for the production mesh, have "
            f"{len(devices)}; the dry-run sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devices[:ndev],
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model_ways: int = 1, pods: int = 1) -> Mesh:
    """Best-effort mesh over whatever devices exist (examples, tests).

    ``pods > 1`` asks for the three-axis ("pod", "data", "model") topology
    (the §3.3 group composition); both counts are clamped to what the host
    actually has, so a 1-device box degrades to a (1, 1) mesh."""
    n = len(jax.devices())
    model_ways = max(1, min(model_ways, n))
    pods = max(1, min(pods, n // model_ways))
    data = n // (model_ways * pods)
    if pods > 1:
        shape = (pods, data, model_ways)
        axes = ("pod", "data", "model")
    else:
        shape = (data, model_ways)
        axes = ("data", "model")
    ndev = pods * data * model_ways
    return jax.make_mesh(shape, axes, devices=jax.devices()[:ndev],
                         axis_types=(AxisType.Auto,) * len(axes))


def mesh_devices(mesh: Mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
