"""Multi-host launcher: ``python -m repro.launch.cluster --processes N ...``.

One module, two roles, selected by the cluster env vars:

* **supervisor** (how you invoke it): parses the SAME run flags as
  ``repro.launch.train`` plus the cluster knobs, then hands the whole argv
  to :func:`repro.cluster.elastic.run_elastic`, which spawns N worker
  processes and supervises them — a dead worker shrinks the world and the
  run resumes from the latest checkpoint at the new size.

* **worker** (how the launcher re-invokes it, detected via
  ``REPRO_PROCESS_ID``): brings up ``jax.distributed`` from the env-var
  :class:`~repro.cluster.spec.ClusterSpec` BEFORE importing anything that
  could touch jax device state, compiles the run with
  ``MeshSpec(cluster=True)`` (the "pod" mesh axis = the process boundary)
  and trains, heartbeating every step.

    # the paper's §3.4 update across 2 real processes over gloo
    python -m repro.launch.cluster --processes 2 --arch vgg-a --smoke \\
        --steps 8 --ckpt-dir /tmp/vgg-cluster

    # chaos: SIGKILL worker 1 at step 3, watch the elastic recovery
    python -m repro.launch.cluster --processes 2 --arch vgg-a --smoke \\
        --steps 8 --ckpt-dir /tmp/vgg-chaos --chaos-kill-step 3

``--verify`` additionally trains the same spec single-process in the
supervisor and asserts the final losses agree to float tolerance — the
§3.4 strip update is G-invariant, so a REAL multi-process run must land on
the single-process trajectory (this is the end-to-end proof the cross-host
collectives compute the right thing, asserted in CI)."""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

from repro.cluster.launcher import (
    ENV_HEARTBEAT_FILE,
    ENV_RESULT_FILE,
    make_heartbeat_listener,
)
from repro.cluster.spec import ClusterSpec, in_worker, initialize

# |cluster loss - single loss| tolerance for --verify: the update is
# G-invariant in exact arithmetic; fp32 reduction-order noise over a few
# smoke steps stays orders of magnitude below this
VERIFY_TOL = 5e-3


def worker_main(args) -> int:
    """One cluster member: jax.distributed up, compile, train, report."""
    spec = ClusterSpec.from_env()
    initialize(spec)
    # imports that build jit caches come AFTER distributed init
    import jax

    from repro.api import compile_run
    from repro.launch.train import spec_from_args

    run = compile_run(spec_from_args(args, cluster=True))
    if jax.process_index() == 0:
        print(f"cluster: {spec.num_processes} processes x "
              f"{spec.local_devices} devices  "
              f"mesh={dict(run.mesh.shape) if run.mesh is not None else None}"
              f"  parallel={run.spec.parallel}")
    hb = os.environ.get(ENV_HEARTBEAT_FILE)
    if hb:
        # the heartbeat rides the telemetry "step" span (the general event
        # hook that replaced the bare on_step callback); compile_run always
        # builds a live recorder, so this works with or without --trace-dir
        run.telemetry.add_listener(make_heartbeat_listener(hb))
    hist = run.fit()
    run.close()
    if jax.process_index() == 0:
        final = hist[-1]["loss"] if hist else None
        if final is not None:
            print(f"final loss: {final:.4f}")
        result_file = os.environ.get(ENV_RESULT_FILE)
        if result_file:
            payload = {"world": spec.num_processes,
                       "steps": run.spec.steps, "final_loss": final}
            with open(result_file, "w") as f:
                json.dump(payload, f)
    return 0


def _verify_single(args) -> float:
    """The same run, single-process, fresh state (no resume): the
    G-invariance reference the cluster's final loss must match."""
    from repro.api import compile_run
    from repro.launch.train import spec_from_args

    import dataclasses
    spec = spec_from_args(args, cluster=False)
    # telemetry stripped: the supervisor has no REPRO_PROCESS_ID, so its
    # trace_p0.jsonl would collide with worker 0's
    spec = dataclasses.replace(spec, ckpt_dir=None, ckpt_every=0,
                               telemetry=None)
    run = compile_run(spec)
    hist = run.fit(start_step=0)
    run.close()
    return hist[-1]["loss"]


def main(argv=None) -> int:
    argv = list(sys.argv[1:]) if argv is None else list(argv)
    worker = in_worker()
    from repro.launch.train import add_run_args, check_run_args

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    add_run_args(ap, parallel_default="zero1")
    ap.add_argument("--processes", type=int, default=2,
                    help="worker processes to launch (the cross-host 'pod' "
                         "axis extent)")
    ap.add_argument("--local-devices", type=int, default=1,
                    help="devices per process (forced host devices on CPU)")
    ap.add_argument("--run-dir", default=None,
                    help="supervisor scratch dir (heartbeats, worker logs, "
                         "result); default: --ckpt-dir, else a temp dir")
    ap.add_argument("--max-restarts", type=int, default=2,
                    help="elastic relaunch budget after worker failures")
    ap.add_argument("--heartbeat-timeout", type=float, default=120.0,
                    help="seconds without progress before the supervisor "
                         "declares a hang (covers jit compile, so generous)")
    ap.add_argument("--chaos-kill-step", type=int, default=None,
                    help="chaos harness: SIGKILL one worker when its "
                         "heartbeat reaches this step (first attempt only)")
    ap.add_argument("--chaos-kill-worker", type=int, default=1)
    ap.add_argument("--grow-back", action="store_true",
                    help="relaunch failed attempts at the FULL --processes "
                         "world instead of shrinking to the survivors "
                         "(transient-failure recovery policy; any world "
                         "change invalidates the cached comm=auto plan)")
    ap.add_argument("--verify", action="store_true",
                    help="also train single-process and assert the final "
                         "losses match (G-invariance, end to end)")
    args = ap.parse_args(argv)
    check_run_args(ap, args)

    if worker:
        return worker_main(args)

    from repro.cluster.elastic import ChaosSpec, run_elastic

    if args.processes < 1:
        ap.error("--processes must be >= 1")
    run_dir = args.run_dir or args.ckpt_dir \
        or tempfile.mkdtemp(prefix="repro-cluster-")
    chaos = None
    if args.chaos_kill_step is not None:
        chaos = ChaosSpec(at_step=args.chaos_kill_step,
                          worker=args.chaos_kill_worker)
    res = run_elastic(argv, run_dir, args.processes,
                      local_devices=args.local_devices,
                      max_restarts=args.max_restarts,
                      heartbeat_timeout=args.heartbeat_timeout,
                      chaos=chaos, grow_back=args.grow_back)
    final = res.result.get("final_loss") if res.result else None
    print(f"[cluster] done: world={res.final_world} "
          f"attempts={res.attempts} final_loss={final}")
    if args.trace_dir:
        # workers each wrote trace_p<pid>.jsonl (Run.close skips the merge
        # in workers); the supervisor sees them all and merges here
        from repro.telemetry import merge_process_traces
        merged = merge_process_traces(args.trace_dir)
        if merged:
            print(f"[cluster] merged Chrome trace: {merged}")
    if args.verify:
        if final is None:
            print("[cluster] verify FAILED: no final loss reported")
            return 1
        ref = _verify_single(args)
        diff = abs(final - ref)
        ok = diff <= VERIFY_TOL
        print(f"[cluster] verify: cluster={final:.6f} single={ref:.6f} "
              f"|diff|={diff:.2e} tol={VERIFY_TOL:.0e} "
              f"{'OK' if ok else 'FAILED'}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
