"""Serving launcher: continuous batching over the paged KV cache, through
the ``ServeSpec -> compile_serve`` seam.

``python -m repro.launch.serve --arch llama3-8b --smoke --requests 8``

``--smoke`` defaults ON (this launcher's job is the CPU-sized demo/CI
check); pass ``--no-smoke`` for the full-size config.  The old flag was
``action="store_true"`` with ``default=True`` — impossible to turn off.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.api import ServeSpec, compile_serve
from repro.api.spec import PAGED_ATTN_IMPLS, SCHEDULER_POLICIES
from repro.configs import ASSIGNED_ARCHS


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--scheduler", default="continuous",
                    choices=list(SCHEDULER_POLICIES))
    ap.add_argument("--attn-impl", default="gather",
                    choices=list(PAGED_ATTN_IMPLS))
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    spec = ServeSpec(arch=args.arch, smoke=args.smoke,
                     max_batch=args.max_batch, page_size=args.page_size,
                     num_pages=args.num_pages, max_prompt=args.prompt_len,
                     max_new_tokens=args.new, scheduler=args.scheduler,
                     attn_impl=args.attn_impl, temperature=args.temperature,
                     seed=args.seed)
    server = compile_serve(spec)

    rng = np.random.default_rng(args.seed)
    lengths = rng.integers(2, args.prompt_len + 1, size=args.requests)
    for L in lengths:
        server.submit(rng.integers(1, server.cfg.vocab_size, size=int(L)))

    t0 = time.perf_counter()
    done = server.drain()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in done)
    print(f"served {len(done)} requests / {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s incl. compile) "
          f"scheduler={spec.scheduler} preemptions="
          f"{server.stats['preemptions']}")
    print("first request:", done[0].output[:16].tolist())
    return done


if __name__ == "__main__":
    main()
