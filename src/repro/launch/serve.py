"""Serving launcher: batched prefill + decode with a reduced model.

``python -m repro.launch.serve --arch llama3-8b --smoke --batch 4 --new 32``
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ASSIGNED_ARCHS, get_config, smoke_variant
from repro.core.sharding import ShardingCtx
from repro.models import transformer
from repro.serve import generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    if cfg.frontend:
        raise SystemExit("serve demo supports token-in/token-out archs")
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    ctx = ShardingCtx()
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.perf_counter()
    out = generate(params, cfg, ctx, prompt, args.new,
                   temperature=args.temperature, key=key)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new / dt:.1f} tok/s)")
    print(out[0][:16])
    return out


if __name__ == "__main__":
    main()
