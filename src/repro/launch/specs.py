"""Abstract (ShapeDtypeStruct) inputs for every (arch x input-shape) pair —
weak-type-correct, shardable, zero allocation.  Consumed by launch/dryrun.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import InputShape, ModelConfig
from repro.core.params import Spec
from repro.core.sharding import ShardingRules
from repro.models import transformer


def _sds(shape, dtype, axes, mesh: Mesh, rules: ShardingRules):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=rules.sharding(axes, shape, mesh))


def abstract_params(cfg: ModelConfig, mesh: Mesh, rules: ShardingRules,
                    dtype=jnp.float32):
    specs = transformer.param_specs(cfg)
    return jax.tree.map(
        lambda s: _sds(s.shape, dtype, s.axes, mesh, rules),
        specs, is_leaf=lambda x: isinstance(x, Spec))


def abstract_batch(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                   rules: ShardingRules) -> Dict[str, Any]:
    """Training / prefill batch specs (full sequence)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "vision":
        s_img = cfg.vision_tokens
        return {
            "tokens": _sds((B, S - s_img), jnp.int32, ("batch", "seq"),
                           mesh, rules),
            "patch_embeds": _sds((B, s_img, cfg.d_model), jnp.float32,
                                 ("batch", "seq", "embed"), mesh, rules),
            "positions": _sds((B, S, 3), jnp.int32, ("batch", "seq", None),
                              mesh, rules),
        }
    if cfg.frontend == "audio":
        return {
            "frame_embeds": _sds((B, S, cfg.d_model), jnp.float32,
                                 ("batch", "seq", "embed"), mesh, rules),
            "codebook_labels": _sds((B, S, cfg.num_codebooks), jnp.int32,
                                    ("batch", "seq", None), mesh, rules),
        }
    return {"tokens": _sds((B, S), jnp.int32, ("batch", "seq"), mesh, rules)}


def abstract_caches(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                    rules: ShardingRules, long_ctx: bool):
    """Cache specs matching transformer.init_caches structure."""
    template = jax.eval_shape(
        lambda: transformer.init_caches(cfg, shape.global_batch,
                                        shape.seq_len, long_ctx=long_ctx))
    axes = transformer.cache_axes(cfg)

    def to_sds(t, ax):
        ax = tuple(ax)[: t.ndim] + (None,) * max(0, t.ndim - len(ax))
        return _sds(t.shape, t.dtype, ax, mesh, rules)

    return jax.tree.map(
        to_sds, template, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def abstract_decode_inputs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                           rules: ShardingRules, long_ctx: bool):
    """One-token decode inputs: (tokens/frame_embeds, positions, caches)."""
    B = shape.global_batch
    caches = abstract_caches(cfg, shape, mesh, rules, long_ctx)
    pos_shape = (B, 1, 3) if cfg.mrope else (B, 1)
    pos = _sds(pos_shape, jnp.int32,
               ("batch", "seq", None)[: len(pos_shape)], mesh, rules)
    if cfg.frontend == "audio":
        tok = _sds((B, 1, cfg.d_model), jnp.float32,
                   ("batch", "seq", "embed"), mesh, rules)
        return {"frame_embeds": tok, "positions": pos, "caches": caches}
    tok = _sds((B, 1), jnp.int32, ("batch", "seq"), mesh, rules)
    return {"tokens": tok, "positions": pos, "caches": caches}
