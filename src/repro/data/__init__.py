from repro.data.pipeline import (  # noqa: F401
    Prefetcher, make_placer, stream_for, lm_token_stream, image_stream,
    asr_frame_stream, vlm_stream, audio_stream,
)
