from repro.data.pipeline import (  # noqa: F401
    Prefetcher,
    asr_frame_stream,
    audio_stream,
    image_stream,
    lm_token_stream,
    make_placer,
    stream_for,
    vlm_stream,
)
