"""Data pipeline — the paper's §4 'data handling module' adapted.

The paper dedicates a hardware thread so pre-processing never starves the
compute library.  Here a background thread fills a bounded queue with
host-side numpy batches (double buffering), and batches are placed onto the
mesh with the batch-dim sharding before the step consumes them.

Streams are synthetic but deterministic (seeded): LM token streams with a
Zipf-ish unigram plus a learnable bigram structure (so losses actually fall),
image/label streams for the CNNs, frame/senone streams for CD-DNN, and the
VLM/audio composites (including MusicGen's codebook delay pattern).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# synthetic sources (deterministic)
# ---------------------------------------------------------------------------
def lm_token_stream(vocab: int, batch: int, seq: int,
                    seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Markov-ish token stream: learnable bigram structure."""
    rng = np.random.default_rng(seed)
    V = int(vocab)
    shift = rng.integers(1, V, size=()).item()
    while True:
        first = rng.integers(0, V, size=(batch, 1))
        noise = rng.random((batch, seq - 1)) < 0.15
        toks = [first]
        for t in range(1, seq):
            nxt = (toks[-1] * 31 + shift) % V
            rand = rng.integers(0, V, size=(batch, 1))
            toks.append(np.where(noise[:, t - 1: t], rand, nxt))
        yield {"tokens": np.concatenate(toks, axis=1).astype(np.int32)}


def image_stream(image_size: int, num_classes: int, batch: int,
                 seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Images whose class determines a planted frequency pattern."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:image_size, 0:image_size].astype(np.float32)
    while True:
        labels = rng.integers(0, num_classes, size=(batch,))
        freq = (labels[:, None, None] + 1).astype(np.float32)
        base = np.sin(freq * xx[None] / image_size * 6.28) \
            + np.cos(freq * yy[None] / image_size * 6.28)
        img = base[..., None] + 0.3 * rng.standard_normal(
            (batch, image_size, image_size, 3)).astype(np.float32)
        yield {"images": img.astype(np.float32),
               "labels": labels.astype(np.int32)}


def asr_frame_stream(input_dim: int, num_senones: int, batch: int,
                     seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    proto = rng.standard_normal((num_senones, input_dim)).astype(np.float32)
    while True:
        sen = rng.integers(0, num_senones, size=(batch,))
        frames = proto[sen] + 0.5 * rng.standard_normal(
            (batch, input_dim)).astype(np.float32)
        yield {"frames": frames.astype(np.float32),
               "senones": sen.astype(np.int32)}


def vlm_stream(cfg: ModelConfig, batch: int, seq_txt: int,
               seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    from repro.models.frontends import mrope_positions
    rng = np.random.default_rng(seed)
    lm = lm_token_stream(cfg.vocab_size, batch, seq_txt, seed)
    s_img = cfg.vision_tokens
    grid_w = max(1, int(np.sqrt(s_img)))
    pos = np.asarray(mrope_positions(batch, s_img, seq_txt, grid_w=grid_w))
    while True:
        toks = next(lm)["tokens"]
        emb = 0.02 * rng.standard_normal(
            (batch, s_img, cfg.d_model)).astype(np.float32)
        yield {"tokens": toks, "patch_embeds": emb, "positions": pos}


def audio_stream(cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    from repro.models.frontends import delay_pattern
    rng = np.random.default_rng(seed)
    K = cfg.num_codebooks
    lm = lm_token_stream(cfg.vocab_size, batch, seq * K, seed)
    while True:
        toks = next(lm)["tokens"].reshape(batch, seq, K)
        delayed = np.asarray(delay_pattern(jnp.asarray(toks), K))
        emb = 0.02 * rng.standard_normal(
            (batch, seq, cfg.d_model)).astype(np.float32)
        yield {"frame_embeds": emb,
               "codebook_labels": delayed.astype(np.int32)}


def stream_for(cfg, batch: int, seq: int, seed: int = 0):
    """Family dispatch lives in the adapter registry (``repro.api``); this
    stays as the stable entry point over the raw stream constructors."""
    from repro.api.families import adapter_for  # lazy: api sits above data
    return adapter_for(cfg).stream(cfg, batch, seq, seed)


# ---------------------------------------------------------------------------
# prefetching + device placement (the paper's dedicated data thread)
# ---------------------------------------------------------------------------
BATCH_SPECS = {
    "tokens": ("batch", "seq"), "images": ("batch", None, None, None),
    "labels": ("batch",), "frames": ("batch", None), "senones": ("batch",),
    "patch_embeds": ("batch", "seq", "embed"),
    "positions": ("batch", "seq", None),
    "frame_embeds": ("batch", "seq", "embed"),
    "codebook_labels": ("batch", "seq", None),
}


_SENTINEL = object()    # queued when the source is exhausted: a finite
#                         source must end the consumer's iteration, not
#                         leave it blocked on an empty queue forever


class Prefetcher:
    """Background-thread prefetch with a bounded queue (double buffering).

    Finite sources terminate cleanly: exhaustion enqueues a sentinel that
    ``__next__`` turns into ``StopIteration``.  ``close()`` stops the
    worker, drains the queue and JOINS the thread (bounded), so no worker
    is left blocked on a full queue after the consumer goes away."""

    def __init__(self, source: Iterator, depth: int = 2,
                 place: Optional[Callable] = None):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._place = place or (lambda b: jax.tree.map(jnp.asarray, b))
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None

        def _put(item) -> bool:
            # bounded put that gives up when close() intervenes, so the
            # worker can never deadlock against a full queue
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for item in source:
                    if not _put(item):
                        return
            except BaseException as e:     # noqa: BLE001 — must cross threads
                # a crashed pipeline is NOT exhaustion: record the exception
                # so the consumer re-raises it instead of quietly stopping
                self._error = e
            finally:
                _put(_SENTINEL)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is _SENTINEL:
            try:                      # keep raising on subsequent calls
                self._q.put_nowait(_SENTINEL)
            except queue.Full:
                pass
            if self._error is not None:
                raise self._error
            raise StopIteration
        return self._place(item)

    def close(self):
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._t.join(timeout=5.0)


def make_placer(mesh: Optional[Mesh], rules) -> Callable:
    if mesh is None:
        return lambda b: jax.tree.map(jnp.asarray, b)

    def place(batch):
        out = {}
        for k, v in batch.items():
            axes = BATCH_SPECS.get(k, ("batch",) + (None,) * (v.ndim - 1))
            sh = rules.sharding(axes, v.shape, mesh)
            out[k] = jax.device_put(jnp.asarray(v), sh)
        return out
    return place
