"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched


def linear_scale_warmup(base_lr: float, scale: float, warmup_steps: int,
                        total_steps: int, final_frac: float = 0.1):
    """Goyal et al.'s large-batch recipe (PAPERS.md): when the global batch
    grows by ``scale`` (the data-parallel ways), the target LR is
    ``base_lr * scale`` — but jumping there at step 0 diverges, so the LR
    ramps LINEARLY from ``base_lr`` to the scaled peak over
    ``warmup_steps`` ("gradual warmup"), then follows the usual cosine
    decay toward ``final_frac`` of the peak.

    ``scale == 1`` (or ``warmup_steps == 0``) degrades to plain
    ``warmup_cosine``-after-warmup behavior at ``base_lr`` — a serial run
    under this schedule is the unscaled baseline the recipe is honest
    against (see benchmarks/fig5_convergence.py)."""
    peak = base_lr * float(scale)

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        frac = jnp.clip(step / max(warmup_steps, 1), 0.0, 1.0)
        warm = base_lr + (peak - base_lr) * frac
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak * (final_frac + (1 - final_frac)
                      * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched
