"""Momentum SGD — the paper's optimizer (synchronous, no hyperparameter
changes: the distributed update is bitwise the serial algorithm on the
summed minibatch gradient)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class SgdState(NamedTuple):
    velocity: Any


@dataclass(frozen=True)
class MomentumSGD:
    momentum: float = 0.9
    weight_decay: float = 0.0

    def init(self, params) -> SgdState:
        return SgdState(jax.tree.map(jnp.zeros_like, params))

    def update(self, grads, state: SgdState, params, lr) -> Tuple[Any, SgdState]:
        new_vel = jax.tree.map(
            lambda g, v, p: self.momentum * v + g + self.weight_decay * p,
            grads, state.velocity, params)
        new_params = jax.tree.map(lambda p, v: p - lr * v, params, new_vel)
        return new_params, SgdState(new_vel)
