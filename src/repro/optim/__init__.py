from repro.optim.adamw import AdamW, AdamWState  # noqa: F401
from repro.optim.dist import make_distributed_update, make_overlapped_update  # noqa: F401
from repro.optim.schedule import constant, linear_scale_warmup, warmup_cosine  # noqa: F401
from repro.optim.sgd import MomentumSGD, SgdState  # noqa: F401
