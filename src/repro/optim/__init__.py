from repro.optim.adamw import AdamW, AdamWState  # noqa: F401
from repro.optim.dist import (  # noqa: F401
    UpdatePlan,
    make_distributed_update,
    make_overlapped_update,
    make_stale_sync_update,
)
from repro.optim.schedule import constant, linear_scale_warmup, warmup_cosine  # noqa: F401
from repro.optim.sgd import MomentumSGD, SgdState  # noqa: F401
