"""Paper §3.4 — the distributed synchronous-SGD update, explicitly.

Between local weight-gradient computation and the SGD step, gradients are
**part-reduce**d over the data-parallel group: each group member receives the
fully-reduced gradient for a 1/G strip of every tensor.  The member applies
the optimizer to ITS strip only (optimizer state exists only for the strip —
the paper's scheme is ZeRO-1 avant la lettre), then **part-broadcast**s the
updated strip so every member again holds the full weights before the next
forward pass.

This module is the explicit shard_map realization, used by the
data-parallel examples and by the equivalence property tests
(distributed update == serial update, to float tolerance).  The production
pjit path reaches the same communication pattern through GSPMD when the
optimizer state carries data-axis sharding (see train/train_step.py).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.sharding import NamedSharding

from repro.core.collectives import (
    axis_size, flatten_pad, padded_size, part_broadcast, part_reduce,
    strip_broadcast, strip_reduce, unflatten,
)


def _flat_index(axis_names) -> jax.Array:
    if isinstance(axis_names, str):
        return lax.axis_index(axis_names)
    idx = jnp.zeros((), jnp.int32)
    for a in axis_names:
        idx = idx * lax.axis_size(a) + lax.axis_index(a)
    return idx


def make_distributed_update(optimizer, mesh: Mesh, data_axes=("data",)):
    """Build (init_fn, update_fn) realizing the paper's update under
    shard_map over ``data_axes``.  Params/grads enter replicated across the
    data axes (grads are the LOCAL minibatch-shard gradients, summed over
    local samples); optimizer state lives as per-member strips sharded on
    dim 0.

    update_fn(params, grads, opt_state, lr) -> (new_params, new_opt_state)
    """
    axes = tuple(a for a in data_axes if a in mesh.axis_names)
    axis_arg = axes if len(axes) > 1 else axes[0]
    G = 1
    for a in axes:
        G *= mesh.shape[a]

    def _strip_init(params):
        def per_tensor(p):
            flat = flatten_pad(p, G)
            strip = flat.reshape(G, -1)
            return strip  # (G, n/G): dim 0 sharded over the data axes
        strips = jax.tree.map(per_tensor, params)
        return optimizer.init(strips)

    def _state_spec(s) -> P:
        # strip tensors are (G, n/G): dim 0 sharded; scalars (e.g. AdamW
        # step count) replicated
        return P(axis_arg) if getattr(s, "ndim", 0) >= 2 else P()

    def init_fn(params):
        template = jax.eval_shape(_strip_init, params)
        out_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, _state_spec(s)), template)
        # build strip-shaped state: (G, n/G) per tensor, dim0 sharded
        with jax.set_mesh(mesh):
            return jax.jit(_strip_init, out_shardings=out_shardings)(params)

    def _update(params, grads, opt_state, lr):
        flat_params, treedef = jax.tree.flatten(params)
        flat_grads = jax.tree.leaves(grads)

        # 1) part-reduce every gradient into this member's strip (mean)
        g_strips = [strip_reduce(g, axis_arg) for g in flat_grads]
        # 2) slice this member's strip of the (replicated) params
        i = _flat_index(axis_arg)
        p_strips = []
        for p in flat_params:
            flat = flatten_pad(p, G)
            n = flat.size // G
            p_strips.append(lax.dynamic_slice(flat, (i * n,), (n,)))
        # 3) serial optimizer on the strips (opt_state enters as the local
        #    strip because shard_map in_specs split dim 0)
        g_tree = jax.tree.unflatten(treedef, g_strips)
        p_tree = jax.tree.unflatten(treedef, p_strips)
        s_local = jax.tree.map(
            lambda s: s[0] if s.ndim >= 2 else s, opt_state)
        new_p_strips, new_state = optimizer.update(g_tree, s_local, p_tree, lr)
        # 4) part-broadcast updated strips back to full tensors
        new_flat = []
        for p, ps in zip(flat_params, jax.tree.leaves(new_p_strips)):
            new_flat.append(strip_broadcast(ps, axis_arg, p.shape))
        new_params = jax.tree.unflatten(treedef, new_flat)
        new_state = jax.tree.map(
            lambda s: s[None] if s.ndim >= 1 else s, new_state)
        return new_params, new_state

    def update_fn(params, grads, opt_state, lr):
        pspec = jax.tree.map(lambda _: P(), params)
        sspec = jax.tree.map(_state_spec, opt_state)
        fn = jax.shard_map(
            _update, mesh=mesh,
            in_specs=(pspec, pspec, sspec, P()),
            out_specs=(pspec, sspec),
            check_vma=False)
        return fn(params, grads, opt_state, lr)

    return init_fn, update_fn
