"""Paper §3.4 — the distributed synchronous-SGD update, as a phase pipeline.

Between local weight-gradient computation and the SGD step, gradients are
**part-reduce**d over the data-parallel group: each group member receives the
fully-reduced gradient for a 1/G strip, applies the optimizer to ITS strip
only (optimizer state exists only for the strip — the paper's scheme is
ZeRO-1 avant la lettre), then **part-broadcast**s the updated strip so every
member again holds the full weights before the next forward pass.

That update decomposes into three separable phases over one shared layout,
and :class:`UpdatePlan` is that decomposition made explicit:

    reduce(grads)  -> g_strips     one wire-dtype part-reduce per fusion
                                   bucket, mean in fp32
    apply(strips)  -> new strips   slice this member's param strips, run
                                   the serial optimizer on its state row
    broadcast(strips) -> params    one fp32 part-broadcast per bucket,
                                   un-fuse back into tensors

Every mode is a composition of the phases, not its own builder:

  * ``make_distributed_update`` — reduce + apply + broadcast in one
    shard_map (the monolithic zero1 step);
  * ``make_overlapped_update`` — apply + broadcast only: the reduces were
    issued inside the backward pass by the ``repro.comm.overlap`` hooks
    (which share the reduce math via ``comm.schedule.reduce_mean``);
  * ``comm=None`` — the seed per-tensor schedule is the SAME pipeline over
    per-tensor buckets (``CommConfig(bucket_bytes=0)`` — ``plan_buckets``
    then closes one bucket per leaf), not a separate code path;
  * ``make_stale_sync_update`` — phase RE-SCHEDULING across steps: step t
    applies the strips reduced at step t-1 from a carried buffer (bounded
    staleness 1), which the strip-owner layout permits because reduce and
    apply touch no shared state;
  * ``parallel="gossip"`` — the same pipeline with the reduce phase's
    collectives swapped for the GossipGraD partner exchange
    (``comm.backends.gossip``; the schedule seam carries the step so the
    partner rotation advances);
  * ``make_topk_ef_update`` — the ``wire_format="topk"`` composition: the
    reduce phase's input is error-feedback-compensated (residual carried
    in strip state) and sparsified per bucket before the wire; the ring
    itself then moves (values, indices) messages.

Communication goes through ``repro.comm``: the gradient tree is coalesced
into fixed-byte fusion buffers (``CommConfig.bucket_bytes``) so each BUCKET
is one part-reduce/part-broadcast pair — collective count drops from
O(#tensors) to O(total_bytes / bucket_bytes), which is what keeps VGG-A's
many small conv/bias tensors out of the latency-bound regime of the §3.2
balance model.  The optimizer itself is elementwise, so bucketed strips,
per-tensor strips and the serial update agree to float tolerance — and the
pipeline is BIT-equal to the pre-refactor builders (pinned in
tests/test_distributed.py).

This module is the explicit shard_map realization, used by the
data-parallel examples and by the equivalence property tests
(distributed update == serial update, to float tolerance).  The production
pjit path reaches the same communication pattern through GSPMD when the
optimizer state carries data-axis sharding (see train/train_step.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.comm.bucketer import (
    BucketPlan,
    CommConfig,
    pack_bucket,
    plan_buckets,
    unpack_buckets,
)
from repro.comm.schedule import Schedule, group_axes, make_schedule, reduce_mean

DEFAULT_COMM = CommConfig()


def owner_perm(hierarchical: bool, axes_sizes) -> Optional[np.ndarray]:
    """Row j of a (G, n/G) state tensor lands on the member at flat mesh
    index j, but under the hierarchical schedule that member OWNS strip
    owner_index = d*G_out + p — so value-initialized optimizer state must
    be laid out in owner order (zeros-init state is insensitive to this).
    None for the flat schedule (identity layout).  Public because
    ``checkpoint.replan`` needs the same layout law to convert strip state
    between world sizes."""
    if hierarchical and len(axes_sizes) == 2:
        g_out, g_in = axes_sizes
        return np.array(
            [d * g_out + p for p in range(g_out) for d in range(g_in)])
    return None


@dataclass(frozen=True)
class UpdatePlan:
    """The shared layout + phase set of the §3.4 update path: which mesh
    axes form the group, how the tree fuses into buckets, which member owns
    which strip, and the three phases every mode composes.  ``build`` is
    the one place the layout is derived, so the monolithic, overlapped,
    per-tensor, stale-sync and gossip paths can never disagree on it."""
    optimizer: Any
    mesh: Mesh
    axes: Tuple[str, ...]
    axis_arg: Any                  # single-name-or-tuple collective form
    G: int
    comm: CommConfig

    @classmethod
    def build(cls, optimizer, mesh: Mesh, data_axes=("data",),
              comm: Optional[CommConfig] = DEFAULT_COMM) -> "UpdatePlan":
        """``comm=None`` selects the seed per-tensor schedule — expressed
        as per-tensor buckets (``bucket_bytes=0`` makes ``plan_buckets``
        close one bucket per leaf), NOT a separate code path."""
        axes, axis_arg, G = group_axes(mesh, data_axes)
        if comm is None:
            comm = CommConfig(bucket_bytes=0)
        return cls(optimizer, mesh, axes, axis_arg, G, comm)

    # -- shared layout ------------------------------------------------
    def buckets(self, params) -> BucketPlan:
        return plan_buckets(params, self.G, self.comm.bucket_bytes)

    def schedule(self, step=None) -> Schedule:
        """The collective schedule, with ``step`` (may be traced) bound
        into step-scheduled backends — the gossip partner rotation — and
        the wire format bound into format-aware ones."""
        return make_schedule(self.axis_arg, self.comm.hierarchical,
                             self.comm.backend, self.comm.cross_backend,
                             step=step, wire_format=self.comm.wire_format,
                             topk_ratio=self.comm.topk_ratio)

    def owner_layout(self) -> Optional[np.ndarray]:
        return owner_perm(self.comm.hierarchical,
                          [self.mesh.shape[a] for a in self.axes])

    def state_spec(self, s) -> P:
        return _state_spec(s, self.axis_arg)

    def init_fn(self, params):
        """(G, n/G) fusion-buffer strip state placed on the mesh — shared
        by every mode (all consume the same plan and owner layout, so a
        checkpoint written by one path restores into another)."""
        perm = self.owner_layout()

        def _strip_init(params):
            plan = self.buckets(params)
            flat = jax.tree.leaves(params)
            # (G, n/G) strips: dim 0 sharded over the data axes
            strips = [pack_bucket(flat, b).reshape(self.G, -1)
                      for b in plan.buckets]
            if perm is not None:
                strips = [s[perm] for s in strips]
            return self.optimizer.init(strips)

        # compute replicated, then reshard with device_put: jit with
        # out_shardings miscompiles this pack+reshard pattern on jax 0.4.x
        # (values arrive multiplied by a mesh-axis extent)
        with jax.set_mesh(self.mesh):
            state = jax.jit(_strip_init)(params)
        shardings = jax.tree.map(
            lambda s: NamedSharding(self.mesh, self.state_spec(s)), state)
        return jax.tree.map(jax.device_put, state, shardings)

    # -- the three phases (called INSIDE shard_map) --------------------
    def reduce(self, sched: Schedule, plan: BucketPlan, grads):
        """Phase 1: one part-reduce per BUCKET — pack gradients into the
        fusion buffer, reduce on the wire dtype, mean in fp32.  Returns
        this member's mean-gradient strip per bucket."""
        flat_grads = jax.tree.leaves(grads)
        return [reduce_mean(sched, pack_bucket(flat_grads, b),
                            self.comm.wire_dtype, self.G)
                for b in plan.buckets]

    def apply(self, sched: Schedule, plan: BucketPlan, params, g_strips,
              opt_state, lr):
        """Phases 2–3: slice this member's param strips, run the serial
        optimizer on its local state row (elementwise, so fusing tensors
        into one buffer does not change the math).  ``opt_state`` enters in
        shard_map-local layout (strips as (1, n/G) rows) and the new state
        leaves the same way."""
        flat_params = jax.tree.leaves(params)
        i = sched.owner_index()
        p_strips = []
        for b in plan.buckets:
            pbuf = pack_bucket(flat_params, b)
            n = b.padded_size // self.G
            p_strips.append(lax.dynamic_slice(pbuf, (i * n,), (n,)))
        s_local = jax.tree.map(
            lambda s: s[0] if s.ndim >= 2 else s, opt_state)
        new_p_strips, new_state = self.optimizer.update(g_strips, s_local,
                                                        p_strips, lr)
        new_state = jax.tree.map(
            lambda s: s[None] if s.ndim >= 1 else s, new_state)
        return jax.tree.leaves(new_p_strips), new_state

    def broadcast(self, sched: Schedule, plan: BucketPlan, params,
                  new_p_strips):
        """Phase 4: one part-broadcast per bucket (always fp32 — weights
        are never quantized on the wire), then un-fuse back into tensors."""
        bufs = [sched.broadcast(ps) for ps in new_p_strips]
        treedef = jax.tree.structure(params)
        return jax.tree.unflatten(treedef, unpack_buckets(bufs, plan))

    # -- shard_map plumbing shared by the monolithic wrappers ----------
    def wrap_update(self, _update):
        """``_update(params, grads, opt_state, lr, step)`` (member code) ->
        ``update_fn(params, grads, opt_state, lr, step=0)`` under shard_map
        over the data axes.  ``step`` feeds step-scheduled backends and the
        staleness carry; step-free modes ignore it, and omitting it keeps
        the seed call shape."""
        def update_fn(params, grads, opt_state, lr, step=0):
            pspec = jax.tree.map(lambda _: P(), params)
            sspec = jax.tree.map(self.state_spec, opt_state)
            fn = jax.shard_map(
                _update, mesh=self.mesh,
                in_specs=(pspec, pspec, sspec, P(), P()),
                out_specs=(pspec, sspec),
                check_vma=False)
            return fn(params, grads, opt_state, lr,
                      jnp.asarray(step, jnp.int32))
        return update_fn


def make_distributed_update(optimizer, mesh: Mesh, data_axes=("data",),
                            comm: Optional[CommConfig] = DEFAULT_COMM):
    """Build (init_fn, update_fn) realizing the paper's update under
    shard_map over ``data_axes``: the full reduce -> apply -> broadcast
    pipeline of one :class:`UpdatePlan`.  Params/grads enter replicated
    across the data axes (grads are the LOCAL minibatch-shard gradients,
    summed over local samples); optimizer state lives as per-member strips
    sharded on dim 0 — per fusion bucket when ``comm`` is given, per tensor
    when ``comm`` is None.  The bucketed collectives run on
    ``comm.backend`` (``repro.comm.backends``).

    update_fn(params, grads, opt_state, lr, step=0)
        -> (new_params, new_opt_state)
    """
    up = UpdatePlan.build(optimizer, mesh, data_axes, comm)

    def _update(params, grads, opt_state, lr, step):
        plan = up.buckets(params)
        sched = up.schedule(step)
        g_strips = up.reduce(sched, plan, grads)
        new_p_strips, new_state = up.apply(sched, plan, params, g_strips,
                                           opt_state, lr)
        new_params = up.broadcast(sched, plan, params, new_p_strips)
        return new_params, new_state

    return up.init_fn, up.wrap_update(_update)


def make_overlapped_update(optimizer, mesh: Mesh, data_axes=("data",),
                           comm: Optional[CommConfig] = None):
    """The backprop-overlapped composition: (init_fn, local_update) where
    ``local_update`` is the apply + broadcast phases only — it consumes
    per-bucket ALREADY-REDUCED mean-gradient strips instead of a raw
    gradient tree, because the reduces were issued inside the backward pass
    by the ``repro.comm.overlap`` hooks (which run the same
    ``reduce_mean`` math), so the reduce phase no longer exists as a
    post-grad block.

    Unlike ``make_distributed_update``'s update_fn, ``local_update(params,
    g_strips, opt_state, lr)`` must be called INSIDE ``shard_map`` over the
    same data axes: the overlapped train step owns the shard_map, because
    the bucket reduces live in its ``value_and_grad`` backward pass (see
    ``train.make_overlapped_train_step``).  ``init_fn`` is the shared
    strip init — state layouts are identical, so a checkpoint written by
    one path restores into the other.
    """
    comm = DEFAULT_COMM if comm is None else comm
    up = UpdatePlan.build(optimizer, mesh, data_axes, comm)
    sched = up.schedule()

    def local_update(params, g_strips, opt_state, lr):
        plan = up.buckets(params)
        new_p_strips, new_state = up.apply(sched, plan, params, g_strips,
                                           opt_state, lr)
        new_params = up.broadcast(sched, plan, params, new_p_strips)
        return new_params, new_state

    return up.init_fn, local_update


def make_stale_sync_update(optimizer, mesh: Mesh, data_axes=("data",),
                           comm: Optional[CommConfig] = None):
    """Bounded staleness (staleness 1): step t APPLIES the mean-gradient
    strips reduced at step t-1 and carries this step's freshly-reduced
    strips for step t+1 — phase re-scheduling ACROSS steps, which the
    strip-owner layout permits because the reduce and apply phases share no
    state.  A full step of backprop/forward compute is then available to
    hide every byte of the reduce (``core.balance.stale_sync_exposed_time``
    is the model); the trade is a one-step-old gradient, bounded — unlike
    fully-async parameter-server staleness.

    opt_state wraps the zero1 strip state:

        {"stale":  per-bucket (G, n/G) carried mean-gradient strips,
         "synced": int32 flag — 0 until a reduce has been carried,
         "zero1":  the inner strip state (BIT-identical layout to the
                   synchronous modes', so zero1 checkpoints resume here
                   with the buffer re-initialized — see ``api.run``)}

    The first step (and the first step after a buffer re-init on resume)
    applies its OWN reduce — there is nothing to consume yet, so it
    degrades to the synchronous update rather than applying zeros.

    update_fn(params, grads, opt_state, lr, step=0)
        -> (new_params, new_opt_state)
    """
    comm = DEFAULT_COMM if comm is None else comm
    up = UpdatePlan.build(optimizer, mesh, data_axes, comm)

    def init_fn(params):
        plan = up.buckets(params)
        sh = NamedSharding(mesh, P(up.axis_arg))
        stale = tuple(
            jax.device_put(jnp.zeros((up.G, b.padded_size // up.G),
                                     jnp.float32), sh)
            for b in plan.buckets)
        # the flag is committed replicated so restore can re-place onto
        # its sharding (an uncommitted scalar would pin to device 0)
        synced = jax.device_put(jnp.zeros((), jnp.int32),
                                NamedSharding(mesh, P()))
        return {"stale": stale, "synced": synced,
                "zero1": up.init_fn(params)}

    def _update(params, grads, opt_state, lr, step):
        plan = up.buckets(params)
        sched = up.schedule(step)
        fresh = up.reduce(sched, plan, grads)
        carried = [s[0] for s in opt_state["stale"]]
        synced = opt_state["synced"]
        # consume LAST step's reduce; an empty buffer (first step, or a
        # resume that re-initialized it) falls back to this step's own
        applied = [jnp.where(synced > 0, c, f)
                   for c, f in zip(carried, fresh)]
        new_p_strips, new_inner = up.apply(sched, plan, params, applied,
                                           opt_state["zero1"], lr)
        new_params = up.broadcast(sched, plan, params, new_p_strips)
        new_state = {"stale": tuple(f[None] for f in fresh),
                     "synced": jnp.ones((), jnp.int32),
                     "zero1": new_inner}
        return new_params, new_state

    return init_fn, up.wrap_update(_update)


def make_topk_ef_update(optimizer, mesh: Mesh, data_axes=("data",),
                        comm: Optional[CommConfig] = None):
    """The ``wire_format="topk"`` composition: top-k sparsified reduce with
    LOCAL error feedback (the memory/compensation scheme of the deep
    gradient compression line — PAPERS.md 1712.01887 / 1711.00705).  Each
    step, every member adds its carried residual to the packed bucket
    gradient, keeps the ``topk_ratio`` largest-|g| entries, and carries
    ``buffer - kept`` forward — what sparsification drops this step is
    re-offered next step, which is what keeps top-k from biasing the
    trajectory the way plain truncation would.  The sparse buckets then
    ride the normal reduce phase, whose topk-bound backend moves (values,
    indices) messages with per-hop re-selection on the ring.

    opt_state wraps the zero1 strip state:

        {"residual": per-bucket (G, padded_size) f32 — row p is member p's
                     local unsent gradient mass (sharded dim 0, so each
                     member materializes one bucket-sized row),
         "zero1":    the inner strip state (BIT-identical layout to the
                     synchronous modes', so zero1 checkpoints resume here
                     with a zero residual — see ``api.run``)}

    The residual is member-LOCAL by construction, so a cross-world replan
    cannot convert it (old members' unsent mass has no owner in the new
    world); restore re-zeros it — one step of stiffer sparsification, the
    same trade the stale-sync buffer re-init makes.

    update_fn(params, grads, opt_state, lr, step=0)
        -> (new_params, new_opt_state)
    """
    from repro.comm.backends.pallas_ring import topk_chunk_k
    from repro.kernels.ref import topk_mask_ref

    comm = DEFAULT_COMM if comm is None else comm
    if comm.wire_format != "topk":
        raise ValueError(
            "make_topk_ef_update requires CommConfig(wire_format='topk'); "
            f"got {comm.wire_format!r}")
    up = UpdatePlan.build(optimizer, mesh, data_axes, comm)

    def init_fn(params):
        plan = up.buckets(params)
        sh = NamedSharding(mesh, P(up.axis_arg))
        residual = tuple(
            jax.device_put(jnp.zeros((up.G, b.padded_size), jnp.float32),
                           sh)
            for b in plan.buckets)
        return {"residual": residual, "zero1": up.init_fn(params)}

    def _update(params, grads, opt_state, lr, step):
        plan = up.buckets(params)
        sched = up.schedule(step)
        flat_grads = jax.tree.leaves(grads)
        g_strips, new_res = [], []
        for b, res in zip(plan.buckets, opt_state["residual"]):
            buf = pack_bucket(flat_grads, b).astype(jnp.float32) + res[0]
            # floor G: every wire chunk must get at least one entry, and
            # the per-chunk k the backend re-selects with (ratio * n/G,
            # floored at 1) then carries at least the bucket's k/G — mass
            # that concentrates in one chunk beyond its per-chunk k is
            # dropped on the wire, the canonical gTop-k approximation,
            # and lands back in the residual via error feedback
            k = topk_chunk_k(b.padded_size, up.comm.topk_ratio, floor=up.G)
            kept = topk_mask_ref(buf, k)
            new_res.append((buf - kept)[None])
            g_strips.append(reduce_mean(sched, kept, up.comm.wire_dtype,
                                        up.G))
        new_p_strips, new_inner = up.apply(sched, plan, params, g_strips,
                                           opt_state["zero1"], lr)
        new_params = up.broadcast(sched, plan, params, new_p_strips)
        return new_params, {"residual": tuple(new_res),
                            "zero1": new_inner}

    return init_fn, up.wrap_update(_update)


def _state_spec(s, axis_arg) -> P:
    # strip tensors are (G, n/G): dim 0 sharded; scalars (e.g. AdamW
    # step count, the staleness flag) replicated
    return P(axis_arg) if getattr(s, "ndim", 0) >= 2 else P()
