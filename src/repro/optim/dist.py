"""Paper §3.4 — the distributed synchronous-SGD update, explicitly.

Between local weight-gradient computation and the SGD step, gradients are
**part-reduce**d over the data-parallel group: each group member receives the
fully-reduced gradient for a 1/G strip, applies the optimizer to ITS strip
only (optimizer state exists only for the strip — the paper's scheme is
ZeRO-1 avant la lettre), then **part-broadcast**s the updated strip so every
member again holds the full weights before the next forward pass.

Communication goes through ``repro.comm``: the gradient tree is coalesced
into fixed-byte fusion buffers (``CommConfig.bucket_bytes``) so each BUCKET
is one part-reduce/part-broadcast pair instead of one pair per tensor —
collective count drops from O(#tensors) to O(total_bytes / bucket_bytes),
which is what keeps VGG-A's many small conv/bias tensors out of the
latency-bound regime of the §3.2 balance model.  ``comm=None`` selects the
seed per-tensor schedule (kept as the reference the bucketed path is
property-tested against); the optimizer itself is elementwise, so bucketed
strips, per-tensor strips and the serial update agree to float tolerance.

This module is the explicit shard_map realization, used by the
data-parallel examples and by the equivalence property tests
(distributed update == serial update, to float tolerance).  The production
pjit path reaches the same communication pattern through GSPMD when the
optimizer state carries data-axis sharding (see train/train_step.py).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

import jax
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.comm.bucketer import CommConfig, pack_bucket, plan_buckets, unpack_buckets
from repro.comm.schedule import group_axes, make_schedule
from repro.core.collectives import flatten_pad, strip_broadcast, strip_reduce

DEFAULT_COMM = CommConfig()


def owner_perm(hierarchical: bool, axes_sizes) -> Optional[np.ndarray]:
    """Row j of a (G, n/G) state tensor lands on the member at flat mesh
    index j, but under the hierarchical schedule that member OWNS strip
    owner_index = d*G_out + p — so value-initialized optimizer state must
    be laid out in owner order (zeros-init state is insensitive to this).
    None for the flat schedule (identity layout).  Public because
    ``checkpoint.replan`` needs the same layout law to convert strip state
    between world sizes."""
    if hierarchical and len(axes_sizes) == 2:
        g_out, g_in = axes_sizes
        return np.array(
            [d * g_out + p for p in range(g_out) for d in range(g_in)])
    return None


def _owner_perm(comm: CommConfig, mesh: Mesh, axes):
    return owner_perm(comm.hierarchical, [mesh.shape[a] for a in axes])


def _make_bucketed_init(optimizer, mesh: Mesh, axes, axis_arg, G: int,
                        comm: CommConfig):
    """init_fn placing (G, n/G) fusion-buffer strip state on the mesh —
    shared by the monolithic and the backprop-overlapped zero1 paths (both
    consume the same plan and the same owner layout)."""
    perm = _owner_perm(comm, mesh, axes)

    def _strip_init(params):
        plan = plan_buckets(params, G, comm.bucket_bytes)
        flat = jax.tree.leaves(params)
        # (G, n/G) fusion-buffer strips: dim 0 sharded over the data axes
        strips = [pack_bucket(flat, b).reshape(G, -1) for b in plan.buckets]
        if perm is not None:
            strips = [s[perm] for s in strips]
        return optimizer.init(strips)

    def init_fn(params):
        # compute replicated, then reshard with device_put: jit with
        # out_shardings miscompiles this pack+reshard pattern on jax 0.4.x
        # (values arrive multiplied by a mesh-axis extent)
        with jax.set_mesh(mesh):
            state = jax.jit(_strip_init)(params)
        shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, _state_spec(s, axis_arg)), state)
        return jax.tree.map(jax.device_put, state, shardings)

    return init_fn


def _apply_strip_update(optimizer, sched, plan, G: int, params, g_strips,
                        opt_state, lr):
    """Steps 2–4 of the §3.4 update, INSIDE shard_map: slice this member's
    param strips, run the optimizer on its local state row, part-broadcast
    the updated strips, un-fuse back into tensors.  ``g_strips`` are the
    already-reduced fp32 mean-gradient strips, one per bucket."""
    flat_params, treedef = jax.tree.flatten(params)
    i = sched.owner_index()
    # 2) slice this member's strip of the (replicated) params
    p_strips = []
    for b in plan.buckets:
        pbuf = pack_bucket(flat_params, b)
        n = b.padded_size // G
        p_strips.append(lax.dynamic_slice(pbuf, (i * n,), (n,)))
    # 3) serial optimizer on the bucket strips (elementwise, so fusing
    #    tensors into one buffer does not change the math); opt_state
    #    enters as the local strip because shard_map split dim 0
    s_local = jax.tree.map(
        lambda s: s[0] if s.ndim >= 2 else s, opt_state)
    new_p_strips, new_state = optimizer.update(g_strips, s_local,
                                               p_strips, lr)
    # 4) one part-broadcast per bucket (always fp32 — weights are never
    #    quantized on the wire), then un-fuse back into tensors
    bufs = [sched.broadcast(ps) for ps in jax.tree.leaves(new_p_strips)]
    new_params = jax.tree.unflatten(treedef, unpack_buckets(bufs, plan))
    new_state = jax.tree.map(
        lambda s: s[None] if s.ndim >= 1 else s, new_state)
    return new_params, new_state


def make_distributed_update(optimizer, mesh: Mesh, data_axes=("data",),
                            comm: Optional[CommConfig] = DEFAULT_COMM):
    """Build (init_fn, update_fn) realizing the paper's update under
    shard_map over ``data_axes``.  Params/grads enter replicated across the
    data axes (grads are the LOCAL minibatch-shard gradients, summed over
    local samples); optimizer state lives as per-member strips sharded on
    dim 0 — per fusion bucket when ``comm`` is given, per tensor when
    ``comm`` is None.  The bucketed collectives run on ``comm.backend``
    (lax or the explicit Pallas ring — ``repro.comm.backends``).

    update_fn(params, grads, opt_state, lr) -> (new_params, new_opt_state)
    """
    axes, axis_arg, G = group_axes(mesh, data_axes)

    if comm is None:
        return _make_per_tensor_update(optimizer, mesh, axis_arg, G)

    init_fn = _make_bucketed_init(optimizer, mesh, axes, axis_arg, G, comm)

    def _update(params, grads, opt_state, lr):
        plan = plan_buckets(params, G, comm.bucket_bytes)
        sched = make_schedule(axis_arg, comm.hierarchical, comm.backend,
                              comm.cross_backend)
        flat_grads = jax.tree.leaves(grads)
        # 1) one part-reduce per BUCKET: pack gradients into the fusion
        #    buffer, reduce on the wire dtype, mean in fp32
        g_strips = [sched.reduce(pack_bucket(flat_grads, b),
                                 comm.wire_dtype) / G
                    for b in plan.buckets]
        return _apply_strip_update(optimizer, sched, plan, G, params,
                                   g_strips, opt_state, lr)

    def update_fn(params, grads, opt_state, lr):
        pspec = jax.tree.map(lambda _: P(), params)
        sspec = jax.tree.map(lambda s: _state_spec(s, axis_arg), opt_state)
        fn = jax.shard_map(
            _update, mesh=mesh,
            in_specs=(pspec, pspec, sspec, P()),
            out_specs=(pspec, sspec),
            check_vma=False)
        return fn(params, grads, opt_state, lr)

    return init_fn, update_fn


def make_overlapped_update(optimizer, mesh: Mesh, data_axes=("data",),
                           comm: Optional[CommConfig] = None):
    """The backprop-overlapped counterpart of ``make_distributed_update``:
    (init_fn, local_update) where ``local_update`` consumes per-bucket
    ALREADY-REDUCED mean-gradient strips instead of a raw gradient tree —
    the reduces were issued inside the backward pass by the
    ``repro.comm.overlap`` hooks, so step 1 of the §3.4 schedule no longer
    exists as a post-grad block.

    Unlike ``make_distributed_update``'s update_fn, ``local_update(params,
    g_strips, opt_state, lr)`` must be called INSIDE ``shard_map`` over the
    same data axes: the overlapped train step owns the shard_map, because
    the bucket reduces live in its ``value_and_grad`` backward pass (see
    ``train.make_overlapped_train_step``).  ``init_fn`` is the shared
    bucketed strip init — state layouts are identical, so a checkpoint
    written by one path restores into the other.
    """
    comm = DEFAULT_COMM if comm is None else comm
    axes, axis_arg, G = group_axes(mesh, data_axes)
    init_fn = _make_bucketed_init(optimizer, mesh, axes, axis_arg, G, comm)
    sched = make_schedule(axis_arg, comm.hierarchical, comm.backend,
                              comm.cross_backend)

    def local_update(params, g_strips, opt_state, lr):
        plan = plan_buckets(params, G, comm.bucket_bytes)
        return _apply_strip_update(optimizer, sched, plan, G, params,
                                   g_strips, opt_state, lr)

    return init_fn, local_update


def _state_spec(s, axis_arg) -> P:
    # strip tensors are (G, n/G): dim 0 sharded; scalars (e.g. AdamW
    # step count) replicated
    return P(axis_arg) if getattr(s, "ndim", 0) >= 2 else P()


def _make_per_tensor_update(optimizer, mesh: Mesh, axis_arg, G: int):
    """The seed schedule: one part-reduce/part-broadcast pair PER TENSOR.
    Latency-bound for nets with many small tensors (§3.2); retained as the
    reference implementation the bucketed path is tested against."""

    def _strip_init(params):
        def per_tensor(p):
            flat = flatten_pad(p, G)
            return flat.reshape(G, -1)
        return optimizer.init(jax.tree.map(per_tensor, params))

    def init_fn(params):
        # see the bucketed init_fn: device_put instead of out_shardings
        with jax.set_mesh(mesh):
            state = jax.jit(_strip_init)(params)
        shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, _state_spec(s, axis_arg)), state)
        return jax.tree.map(jax.device_put, state, shardings)

    def _update(params, grads, opt_state, lr):
        flat_params, treedef = jax.tree.flatten(params)
        flat_grads = jax.tree.leaves(grads)

        # 1) part-reduce every gradient into this member's strip (mean)
        g_strips = [strip_reduce(g, axis_arg) for g in flat_grads]
        # 2) slice this member's strip of the (replicated) params
        i = make_schedule(axis_arg).owner_index()
        p_strips = []
        for p in flat_params:
            flat = flatten_pad(p, G)
            n = flat.size // G
            p_strips.append(lax.dynamic_slice(flat, (i * n,), (n,)))
        # 3) serial optimizer on the strips
        g_tree = jax.tree.unflatten(treedef, g_strips)
        p_tree = jax.tree.unflatten(treedef, p_strips)
        s_local = jax.tree.map(
            lambda s: s[0] if s.ndim >= 2 else s, opt_state)
        new_p_strips, new_state = optimizer.update(g_tree, s_local, p_tree, lr)
        # 4) part-broadcast updated strips back to full tensors
        new_flat = []
        for p, ps in zip(flat_params, jax.tree.leaves(new_p_strips)):
            new_flat.append(strip_broadcast(ps, axis_arg, p.shape))
        new_params = jax.tree.unflatten(treedef, new_flat)
        new_state = jax.tree.map(
            lambda s: s[None] if s.ndim >= 1 else s, new_state)
        return new_params, new_state

    def update_fn(params, grads, opt_state, lr):
        pspec = jax.tree.map(lambda _: P(), params)
        sspec = jax.tree.map(lambda s: _state_spec(s, axis_arg), opt_state)
        fn = jax.shard_map(
            _update, mesh=mesh,
            in_specs=(pspec, pspec, sspec, P()),
            out_specs=(pspec, sspec),
            check_vma=False)
        return fn(params, grads, opt_state, lr)

    return init_fn, update_fn
