"""AdamW — for the transformer-family architectures (beyond-paper substrate;
the paper's CNN/DNN experiments use momentum SGD)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


@dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init(self, params) -> AdamWState:
        def z(p):
            return jnp.zeros_like(p)
        return AdamWState(jax.tree.map(z, params), jax.tree.map(z, params),
                          jnp.zeros((), jnp.int32))

    def update(self, grads, state: AdamWState, params, lr
               ) -> Tuple[Any, AdamWState]:
        c = state.count + 1
        bc1 = 1 - self.b1 ** c.astype(jnp.float32)
        bc2 = 1 - self.b2 ** c.astype(jnp.float32)

        mu = jax.tree.map(lambda g, m: self.b1 * m + (1 - self.b1) * g,
                          grads, state.mu)
        nu = jax.tree.map(lambda g, v: self.b2 * v + (1 - self.b2) * g * g,
                          grads, state.nu)
        new_params = jax.tree.map(
            lambda p, m, v: p - lr * ((m / bc1) / (jnp.sqrt(v / bc2)
                                                   + self.eps)
                                      + self.weight_decay * p),
            params, mu, nu)
        return new_params, AdamWState(mu, nu, c)
