"""Paper §2.2 — cache blocking as constrained B/F minimization, adapted to TPU.

The paper formulates block-size selection as:

    BS  = working-set bytes of one block (inputs + outputs + weights)
    CPB = FLOPs computed on that block
    minimize B/F = BS/CPB  subject to  BS < Size_cache

and solves it by brute-force search over loop-block sizes, with one dimension
pinned to a multiple of the SIMD width.

TPU adaptation (DESIGN.md §2): the capacity constraint is VMEM (~16 MiB per
core, halved for double buffering); the alignment constraint is the lane/MXU
width 128 (sublane 8) instead of AVX2's 8-float SIMD; the chosen blocks are
emitted as Pallas ``BlockSpec`` tile shapes.  The search itself — the paper's
contribution — is unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

LANE = 128      # TPU lane width / MXU tile edge
SUBLANE = 8     # f32 sublane


def _candidates(dim: int, align: int, max_val: Optional[int] = None) -> List[int]:
    """Aligned candidate block sizes for a dimension of extent ``dim``."""
    cap = dim if max_val is None else min(dim, max_val)
    out = []
    c = align
    while c <= cap:
        if dim % c == 0:
            out.append(c)
        c *= 2
    if dim <= cap and dim % align == 0 and dim not in out:
        out.append(dim)
    if not out:
        out = [min(dim, align)]
    return out


@dataclass(frozen=True)
class GemmBlocking:
    bm: int
    bn: int
    bk: int
    bytes_per_block: int
    bf_ratio: float     # bytes moved per FLOP at steady state


def solve_gemm_blocking(M: int, N: int, K: int,
                        vmem_bytes: int = 8 * 2**20,
                        size_data: int = 4,
                        acc_bytes: int = 4) -> GemmBlocking:
    """Brute-force B/F minimization for C[M,N] += A[M,K] @ B[K,N].

    Working set (paper's BS, with the f32 accumulator tile counted once and
    A/B double-buffered by the caller's vmem budget):
        BS = size*(bm*bk + bk*bn) + acc*bm*bn
    Steady-state HBM traffic to produce one (bm, bn) output tile:
        bytes = size*(bm*K + K*bn) + acc*bm*bn
        flops = 2*bm*bn*K
    so B/F = size*(1/bn + 1/bm)/2 + acc/(2K): maximize the harmonic mean of
    (bm, bn) under the capacity constraint — the brute force reproduces the
    paper's search rather than assuming the closed form; a property test
    checks they agree.
    """
    best: Optional[GemmBlocking] = None
    for bm in _candidates(M, SUBLANE, 512):
        for bn in _candidates(N, LANE, 2048):
            for bk in _candidates(K, LANE, 2048):
                bs = size_data * (bm * bk + bk * bn) + acc_bytes * bm * bn
                if bs > vmem_bytes:
                    continue
                traffic = size_data * (bm * K + K * bn) + acc_bytes * bm * bn
                flops = 2.0 * bm * bn * K
                bf = traffic / flops
                cand = GemmBlocking(bm, bn, bk, bs, bf)
                if best is None or bf < best.bf_ratio or (
                        bf == best.bf_ratio and bs < best.bytes_per_block):
                    best = cand
    assert best is not None
    return best


@dataclass(frozen=True)
class ConvBlocking:
    b_mb: int      # minibatch block
    b_ifm: int
    b_ofm: int
    b_oh: int
    b_ow: int
    bytes_per_block: int
    bf_ratio: float


def conv_block_bytes(b_mb, b_ifm, b_ofm, b_oh, b_ow, k, s,
                     size_data: int = 4) -> int:
    """Paper §2.2 BS: output block + input block + weight block."""
    in_h = b_oh * s + k - 1
    in_w = b_ow * s + k - 1
    return size_data * (b_mb * b_ofm * b_oh * b_ow
                        + b_mb * b_ifm * in_h * in_w
                        + b_ifm * b_ofm * k * k)


def conv_block_flops(b_mb, b_ifm, b_ofm, b_oh, b_ow, k) -> float:
    """Paper §2.2 CPB = 2 * mb * ifm * ofm * k_w * k_h * out_w * out_h."""
    return 2.0 * b_mb * b_ifm * b_ofm * b_oh * b_ow * k * k


def solve_conv_blocking(minibatch: int, ifm: int, ofm: int,
                        out_hw: int, kernel: int, stride: int = 1,
                        cache_bytes: int = 8 * 2**20,
                        size_data: int = 4,
                        simd: int = LANE) -> ConvBlocking:
    """The paper's brute-force state-space search (§2.2), with the ofm block
    pinned to a multiple of the SIMD/lane width.  Traffic model: traversing
    consecutive blocks along each dim reuses the overlapping input rows /
    resident outputs (the paper's 'traversal' observation); we charge each
    block its BS and account reuse by preferring blocks that cover a whole
    dimension (the flops denominator grows with coverage)."""
    best: Optional[ConvBlocking] = None
    mb_cands = sorted({1, min(2, minibatch), min(4, minibatch),
                       min(8, minibatch), minibatch})
    ofm_cands = _candidates(ofm, min(simd, ofm))
    ifm_cands = sorted({1, *(c for c in (8, 16, 32, 64, 128, 256, 512, 1024)
                             if c <= ifm and ifm % c == 0), ifm})
    hw_cands = sorted({1, *(c for c in (2, 3, 4, 6, 7, 12, 14, 24, 28, 56)
                            if c <= out_hw and out_hw % c == 0), out_hw})
    for b_mb in mb_cands:
        for b_ifm in ifm_cands:
            for b_ofm in ofm_cands:
                for b_oh in hw_cands:
                    for b_ow in hw_cands:
                        bs = conv_block_bytes(b_mb, b_ifm, b_ofm, b_oh, b_ow,
                                              kernel, stride, size_data)
                        if bs > cache_bytes:
                            continue
                        # bytes charged: input+weights stream per block; the
                        # output tile is resident while the ifm loop runs.
                        in_h = b_oh * stride + kernel - 1
                        in_w = b_ow * stride + kernel - 1
                        traffic = size_data * (
                            b_mb * b_ofm * b_oh * b_ow            # out, once
                            + b_mb * ifm * in_h * in_w            # all ifm
                            + ifm * b_ofm * kernel * kernel)      # all wts
                        flops = conv_block_flops(b_mb, ifm, b_ofm, b_oh, b_ow,
                                                 kernel)
                        bf = traffic / flops
                        cand = ConvBlocking(b_mb, b_ifm, b_ofm, b_oh, b_ow,
                                            bs, bf)
                        if best is None or bf < best.bf_ratio:
                            best = cand
    assert best is not None
    return best


def layer_bf_unblocked(l_out_hw: int, kernel: int, stride: int = 1,
                       size_data: int = 4) -> float:
    """Paper §2.2 row-at-a-time B/F:
    size*(out_w*out_h + in_w*in_h + k_w*k_h)/(2*k_w*k_h*out_w*out_h).
    For OverFeat-FAST C5 (12x12 out, 3x3 kernel) this is 0.54."""
    out_w = out_h = l_out_hw
    in_w = out_w * stride + kernel - 1
    in_h = out_h * stride + kernel - 1
    return size_data * (out_w * out_h + in_w * in_h + kernel * kernel) / (
        2.0 * kernel * kernel * out_w * out_h)


def layer_bf_fully_cached(minibatch: int, ifm: int, ofm: int, out_hw: int,
                          kernel: int, stride: int = 1,
                          size_data: int = 4) -> float:
    """Paper §2.2 best-case B/F when everything fits on chip:
    for OverFeat-FAST C5 at minibatch 256 this is ~0.003."""
    out_w = out_h = out_hw
    in_w = out_w * stride + kernel - 1
    in_h = out_h * stride + kernel - 1
    num = size_data * (minibatch * ofm * out_w * out_h
                       + minibatch * ifm * in_w * in_h
                       + ifm * ofm * kernel * kernel)
    den = 2.0 * minibatch * ofm * ifm * kernel * kernel * out_w * out_h
    return num / den
