"""Logical-axis sharding rules (MaxText-style), divisibility-safe.

Every parameter and activation names its dims with *logical* axes
("batch", "ff", "heads", ...).  A rule table maps logical axes to mesh axes;
the resolver drops a rule whenever the dim is not divisible by the mesh-axis
extent (e.g. 8 kv-heads on a 16-way model axis), so one rule table serves all
ten architectures.

The rule table IS the paper's hybrid-parallel assignment: "batch" on the
data-parallel group axes (pod, data) = the G groups of §3.3; feature-like
axes ("ff", "heads", "vocab", "experts", ...) on the in-group "model" axis.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Optional[Tuple[str, ...]]   # mesh axes one logical axis maps to

# Paper-faithful hybrid-parallel rules (DESIGN.md §2).
DEFAULT_RULES: Dict[str, MeshAxes] = {
    # data-parallel group axes (the paper's G groups)
    "batch": ("pod", "data"),
    # model-parallel (within-group) axes
    "ff": ("model",),
    "moe_ff": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "experts": ("model",),        # falls back to moe_ff when E % 16 != 0
    "moe_out": ("model",),        # moe_down_rs: shard down-proj output d
    # replicated by default
    "embed": None,
    "embed_fsdp": ("data",),      # FSDP weight sharding (mixtral etc.)
    "seq": None,
    "seq_res": ("model",),        # seq_shard_carry: residual stream seq dim
    "kernel": None,
    "head_dim": None,
    "ssm_state": None,
    "codebooks": None,
    "cache_seq": None,            # long_500k: overridden to ("data",)
}


@dataclass(frozen=True)
class ShardingRules:
    rules: Dict[str, MeshAxes] = field(default_factory=lambda: dict(DEFAULT_RULES))

    def with_overrides(self, **over: MeshAxes) -> "ShardingRules":
        r = dict(self.rules)
        r.update(over)
        return ShardingRules(r)

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Sequence[int], mesh: Mesh) -> P:
        """Resolve logical axes to a PartitionSpec, honoring divisibility and
        never assigning one mesh axis twice."""
        used = set()
        parts = []
        for name, dim in zip(logical_axes, shape):
            assignment = None
            if name is not None:
                cand = self.rules.get(name)
                if cand:
                    axes = tuple(a for a in cand if a in mesh.axis_names
                                 and a not in used)
                    extent = 1
                    for a in axes:
                        extent *= mesh.shape[a]
                    if axes and extent > 1 and dim % extent == 0:
                        assignment = axes if len(axes) > 1 else axes[0]
                        used.update(axes)
            parts.append(assignment)
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def sharding(self, logical_axes: Sequence[Optional[str]],
                 shape: Sequence[int], mesh: Mesh) -> NamedSharding:
        return NamedSharding(mesh, self.spec(logical_axes, shape, mesh))


@dataclass(frozen=True)
class ShardingCtx:
    """Carried through model code; no-op when mesh is None (CPU tests)."""
    mesh: Optional[Mesh] = None
    rules: ShardingRules = field(default_factory=ShardingRules)

    def constrain(self, x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
        if self.mesh is None:
            return x
        spec = self.rules.spec(logical_axes, x.shape, self.mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


def tree_shardings(axes_tree, shape_tree, mesh: Mesh,
                   rules: ShardingRules):
    """Map a pytree of logical-axes tuples + matching shapes to NamedShardings."""
    return jax.tree.map(
        lambda ax, shp: rules.sharding(ax, shp, mesh),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
