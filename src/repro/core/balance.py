"""Paper §3 — computation/communication balance equations for synchronous SGD.

Every formula here is a direct transcription of the paper (Das et al. 2016),
with the equation it came from cited inline.  All comp quantities are FLOPs,
all comm quantities are bytes, all times are seconds.

These equations are used three ways:
  * by ``benchmarks/`` to regenerate the paper's Table 1 and the analytic
    scaling curves behind Figs 4/6/7 (paper-faithful reproduction);
  * by ``core.hybrid`` to pick the data/model/hybrid strategy per layer
    (the paper's §3.2/§3.3 decision rules);
  * by tests, as executable documentation (property tests assert the
    closed forms match the long forms).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.configs.base import ConvLayerSpec, HardwareConfig

SIZE_F32 = 4


# ---------------------------------------------------------------------------
# §3.1 data parallelism — per-layer comp and comm
# ---------------------------------------------------------------------------
def conv_comp_flops(lyr: ConvLayerSpec, mb_node: int) -> float:
    """Paper §3.1: Comp = 3*2*MB_node*ifm*ofm*k_w*k_h*out_w*out_h
    (forward + backprop + weight-gradient, each 2*MACs)."""
    return (3.0 * 2.0 * mb_node * lyr.ifm * lyr.ofm * lyr.kernel
            * lyr.kernel * lyr.out_hw * lyr.out_hw)


def fc_comp_flops(ifm: int, ofm: int, mb_node: int) -> float:
    """FC = conv with k=out=1 (paper §2.1)."""
    return 3.0 * 2.0 * mb_node * ifm * ofm


def data_parallel_comm_bytes(lyr: ConvLayerSpec, overlap: float = 1.0,
                             size_data: int = SIZE_F32) -> float:
    """Paper §3.1: Comm = size_data*ifm*ofm*k_w*k_h*(2-overlap).
    (send partial weight gradients + receive updated weights; overlap=1
    means sends/receives fully overlap each other.)"""
    k = max(lyr.kernel, 1)
    return size_data * lyr.ifm * lyr.ofm * k * k * (2.0 - overlap)


def data_parallel_comp_comm_ratio(lyr: ConvLayerSpec, mb_node: int) -> float:
    """Paper §3.1 closed form: comp_comm = 1.5*out_w*out_h*MB_node
    (FP32, overlap=1).  Independent of kernel size, ifm, ofm, stride."""
    return 1.5 * lyr.out_hw * lyr.out_hw * mb_node


def aggregate_comp_comm_ratio(layers: Sequence[ConvLayerSpec],
                              mb_node: int = 1, overlap: float = 1.0) -> float:
    """Network-level comp-to-comm for the data-parallel regime: total conv
    FLOPs per node / total gradient+weight bytes.  The paper quotes 208 for
    OverFeat-FAST and 1456 for VGG-A conv layers."""
    comp = sum(conv_comp_flops(lyr, mb_node) for lyr in layers)
    comm = sum(data_parallel_comm_bytes(lyr, overlap) for lyr in layers)
    return comp / comm


# ---------------------------------------------------------------------------
# §3.1 overlap / bubble model
# ---------------------------------------------------------------------------
@dataclass
class LayerBalance:
    name: str
    comp: float    # FLOPs per node per iteration (3 passes)
    comm: float    # bytes per node per iteration (data-parallel volume)


def bubble_schedule(layers: Sequence[LayerBalance], hw: HardwareConfig,
                    efficiency: float = 1.0) -> List[float]:
    """Paper §3.1:
        ocomp_i  = sum_{j<i} comp_j + comp_i/3
        ocomms_i = sum_{j<=i} comms_j
        bubble_i = ocomms_i/comms_sys - ocomp_i/comp_sys
    Layers are indexed in FORWARD order; communication of layer i (issued
    right after its weight-gradient in backprop) can overlap with the
    remaining backprop of layers j<i plus layer i's own input-grad pass
    (the comp_i/3 term — the paper computes the weight gradient BEFORE
    backprop to enlarge the overlap window).  Returns per-layer bubbles
    (seconds, may be negative = fully hidden)."""
    comp_sys = hw.peak_flops * efficiency
    bubbles = []
    for i, li in enumerate(layers):
        ocomp = sum(lyr.comp for lyr in layers[:i]) + li.comp / 3.0
        ocomms = sum(lyr.comm for lyr in layers[: i + 1])
        bubbles.append(ocomms / hw.link_bw - ocomp / comp_sys)
    return bubbles


def issue_order(triggers: Sequence[int]) -> Tuple[int, ...]:
    """Bucket indices in backprop issue order — THE ordering rule of the
    §3.1 overlap schedule, defined once: descending trigger layer (a bucket
    completed by a later layer is ready earlier in backprop), ties toward
    the later tree-order bucket.  ``repro.comm`` (``overlap.issue_order``,
    ``BucketPlan.backprop_order``) and the closed forms below all delegate
    here, so the analytic model can never drift from the executable
    schedule."""
    return tuple(sorted(range(len(triggers)),
                        key=lambda b: (triggers[b], b), reverse=True))


def bucket_bubble_schedule(comm_times: Sequence[float],
                           triggers: Sequence[int],
                           layer_comps: Sequence[float],
                           hw: HardwareConfig,
                           efficiency: float = 1.0) -> List[float]:
    """The §3.1 bubble schedule at fusion-BUCKET granularity — the analytic
    model of ``repro.comm.overlap``'s executable schedule.

    ``comm_times[b]``   seconds of communication for bucket ``b`` (tree
                        order; e.g. ``ring_collective_time`` of its padded
                        bytes — the caller picks the comm model).
    ``triggers[b]``     the forward-order layer whose weight-gradient pass
                        completes bucket ``b`` (``overlap.bucket_triggers``).
    ``layer_comps[t]``  FLOPs of layer ``t`` per node per iteration (all
                        three passes, like ``LayerBalance.comp``).

    Buckets are issued in descending-trigger order (backprop readiness); at
    bucket ``b``'s issue point the un-overlapped window is its own transfer
    plus everything issued after it, while the hideable compute is the
    remaining backprop of layers below the trigger plus the trigger layer's
    own input-gradient pass (the paper's ``comp/3`` term):

        bubble_b = (comm_b + comms issued after b) / comms_sys
                 - (sum_{j < trigger_b} comp_j + comp_{trigger_b}/3) / comp_sys

    Returned in bucket (tree) order, seconds, may be negative = fully
    hidden.  With one bucket per layer this IS ``bubble_schedule`` — the
    reduction is property-tested in tests/test_comm.py.
    """
    comp_sys = hw.peak_flops * efficiency
    order = issue_order(triggers)
    total_comm = float(sum(comm_times))
    bubbles = [0.0] * len(comm_times)
    issued = 0.0
    for b in order:
        t = triggers[b]
        ocomp = sum(layer_comps[:t]) + layer_comps[t] / 3.0
        bubbles[b] = (total_comm - issued) - ocomp / comp_sys
        issued += comm_times[b]
    return bubbles


def overlap_exposed_time(comm_times: Sequence[float],
                         triggers: Sequence[int],
                         layer_comps: Sequence[float],
                         hw: HardwareConfig,
                         efficiency: float = 1.0) -> float:
    """Exposed communication (seconds) of the §3.1 overlap schedule, by
    timeline: buckets transfer on one shared serialized link in issue order
    (descending trigger), each issued when its trigger layer's weight
    gradient finishes (the paper computes the weight gradient BEFORE the
    input-gradient pass to enlarge the window) and due when that layer's
    NEXT-iteration forward starts.  The step stalls by the worst lateness
    across buckets — a stall shifts every later deadline, absorbing later
    lateness — so, unlike summing ``bucket_bubble_schedule`` positives
    (each bubble re-counts the comm below it), the result is bounded by
    ``sum(comm_times)``: with zero overlappable compute it IS the
    monolithic all-exposed time.
    """
    comp_sys = hw.peak_flops * efficiency
    order = issue_order(triggers)
    t_bp = 2.0 / 3.0 * sum(layer_comps) / comp_sys
    below = [0.0]                     # prefix sums: sum_{j<t} comp_j
    for c in layer_comps:
        below.append(below[-1] + c)
    link_free = 0.0
    exposed = 0.0
    for b in order:
        t = triggers[b]
        issue = (2.0 / 3.0 * (below[len(layer_comps)] - below[t + 1])
                 + layer_comps[t] / 3.0) / comp_sys
        finish = max(issue, link_free) + comm_times[b]
        link_free = finish
        deadline = t_bp + below[t] / 3.0 / comp_sys
        exposed = max(exposed, finish - deadline)
    return max(0.0, exposed)


def scaling_efficiency(layers: Sequence[LayerBalance], hw: HardwareConfig,
                       efficiency: float = 1.0) -> float:
    """Paper §3.1: efficiency = (sum comp_i / comp_sys) /
    (sum_i bubble_i+ + sum comp_i / comp_sys).  Positive bubbles are the
    un-hidden communication; bubble_0 (the first layer) is never hidable."""
    comp_sys = hw.peak_flops * efficiency
    t_comp = sum(lyr.comp for lyr in layers) / comp_sys
    bubbles = bubble_schedule(layers, hw, efficiency)
    t_bubble = sum(max(0.0, b) for b in bubbles)
    return t_comp / (t_comp + t_bubble)


def max_data_parallel_nodes(layers: Sequence[LayerBalance],
                            hw: HardwareConfig, minibatch: int) -> float:
    """Paper §3.1: N <= minibatch * (comms_sys/comp_sys) * (ocomp_k/ocomms_k)
    where L_k is the last layer in the data-parallel regime.  comp here is
    per data point (MB_node = 1)."""
    k = len(layers) - 1
    ocomp_k = sum(lyr.comp for lyr in layers[:k]) + layers[k].comp / 3.0
    ocomms_k = sum(lyr.comm for lyr in layers)
    n = minibatch * (hw.link_bw / hw.peak_flops) * (ocomp_k / ocomms_k)
    return min(float(minibatch), n)  # >= 1 data point per node


# ---------------------------------------------------------------------------
# §3.2 model parallelism
# ---------------------------------------------------------------------------
def model_parallel_comm_bytes(ifm: int, in_hw: int, minibatch: int,
                              size_data: int = SIZE_F32) -> float:
    """Paper §3.2 total forward-pass activation exchange:
    size_data * ifm * input_w * input_h * minibatch."""
    return size_data * ifm * in_hw * in_hw * minibatch


def model_parallel_preferred(lyr: ConvLayerSpec, in_hw: int, minibatch: int,
                             overlap: float = 1.0) -> bool:
    """Paper §3.2 decision rule:
    ofm*k_w*k_h*(2-overlap) > input_w*input_h*minibatch  => model parallel.
    For FC layers (k=in=1): ofm > minibatch => model parallel."""
    k = max(lyr.kernel, 1)
    return lyr.ofm * k * k * (2.0 - overlap) > in_hw * in_hw * minibatch


# ---------------------------------------------------------------------------
# §3.3 hybrid parallelism
# ---------------------------------------------------------------------------
def hybrid_comm_bytes(ifm: int, ofm: int, kernel: int, in_hw: int,
                      minibatch: int, G: int, N: int,
                      overlap: float = 0.0, size_data: int = SIZE_F32) -> float:
    """Paper §3.3: total communication volume for G data-parallel groups of
    N/G model-parallel nodes:
        G > 1: 2*size*ifm*in_w*in_h*(minibatch/G)
               + size*ofm*ifm*k_w*k_h*(2-overlap)*(G/N)
        G = 1: 2*size*ifm*in_w*in_h*minibatch            (pure model parallel)
    """
    k = max(kernel, 1)
    if G <= 1:
        return 2.0 * size_data * ifm * in_hw * in_hw * minibatch
    model_part = 2.0 * size_data * ifm * in_hw * in_hw * (minibatch / G)
    data_part = size_data * ofm * ifm * k * k * (2.0 - overlap) * (G / N)
    return model_part + data_part


def optimal_group_count(N: int, minibatch: int, ofm: int) -> int:
    """Paper §3.3 (FC layer, FP32, no overlap):
    d(8*ifm*(minibatch/G + ofm*G/N))/dG = 0  =>  G = sqrt(N*minibatch/ofm).
    Clamped to [1, N] and rounded to the nearest divisor-friendly integer."""
    g = math.sqrt(N * minibatch / ofm)
    g = max(1.0, min(float(N), g))
    return max(1, round(g))


def hybrid_comm_at_optimum(ifm: int, ofm: int, minibatch: int, N: int,
                           size_data: int = SIZE_F32) -> Tuple[int, float]:
    """Evaluate the §3.3 FC example.  For ofm=4096, minibatch=256, N=64 the
    paper gets G=3 and volume 8*ifm*213 (vs 8*ifm*256 for G=1)."""
    G = optimal_group_count(N, minibatch, ofm)
    vol = hybrid_comm_bytes(ifm, ofm, 1, 1, minibatch, G, N, overlap=0.0,
                            size_data=size_data)
    return G, vol


# ---------------------------------------------------------------------------
# §3.2 latency + bucket term (extends the paper's pure-bandwidth comm model)
# ---------------------------------------------------------------------------
# The paper's comms_sys is bandwidth-only; its SWlat appears once per message.
# The part-reduce/part-broadcast pair for one fusion buffer on a ring of G
# members costs 2*(G-1) messages, so issuing one pair PER TENSOR puts nets
# with many small tensors (VGG-A conv biases) in the latency-bound regime.
# Bucketing (repro.comm) amortizes SWlat over bucket_bytes; these closed
# forms predict the collective count and the optimal bucket size that
# benchmarks/table1_balance.py and the comm sweep report.


@dataclass(frozen=True)
class RingBackendModel:
    """How a collective backend (repro.comm.backends) shifts the §3.2 comm
    constants: per-message software latency scales by ``latency_scale`` and
    the achieved link bandwidth is ``bw_efficiency * hw.link_bw``."""
    latency_scale: float
    bw_efficiency: float


# Per-backend constants for the ring cost model.  "lax" is the calibration
# baseline (1, 1): hw tables already describe the stock XLA collectives.
# "pallas-ring" is the hand-scheduled ring of kernels/ring.py: issuing each
# hop's neighbor copy straight from the kernel skips the per-collective
# dispatch/fusion barrier (~half the per-message SWlat), while the
# double-buffered chunk rotation exposes one chunk of pipeline fill per
# direction (~95% of link bandwidth).  Provisional until the runtime
# autotuning feedback loop (ROADMAP) replaces them with measured values —
# benchmarks/comm_bucket_sweep.py reports predicted-vs-measured per backend.
RING_BACKEND_MODELS = {
    "lax": RingBackendModel(latency_scale=1.0, bw_efficiency=1.0),
    "pallas-ring": RingBackendModel(latency_scale=0.5, bw_efficiency=0.95),
    # the gossip exchange is plain lax.ppermute under the hood — stock XLA
    # message constants; its win is the MESSAGE COUNT (one partner message
    # vs the ring's G-1 hops, see gossip_exchange_time), not the per-message
    # cost
    "gossip": RingBackendModel(latency_scale=1.0, bw_efficiency=1.0),
}


# Cross-HOST link regimes for the cluster subsystem (repro.cluster): when
# the "pod" axis is a process/host boundary (launch.mesh.make_cluster_mesh),
# the hierarchical schedule's cross-pod hop crosses one of these links, not
# the in-node fabric.  (link bytes/s per direction, per-message SWlat s) —
# the paper's §5 hardware: FDR InfiniBand on the Endeavor cluster (RDMA, the
# §3.2 calibration SWlat) and 10GbE Ethernet on the 16-node AWS cluster
# (~14X on 16 — kernel TCP stack, ~10x the per-message software latency).
CROSS_HOST_REGIMES = {
    "infiniband-fdr": (56e9 / 8, 5e-6),
    "ethernet-10gbe": (10e9 / 8, 50e-6),
}


def cross_host_hw(hw: HardwareConfig, regime: str) -> HardwareConfig:
    """``hw`` with its link constants replaced by a cross-host regime's —
    feed the result to ``hierarchical_allreduce_time`` (with ``pod_bw`` set
    to the fast in-host bandwidth) to model a multi-host cluster step."""
    if regime not in CROSS_HOST_REGIMES:
        raise ValueError(f"unknown cross-host regime {regime!r}; "
                         f"known: {tuple(CROSS_HOST_REGIMES)}")
    bw, lat = CROSS_HOST_REGIMES[regime]
    return dataclasses.replace(hw, name=f"{hw.name}+{regime}",
                               link_bw=bw, sw_latency=lat)


def backend_hw(hw: HardwareConfig, backend: str) -> HardwareConfig:
    """``hw`` with the backend's latency/bandwidth constants applied —
    the one place backend names enter the §3.2 closed forms."""
    if backend not in RING_BACKEND_MODELS:
        raise ValueError(f"unknown collective backend {backend!r}; "
                         f"known: {tuple(RING_BACKEND_MODELS)}")
    m = RING_BACKEND_MODELS[backend]
    if m.latency_scale == 1.0 and m.bw_efficiency == 1.0:
        return hw
    return dataclasses.replace(
        hw, name=f"{hw.name}+{backend}",
        sw_latency=hw.sw_latency * m.latency_scale,
        link_bw=hw.link_bw * m.bw_efficiency)


def collective_count(total_bytes: float, n_tensors: int,
                     bucket_bytes: float) -> int:
    """Part-reduce/part-broadcast pairs per step: O(#tensors) without
    fusion (bucket_bytes <= 0), O(total_bytes / bucket_bytes) with it."""
    if bucket_bytes <= 0:
        return n_tensors
    return max(1, math.ceil(total_bytes / bucket_bytes))


def ring_collective_time(nbytes: float, G: int, hw: HardwareConfig,
                         backend: str = "lax") -> float:
    """One reduce-scatter + all-gather pair on a G-member ring:
    2*(G-1) messages of nbytes/G each (bandwidth-optimal decomposition,
    see collectives.part_reduce_broadcast) + per-message SWlat.  ``backend``
    applies the per-implementation constants (``RING_BACKEND_MODELS``)."""
    if G <= 1:
        return 0.0
    hw = backend_hw(hw, backend)
    return 2.0 * (G - 1) * (hw.sw_latency + (nbytes / G) / hw.link_bw)


def bucketed_allreduce_time(total_bytes: float, n_tensors: int,
                            bucket_bytes: float, G: int,
                            hw: HardwareConfig,
                            n_coll: int = 0,
                            fill_bytes: float = 0.0,
                            backend: str = "lax") -> float:
    """Gradient round-trip time with fusion buffers:
        n_coll * 2*(G-1)*SWlat            (latency, amortized by bucketing)
      + 2*(G-1)/G * total_bytes / BW      (bandwidth, bucket-independent)
      + 2*(G-1)/G * fill_bytes / BW       (pipeline fill: the first message
                                           cannot overlap anything)
    Minimized by ``optimal_bucket_bytes``.  The fill term applies to EVERY
    schedule — per-tensor included: its granularity is the largest single
    tensor, which for fc-heavy nets dwarfs any sane bucket.

    ``n_coll`` overrides the closed-form collective count with the REAL
    planner's (``repro.comm.plan_buckets(...).n_collectives``) — the closed
    form assumes tensors split freely across buckets, but the planner never
    splits one, so a tree dominated by a few huge tensors issues far fewer
    collectives than ceil(total/bucket).  ``fill_bytes`` likewise overrides
    the default average-message estimate (total/n_coll) with the largest
    real message when the caller knows it.  ``backend`` applies the
    per-implementation ring constants (``RING_BACKEND_MODELS``)."""
    if G <= 1:
        return 0.0
    hw = backend_hw(hw, backend)
    if n_coll <= 0:
        n_coll = collective_count(total_bytes, n_tensors, bucket_bytes)
    if fill_bytes <= 0:
        fill_bytes = total_bytes / n_coll
    frac = 2.0 * (G - 1) / G
    return (n_coll * 2.0 * (G - 1) * hw.sw_latency
            + frac * (total_bytes + fill_bytes) / hw.link_bw)


# ---------------------------------------------------------------------------
# Compressed wire formats (CommConfig.wire_format): bytes-on-wire models.
# The reduce side of the §3.4 strip roundtrip can ship a compressed encoding
# (the ring dequantizes/accumulates/re-encodes per hop — kernels/ring.py);
# the all-gather side broadcasts WEIGHTS and always stays dense fp32.  These
# constants are what the comm sweep and the comm="auto" autotuner use to
# pick wire format + bucket size jointly.
# ---------------------------------------------------------------------------
WIRE_FORMAT_BYTES = {"fp32": 4.0, "bf16": 2.0, "int8": 1.0}
INT8_SCALE_BYTES = 4     # one f32 max-abs scale rides along per int8 message
TOPK_ENTRY_BYTES = 8.0   # 4B f32 value + 4B int32 index per kept element


def wire_bytes_per_element(wire_format: str, topk_ratio: float = 0.05) -> float:
    """Reduce-side wire bytes per (dense fp32) gradient element."""
    if wire_format == "topk":
        return TOPK_ENTRY_BYTES * topk_ratio
    try:
        return WIRE_FORMAT_BYTES[wire_format]
    except KeyError:
        raise ValueError(
            f"unknown wire format {wire_format!r}; known: "
            f"{tuple(WIRE_FORMAT_BYTES) + ('topk',)}") from None


def wire_reduce_factor(wire_format: str, topk_ratio: float = 0.05) -> float:
    """Reduce-side bytes-on-wire as a fraction of dense fp32 (fp32 -> 1,
    bf16 -> 0.5, int8 -> 0.25, topk -> 2*ratio)."""
    return wire_bytes_per_element(wire_format, topk_ratio) / SIZE_F32


def wire_reduce_bytes(total_bytes: float, G: int, n_coll: int,
                      wire_format: str, topk_ratio: float = 0.05) -> float:
    """Total reduce-side wire bytes of one step: the compressed payload plus
    the int8 per-message scale overhead ((G-1) messages per collective).
    ``total_bytes`` is the DENSE fp32 gradient volume."""
    data = total_bytes * wire_reduce_factor(wire_format, topk_ratio)
    if wire_format == "int8":
        data += n_coll * max(G - 1, 0) * INT8_SCALE_BYTES
    return data


def compressed_allreduce_time(total_bytes: float, n_tensors: int,
                              bucket_bytes: float, G: int,
                              hw: HardwareConfig,
                              wire_format: str = "fp32",
                              topk_ratio: float = 0.05,
                              n_coll: int = 0,
                              fill_bytes: float = 0.0,
                              backend: str = "lax") -> float:
    """``bucketed_allreduce_time`` with a compressed reduce wire: the
    reduce-scatter side moves ``wire_reduce_factor`` of the dense bytes,
    the all-gather (weight broadcast) side stays dense fp32:

        n_coll * 2*(G-1)*SWlat
      + (G-1)/G * (1 + f) * (total_bytes + fill_bytes) / BW

    At ``f = 1`` (fp32) this IS ``bucketed_allreduce_time`` — the reduction
    is property-tested in tests/test_comm.py."""
    if G <= 1:
        return 0.0
    hw = backend_hw(hw, backend)
    if n_coll <= 0:
        n_coll = collective_count(total_bytes, n_tensors, bucket_bytes)
    if fill_bytes <= 0:
        fill_bytes = total_bytes / n_coll
    f = wire_reduce_factor(wire_format, topk_ratio)
    return (n_coll * 2.0 * (G - 1) * hw.sw_latency
            + (G - 1) / G * (1.0 + f)
            * (total_bytes + fill_bytes) / hw.link_bw)


def optimal_bucket_bytes(total_bytes: float, G: int,
                         hw: HardwareConfig,
                         wire_format: str = "fp32",
                         topk_ratio: float = 0.05) -> float:
    """Minimizer of ``compressed_allreduce_time`` over the bucket size:
    d/db [ (B/b)*2*(G-1)*SWlat + (G-1)/G * (1+f) * (B+b)/BW ] = 0
        =>  b* = sqrt(B * SWlat * BW * G * 2/(1+f))
    where ``f`` is the reduce-side ``wire_reduce_factor`` — at fp32
    (f = 1) this is the classic ``b* = sqrt(B * SWlat * BW * G)``.  A
    compressed wire shrinks the bandwidth term, so the latency term is
    amortized over a LARGER optimal bucket.  Clamped to [64 KiB, B] (a
    bucket never exceeds the whole tree)."""
    if G <= 1 or total_bytes <= 0:
        return total_bytes
    f = wire_reduce_factor(wire_format, topk_ratio)
    b = math.sqrt(total_bytes * hw.sw_latency * hw.link_bw * G
                  * 2.0 / (1.0 + f))
    return max(min(b, total_bytes), min(64 * 1024, total_bytes))


def hierarchical_allreduce_time(total_bytes: float, n_tensors: int,
                                bucket_bytes: float, g_in: int, g_out: int,
                                hw: HardwareConfig,
                                pod_bw: float = 0.0,
                                n_coll: int = 0,
                                fill_bytes: float = 0.0,
                                backend: str = "lax",
                                cross_backend: str = "lax") -> float:
    """Two-level schedule (repro.comm.HierarchicalSchedule): bucketed
    reduce-scatter + all-gather in-pod over ``g_in`` members at the fast
    in-pod bandwidth ``pod_bw`` (defaults to hw.link_bw), plus the cross-pod
    hop over ``g_out`` pods moving only the 1/g_in strip bytes on
    hw.link_bw.  Composes the paper's §3.3 node groups.

    Both stages issue ONE collective per bucket (the cross-pod hop reduces
    each bucket's strip, it does not re-bucket it), so a single collective
    count applies to both; ``n_coll`` overrides it with the real planner's.
    ``backend`` applies to the in-pod stage and ``cross_backend`` to the
    cross-pod hop — mirroring ``make_schedule``'s per-level backends."""
    if n_coll <= 0:
        n_coll = collective_count(total_bytes, n_tensors, bucket_bytes)
    pod_hw = hw if pod_bw <= 0 else dataclasses.replace(
        hw, name=hw.name + "+pod", link_bw=pod_bw)
    t_in = bucketed_allreduce_time(total_bytes, n_tensors, bucket_bytes,
                                   g_in, pod_hw, n_coll=n_coll,
                                   fill_bytes=fill_bytes, backend=backend)
    strip_bytes = total_bytes / max(g_in, 1)
    t_out = bucketed_allreduce_time(strip_bytes, n_tensors, bucket_bytes,
                                    g_out, hw, n_coll=n_coll,
                                    fill_bytes=fill_bytes / max(g_in, 1),
                                    backend=cross_backend)
    return t_in + t_out


# ---------------------------------------------------------------------------
# Relaxed-consistency modes (PARALLEL_MODES stale-sync / gossip): what each
# buys per step relative to the synchronous §3.2 ring round-trip above
# ---------------------------------------------------------------------------
def gossip_exchange_time(total_bytes: float, n_tensors: int,
                         bucket_bytes: float, G: int,
                         hw: HardwareConfig,
                         n_coll: int = 0,
                         backend: str = "gossip") -> float:
    """Per-step wire time of the GossipGraD partner exchange
    (``comm.backends.gossip``) plus the unchanged strip all-gather:

        n_coll * SWlat + (total/G) / BW              (exchange: ONE
                                                      chunk-sized partner
                                                      message per bucket)
      + n_coll * (G-1) * SWlat
      + (G-1)/G * total / BW                         (all-gather: params
                                                      must stay replicated)

    versus the synchronous ring's ``2*(G-1)`` messages per bucket
    (``bucketed_allreduce_time``) — the reduce side drops from G-1
    messages to one, which is the latency-bound-regime win the mode
    exists for.  Same knob conventions as the ring forms (``n_coll``
    overrides the closed-form collective count with the real planner's).
    """
    if G <= 1:
        return 0.0
    hw = backend_hw(hw, backend)
    if n_coll <= 0:
        n_coll = collective_count(total_bytes, n_tensors, bucket_bytes)
    exchange = n_coll * hw.sw_latency + (total_bytes / G) / hw.link_bw
    gather = (n_coll * (G - 1) * hw.sw_latency
              + ((G - 1) / G) * total_bytes / hw.link_bw)
    return exchange + gather


def stale_sync_exposed_time(comm_time: float, compute_time: float) -> float:
    """Exposed comm under bounded staleness (PARALLEL_MODES "stale-sync"):
    step t consumes the reduce issued at t-1, so a FULL step of compute is
    available to hide it — exposure is only the overflow.  The limit of the
    §3.1 bubble schedule when the overlap window grows from the remaining
    backprop to the whole step; the price is a one-step-old gradient, not
    wire time."""
    return max(0.0, comm_time - compute_time)


# ---------------------------------------------------------------------------
# Whole-network scaling model (drives the Fig 4 / Fig 6 / Fig 7 benchmarks)
# ---------------------------------------------------------------------------
def network_balance(conv_layers: Sequence[ConvLayerSpec],
                    fc_layers: Sequence[ConvLayerSpec],
                    minibatch: int, nodes: int, hw: HardwareConfig,
                    compute_eff: float = 0.75,
                    overlap: float = 1.0) -> dict:
    """Estimate one-iteration time and scaling efficiency at ``nodes`` nodes.

    Conv layers run data-parallel with the §3.1 bubble/overlap model.
    FC layers run hybrid-parallel with the §3.3 optimal G; their activation
    and weight exchanges are not overlappable with conv compute in the
    paper's schedule, so their comm adds serially (conservative, matches the
    paper's observation that FC layers 'do not scale much').
    """
    mb_node = max(1.0, minibatch / nodes)
    comp_sys = hw.peak_flops * compute_eff

    conv = [LayerBalance(f"conv{i}", conv_comp_flops(lyr, mb_node),
                         data_parallel_comm_bytes(lyr, overlap))
            for i, lyr in enumerate(conv_layers)]
    t_conv_comp = sum(lyr.comp for lyr in conv) / comp_sys
    if nodes == 1:
        t_conv = t_conv_comp
        t_fc = sum(fc_comp_flops(lyr.ifm, lyr.ofm, minibatch) for lyr in fc_layers) / comp_sys
        return dict(step_time=t_conv + t_fc, efficiency=1.0, G_fc=1)

    bubbles = bubble_schedule(conv, hw, compute_eff)
    t_conv = t_conv_comp + sum(max(0.0, b) for b in bubbles)

    t_fc = 0.0
    G_used = 1
    for lyr in fc_layers:
        G = optimal_group_count(nodes, minibatch, lyr.ofm)
        G_used = G
        comm = hybrid_comm_bytes(lyr.ifm, lyr.ofm, 1, 1, minibatch, G, nodes,
                                 overlap=0.0)
        comp = fc_comp_flops(lyr.ifm, lyr.ofm, minibatch) / nodes
        t_fc += comp / comp_sys + comm / hw.link_bw + hw.sw_latency
    step = t_conv + t_fc
    # efficiency vs perfect scaling of the single-node time
    single = (sum(conv_comp_flops(lyr, minibatch) for lyr in conv_layers)
              + sum(fc_comp_flops(lyr.ifm, lyr.ofm, minibatch) for lyr in fc_layers)) / comp_sys
    eff = single / (nodes * step)
    return dict(step_time=step, efficiency=min(1.0, eff), G_fc=G_used)


def dnn_hybrid_scaling(input_dim: int, hidden: int, n_hidden: int,
                       output_dim: int, minibatch: int, nodes: int,
                       hw: HardwareConfig, compute_eff: float = 0.6) -> dict:
    """§5.4 CD-DNN: all-FC network under hybrid parallelism."""
    dims = [(input_dim, hidden)] + [(hidden, hidden)] * (n_hidden - 1) \
        + [(hidden, output_dim)]
    comp_sys = hw.peak_flops * compute_eff
    if nodes == 1:
        t = sum(fc_comp_flops(i, o, minibatch) for i, o in dims) / comp_sys
        return dict(step_time=t, efficiency=1.0, speedup=1.0)
    t = 0.0
    for i, o in dims:
        G = optimal_group_count(nodes, minibatch, o)
        comm = hybrid_comm_bytes(i, o, 1, 1, minibatch, G, nodes, overlap=0.5)
        t += fc_comp_flops(i, o, minibatch) / nodes / comp_sys \
            + comm / hw.link_bw + hw.sw_latency
    single = sum(fc_comp_flops(i, o, minibatch) for i, o in dims) / comp_sys
    return dict(step_time=t, efficiency=min(1.0, single / (nodes * t)),
                speedup=single / t)
