"""Paper §3.3 — hybrid data/model parallelism planner.

The mesh realizes the paper's scheme directly:

    G groups            = |pod| * |data|   (data-parallel replicas)
    nodes per group     = |model|          (model-parallel within a group)

This module (a) reports the paper-optimal G for a given layer/network so the
chosen mesh can be judged against the paper's own rule, and (b) produces the
``ShardingRules`` used to lower each (arch x input-shape) pair — including the
overrides for FSDP weight sharding and the long-context decode cache layout.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

from jax.sharding import Mesh

from repro.configs.base import HardwareConfig, InputShape, ModelConfig
from repro.core import balance
from repro.core.sharding import ShardingRules


@dataclass(frozen=True)
class HybridPlan:
    arch: str
    shape: str
    G: int                      # data-parallel group count of the mesh
    model_ways: int             # model-parallel width within a group
    G_opt_head: int             # paper-optimal G for the LM-head FC layer
    G_opt_ff: int               # paper-optimal G for the widest MLP layer
    rules: ShardingRules
    notes: Tuple[str, ...] = ()


def mesh_groups(mesh: Mesh) -> Tuple[int, int]:
    g = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            g *= mesh.shape[ax]
    m = mesh.shape.get("model", 1)
    return g, m


def plan(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
         hw: HardwareConfig) -> HybridPlan:
    G, model_ways = mesh_groups(mesh)
    N = G * model_ways
    notes = []

    # Paper §3.3: G = sqrt(N * minibatch / ofm) for an FC layer of width ofm.
    # The transformer analogues of the paper's big FC layers:
    mb = shape.global_batch * (shape.seq_len if shape.kind == "train" else 1)
    g_head = balance.optimal_group_count(N, mb, max(cfg.vocab_size, 1))
    widest_ff = max(cfg.d_ff, cfg.moe_d_ff * max(cfg.num_experts_per_tok, 1),
                    cfg.q_dim, 1)
    g_ff = balance.optimal_group_count(N, mb, widest_ff)

    rules = ShardingRules()
    if cfg.fsdp:
        rules = rules.with_overrides(embed=("data",))
        notes.append("fsdp: weight d_model sharded over 'data' "
                     "(beyond-paper; the paper replicates weights per node — "
                     "infeasible for this arch at 141B params)")
    if shape.kind == "decode":
        if shape.global_batch < G:
            # long_500k: batch=1 cannot be data-sharded; shard the KV-cache
            # sequence dim over the group axes instead (paper's part-reduce
            # applied to attention partials; see serve/decode.py).
            rules = rules.with_overrides(batch=None, cache_seq=("data",))
            notes.append("batch < G: cache_seq sharded over 'data', "
                         "attention partials combined part-reduce-style")
        elif cfg.num_kv_heads % model_ways != 0:
            # kv heads can't shard on 'model' (e.g. 24 % 16): shard the
            # cache sequence dim there instead, or the per-device KV cache
            # replicates model_ways x (39 GB/dev for musicgen decode_32k).
            # Softmax over the sharded seq dim psums partial max/sum —
            # again the paper's part-reduce pattern.
            rules = rules.with_overrides(cache_seq=("model",))
            notes.append(f"kv_heads={cfg.num_kv_heads} not divisible by "
                         f"model={model_ways}: cache_seq sharded on 'model'")
    return HybridPlan(cfg.name, shape.name, G, model_ways, g_head, g_ff,
                      rules, tuple(notes))
