"""Paper §3.4 — the two communication primitives, on jax.lax collectives.

    part-reduce    = reduce partial tensors over a node group, scatter the
                     result strips  -> MPI_Reduce_scatter -> lax.psum_scatter
    part-broadcast = every node broadcasts its strip to the group
                     -> MPI_Allgather -> lax.all_gather

The paper uses part-reduce between local weight-gradient computation and the
SGD update (each node updates a 1/G strip of the weights), and part-broadcast
to repopulate the updated weights — see ``optim/dist.py``.  In model-parallel
forward, part-reduce combines partial activations; part-broadcast rebuilds
full input gradients in backprop.

These run inside ``jax.shard_map``; axis_name may be a single mesh axis or a
tuple (e.g. ("pod", "data") for the multi-pod gradient reduction — the
cross-pod hop composes with the in-pod ring exactly as the paper composes
groups).

These functions are the internals of ``repro.comm.backends.LaxBackend`` —
the reference :class:`~repro.comm.backends.CollectiveBackend` every other
implementation (e.g. the Pallas ring of ``backends.pallas_ring``) must
match: same strip ownership (flat group member i owns chunk i along the
scatter dim) and same wire-dtype semantics (collectives run on whatever
dtype they are handed; casts belong to the schedule layer).
"""
from __future__ import annotations

from typing import Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

AxisNames = Union[str, Tuple[str, ...]]


def axis_size(axis_name: AxisNames) -> int:
    if isinstance(axis_name, str):
        return lax.axis_size(axis_name)
    n = 1
    for a in axis_name:
        n *= lax.axis_size(a)
    return n


def flat_group_index(axis_name: AxisNames) -> jax.Array:
    """This member's flat index in the (possibly composed) group: row-major
    over the axis tuple, matching how ``lax.psum_scatter``/``lax.ppermute``
    linearize a multi-axis group — THE strip-owner convention every
    collective backend must share."""
    if isinstance(axis_name, str):
        return lax.axis_index(axis_name)
    idx = jnp.zeros((), jnp.int32)
    for a in axis_name:
        idx = idx * lax.axis_size(a) + lax.axis_index(a)
    return idx


def part_reduce(x: jax.Array, axis_name: AxisNames, dim: int = 0) -> jax.Array:
    """Reduce-scatter ``x`` (replicated-shape partial sums, one per member of
    ``axis_name``) into per-member strips along ``dim``.
    Paper Fig. 1 (MPI_Reduce_scatter)."""
    return lax.psum_scatter(x, axis_name, scatter_dimension=dim, tiled=True)


def part_broadcast(x: jax.Array, axis_name: AxisNames, dim: int = 0) -> jax.Array:
    """All-gather strips along ``dim`` so every group member holds the full
    tensor.  Paper Fig. 2 (MPI_Allgather)."""
    return lax.all_gather(x, axis_name, axis=dim, tiled=True)


def part_reduce_broadcast(x: jax.Array, axis_name: AxisNames,
                          dim: int = 0) -> jax.Array:
    """part_broadcast(part_reduce(x)) == psum(x); the strip round-trip is the
    bandwidth-optimal ring allreduce decomposition (2*(G-1)/G * bytes)."""
    return part_broadcast(part_reduce(x, axis_name, dim), axis_name, dim)


# ---------------------------------------------------------------------------
# Strip helpers for the distributed optimizer: arbitrary-shaped tensors are
# flattened and padded so every group member owns an equal 1-D strip.
# ---------------------------------------------------------------------------
def padded_size(n: int, group: int) -> int:
    return ((n + group - 1) // group) * group


def flatten_pad(x: jax.Array, group: int) -> jax.Array:
    flat = x.reshape(-1)
    pad = padded_size(flat.size, group) - flat.size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat


def unflatten(flat: jax.Array, shape: Sequence[int]) -> jax.Array:
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def strip_reduce(grad: jax.Array, axis_name: AxisNames) -> jax.Array:
    """part-reduce a gradient tensor into this member's 1-D strip
    (mean over the group, matching synchronous-SGD averaging)."""
    g = axis_size(axis_name)
    flat = flatten_pad(grad, g)
    return part_reduce(flat, axis_name, dim=0) / g


def strip_broadcast(strip: jax.Array, axis_name: AxisNames,
                    shape: Sequence[int]) -> jax.Array:
    """part-broadcast updated weight strips back to the full tensor."""
    return unflatten(part_broadcast(strip, axis_name, dim=0), shape)
