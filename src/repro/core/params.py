"""Parameter-spec system: one declaration yields init, logical axes, shapes.

Models declare trees of ``Spec`` leaves; ``init_tree`` materializes arrays,
``axes_tree``/``shape_tree`` extract the matching metadata pytrees consumed by
``core.sharding`` (so the param pytree and its sharding pytree can never drift
apart structurally).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "fan_in"          # fan_in | normal | zeros | ones | embed
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, Spec)


def init_tree(specs, key: jax.Array, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    arrs = []
    for k, s in zip(keys, leaves):
        if s.init == "zeros":
            a = jnp.zeros(s.shape, dtype)
        elif s.init == "ones":
            a = jnp.ones(s.shape, dtype)
        elif s.init == "embed":
            a = jax.random.normal(k, s.shape, dtype) * s.scale
        elif s.init == "normal":
            a = jax.random.normal(k, s.shape, dtype) * s.scale
        else:  # fan_in
            fan_in = s.shape[0] if len(s.shape) >= 2 else max(s.shape[0], 1)
            if len(s.shape) >= 3:  # (.., in, out) stacked weights
                fan_in = s.shape[-2]
            a = jax.random.normal(k, s.shape, dtype) * (
                s.scale / np.sqrt(fan_in))
        arrs.append(a)
    return jax.tree.unflatten(treedef, arrs)


def axes_tree(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def shape_tree(specs):
    return jax.tree.map(lambda s: s.shape, specs, is_leaf=_is_spec)


def abstract_tree(specs, dtype=jnp.float32):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs,
        is_leaf=_is_spec)


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
