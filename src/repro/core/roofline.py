"""Roofline-term extraction from compiled (post-GSPMD) HLO.

Three terms per (arch x shape x mesh), all in seconds-per-step per chip:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = sum over collectives of ring-model bytes / link_bw

``compiled.cost_analysis()`` reports per-device FLOPs/bytes (the module is
already SPMD-partitioned).  Collective bytes are NOT in cost_analysis, so we
parse the HLO text: for each all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction we take the result-shape bytes and
the replica-group size, and charge the bandwidth-optimal ring cost:

    all-gather      (n-1)/n * result_bytes          (result = full tensor)
    reduce-scatter  (n-1)/n * operand_bytes
    all-reduce      2*(n-1)/n * result_bytes
    all-to-all      (n-1)/n * result_bytes
    collective-permute  result_bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

from repro.configs.base import TPU_V5E, HardwareConfig

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(?P<dtype>[a-z0-9]+)\[(?P<dims>[0-9,]*)\][^=]*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = _DTYPE_BYTES.get(dtype, 4)
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)
    ring_bytes: float = 0.0      # link-traversal bytes after ring discount

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line:
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("dtype"), m.group("dims"))
        gm = _GROUPS_RE.search(line)
        group = int(gm.group(2)) if gm else 2
        frac = (group - 1) / group if group > 1 else 0.0
        if op == "all-reduce":
            ring = 2.0 * frac * nbytes
        elif op == "collective-permute":
            ring = float(nbytes)
        else:
            ring = frac * nbytes
        stats.bytes_by_kind[op] = stats.bytes_by_kind.get(op, 0) + nbytes
        stats.count_by_kind[op] = stats.count_by_kind.get(op, 0) + 1
        stats.ring_bytes += ring
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    hlo_flops_per_dev: float
    hlo_bytes_per_dev: float
    coll: CollectiveStats
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_total: float
    mem_per_dev_bytes: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops summed over chips): catches remat and
        redundant recompute (ratio << 1) or rematerialization-free lowering
        (ratio ~ 1)."""
        total_hlo = self.hlo_flops_per_dev * self.n_devices
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline-implied step time."""
        t = self.step_time_s
        if not t:
            return 0.0
        return self.model_flops_total / (
            self.n_devices * 197e12 * t)

    def row(self) -> dict:
        return dict(
            arch=self.arch, shape=self.shape, mesh=self.mesh,
            devices=self.n_devices,
            flops_per_dev=self.hlo_flops_per_dev,
            bytes_per_dev=self.hlo_bytes_per_dev,
            coll_bytes=self.coll.total_bytes,
            coll_ring_bytes=self.coll.ring_bytes,
            coll_counts=dict(self.coll.count_by_kind),
            compute_s=self.compute_s, memory_s=self.memory_s,
            collective_s=self.collective_s, dominant=self.dominant,
            model_flops=self.model_flops_total,
            useful_ratio=self.useful_flops_ratio,
            mem_per_dev_gb=self.mem_per_dev_bytes / 2**30,
            mfu=self.mfu,
        )


def analyze(arch: str, shape: str, mesh_desc: str, n_devices: int,
            cost: Dict[str, float], hlo_text: str,
            model_flops_total: float,
            mem_per_dev_bytes: float = 0.0,
            hw: HardwareConfig = TPU_V5E) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(hlo_text)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_desc, n_devices=n_devices,
        hlo_flops_per_dev=flops, hlo_bytes_per_dev=nbytes, coll=coll,
        compute_s=flops / hw.peak_flops,
        memory_s=nbytes / hw.mem_bw,
        collective_s=coll.ring_bytes / hw.link_bw,
        model_flops_total=model_flops_total,
        mem_per_dev_bytes=mem_per_dev_bytes,
    )
