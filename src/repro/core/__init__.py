"""Core of the PCL-DNN reproduction: the paper's balance equations (§3),
blocking solver (§2.2), part-reduce/part-broadcast primitives (§3.4), the
hybrid-parallel planner (§3.3), logical-axis sharding, and the roofline
analyzer used by the dry-run."""
from repro.core import (  # noqa: F401
    balance,
    blocking,
    collectives,
    hybrid,
    params,
    roofline,
    sharding,
)
