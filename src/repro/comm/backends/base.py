"""The ``CollectiveBackend`` protocol — see the package docstring for the
full contract (strip ownership, wire-dtype semantics, shard_map context)."""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax

from repro.core.collectives import AxisNames


@runtime_checkable
class CollectiveBackend(Protocol):
    """One implementation of the paper's three group collectives, called
    INSIDE ``jax.shard_map`` over ``axis_name`` (a mesh axis or tuple).

    name:            registry id (``COLLECTIVE_BACKENDS``); CLI-visible.
    part_reduce:     reduce replicated-shape partials over the group,
                     scatter per-member strips along ``dim`` — flat group
                     member i (``collectives.flat_group_index``) receives
                     fully-reduced chunk i.
    part_broadcast:  exact inverse on strips — every member ends with the
                     full tensor, chunks in owner order along ``dim``.
    psum:            full all-reduce (``part_broadcast(part_reduce(x))``
                     up to layout).  Not yet on a training hot path — the
                     train steps' scalar loss/grad-norm reductions still
                     call ``lax.psum`` directly; it completes the contract
                     (equivalence tests pin it, and the async/stale-sync
                     ROADMAP modes need a backend all-reduce).

    All three operate on the dtype they are handed and return it unchanged
    (wire-dtype casts live in ``repro.comm.schedule``) — EXCEPT when a
    compressed wire format is bound: a backend may implement
    ``bind_wire_format(wire_format, topk_ratio) -> backend`` (optional —
    the schedule layer probes it with ``getattr``), and with ``"int8"`` /
    ``"topk"`` bound its ``part_reduce`` owns the encode/decode, takes f32
    and returns f32 strips (the lossy arithmetic IS the wire contract
    then; ``part_broadcast`` stays dense and dtype-transparent — weights
    are never compressed).  A backend may restrict ``dim``/rank to the
    schedules' canonical 1-D fusion-buffer form — raise
    ``NotImplementedError`` for shapes outside its contract.
    """
    name: str

    def part_reduce(self, x: jax.Array, axis_name: AxisNames,
                    dim: int = 0) -> jax.Array:
        ...

    def part_broadcast(self, x: jax.Array, axis_name: AxisNames,
                       dim: int = 0) -> jax.Array:
        ...

    def psum(self, x: jax.Array, axis_name: AxisNames) -> jax.Array:
        ...
