"""The paper's §3.4 ring, explicitly: neighbor exchange via ``lax.ppermute``
(one hop to the right per step — XLA's ICI neighbor DMA on TPU) with the
per-hop chunk combine in a Pallas kernel (``kernels.ring.ring_hop_accum``).

Schedule (identical to the stacked ``kernels.ring`` kernels, whose
interpret-mode validation pins it against the jnp oracles):

    reduce-scatter   member p sends its local chunk (p-1)%G first; at step
                     s it receives the partial of chunk (p-2-s)%G, adds its
                     own contribution (the Pallas hop kernel) and forwards.
                     After G-1 hops the fully-reduced chunk p sits on
                     member p — the ``lax.psum_scatter(tiled=True)`` owner
                     convention, so this backend and ``LaxBackend`` are
                     drop-in interchangeable.
    all-gather       member p's strip travels the ring; at step s the strip
                     of owner (p-1-s)%G arrives and is placed (pure data
                     movement — no kernel needed).

Costs 2*(G-1) messages of ``size/G`` like the lax ring, but with the hop
pipeline under kernel control: ``core.balance.RING_BACKEND_MODELS`` carries
this backend's latency/bandwidth constants (lower per-message dispatch
latency, a small per-hop rotation bubble) for the predicted-vs-measured
rows of ``benchmarks/comm_bucket_sweep.py``.

Operates on the schedules' canonical 1-D fusion buffers (``dim == 0``);
buffer sizes are strip multiples by construction (``repro.comm.bucketer``
pads every bucket to the group size).  On CPU the hop kernel runs in
interpret mode (auto-detected), which is what the equivalence tests
exercise.  The COMPILED Mosaic path (interpret=False, auto-selected on
TPU) has not been exercised — this container is CPU-only — and chunk
sizes here are arbitrary (padded_size/G), not lane-aligned; first TPU
bring-up should expect to pad hop blocks to (8, 128) tiles (tracked in
ROADMAP next to the remote-DMA ring).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.collectives import AxisNames, axis_size, flat_group_index, flatten_pad, unflatten
from repro.kernels.ring import ring_hop_accum


def _ring_perm(G: int) -> List[Tuple[int, int]]:
    return [(i, (i + 1) % G) for i in range(G)]


@dataclass(frozen=True)
class PallasRingBackend:
    """``interpret=None`` auto-selects Pallas interpret mode off-TPU."""
    name: str = "pallas-ring"
    interpret: Optional[bool] = None

    def _check(self, x: jax.Array, dim: int) -> None:
        if dim != 0 or x.ndim != 1:
            raise NotImplementedError(
                "PallasRingBackend implements the schedules' canonical 1-D "
                f"fusion-buffer form (dim=0); got dim={dim}, "
                f"shape={x.shape}. Flatten first (see collectives."
                "flatten_pad) or use LaxBackend.")

    def part_reduce(self, x: jax.Array, axis_name: AxisNames,
                    dim: int = 0) -> jax.Array:
        self._check(x, dim)
        G = axis_size(axis_name)
        if G == 1:
            return x
        if x.size % G:
            raise ValueError(
                f"buffer size {x.size} not a strip multiple of group {G}")
        p = flat_group_index(axis_name)
        chunks = x.reshape(G, x.size // G)
        perm = _ring_perm(G)
        send = chunks[jnp.mod(p - 1, G)]
        for s in range(G - 1):
            recv = lax.ppermute(send, axis_name, perm=perm)
            c = jnp.mod(p - 2 - s, G)
            send = ring_hop_accum(chunks, recv, c, interpret=self.interpret)
        return send

    def part_broadcast(self, x: jax.Array, axis_name: AxisNames,
                       dim: int = 0) -> jax.Array:
        self._check(x, dim)
        G = axis_size(axis_name)
        if G == 1:
            return x
        p = flat_group_index(axis_name)
        perm = _ring_perm(G)
        out = jnp.zeros((G, x.size), x.dtype).at[p].set(x)
        send = x
        for s in range(G - 1):
            recv = lax.ppermute(send, axis_name, perm=perm)
            out = out.at[jnp.mod(p - 1 - s, G)].set(recv)
            send = recv
        return out.reshape(G * x.size)

    def psum(self, x: jax.Array, axis_name: AxisNames) -> jax.Array:
        G = axis_size(axis_name)
        if G == 1:
            return x
        flat = flatten_pad(x, G)
        strips = self.part_reduce(flat, axis_name)
        return unflatten(self.part_broadcast(strips, axis_name), x.shape)
