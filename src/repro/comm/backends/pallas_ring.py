"""The paper's §3.4 ring, explicitly: neighbor exchange via ``lax.ppermute``
(one hop to the right per step — XLA's ICI neighbor DMA on TPU) with the
per-hop chunk combine in a Pallas kernel (``kernels.ring.ring_hop_accum``).

Schedule (identical to the stacked ``kernels.ring`` kernels, whose
interpret-mode validation pins it against the jnp oracles):

    reduce-scatter   member p sends its local chunk (p-1)%G first; at step
                     s it receives the partial of chunk (p-2-s)%G, adds its
                     own contribution (the Pallas hop kernel) and forwards.
                     After G-1 hops the fully-reduced chunk p sits on
                     member p — the ``lax.psum_scatter(tiled=True)`` owner
                     convention, so this backend and ``LaxBackend`` are
                     drop-in interchangeable.
    all-gather       member p's strip travels the ring; at step s the strip
                     of owner (p-1-s)%G arrives and is placed (pure data
                     movement — no kernel needed).

Costs 2*(G-1) messages of ``size/G`` like the lax ring, but with the hop
pipeline under kernel control: ``core.balance.RING_BACKEND_MODELS`` carries
this backend's latency/bandwidth constants (lower per-message dispatch
latency, a small per-hop rotation bubble) for the predicted-vs-measured
rows of ``benchmarks/comm_bucket_sweep.py``.

Operates on the schedules' canonical 1-D fusion buffers (``dim == 0``);
buffer sizes are strip multiples by construction (``repro.comm.bucketer``
pads every bucket to the group size).  On CPU the hop kernel runs in
interpret mode (auto-detected), which is what the equivalence tests
exercise.  The COMPILED Mosaic path (interpret=False, auto-selected on
TPU) has not been exercised — this container is CPU-only — and chunk
sizes here are arbitrary (padded_size/G), not lane-aligned; first TPU
bring-up should expect to pad hop blocks to (8, 128) tiles (tracked in
ROADMAP next to the remote-DMA ring).

**Compressed wire formats** (``wire_format``, bound by the schedule layer
via ``bind_wire_format``): ``"int8"`` replaces the hop combine with
``kernels.ring.ring_hop_int8`` — the ppermute moves (int8 message, f32
scale) instead of a dense f32 chunk, each hop dequantizes + accumulates in
f32 + re-quantizes fresh inside the kernel; ``"topk"`` moves (values,
indices) messages, the hop scatter-adds them dense
(``kernels.ring.ring_hop_topk``) and re-selects top-k before forwarding
(the final hop keeps the dense accumulator).  The part-broadcast of
updated weights is NEVER compressed — lossy weights would break the
replicated-params invariant the §3.4 update relies on.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.collectives import AxisNames, axis_size, flat_group_index, flatten_pad, unflatten
from repro.kernels.ring import int8_quantize, ring_hop_accum, ring_hop_int8, ring_hop_topk


def _ring_perm(G: int) -> List[Tuple[int, int]]:
    return [(i, (i + 1) % G) for i in range(G)]


def topk_chunk_k(n: int, ratio: float, floor: int = 1) -> int:
    """Entries kept per ``n``-element wire message at ``ratio`` (>= floor,
    <= n; the n cap wins — ``lax.top_k`` rejects k > n) — shared by both
    ring backends so their wire layouts agree."""
    return min(n, max(floor, math.ceil(ratio * n)))


def _topk_select(x: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """(values, int32 indices) of the k largest-|x| entries (jnp: selection
    is not a memory-bound combine, so it stays outside the Pallas hop)."""
    _, idx = lax.top_k(jnp.abs(x), k)
    return x[idx], idx.astype(jnp.int32)


@dataclass(frozen=True)
class PallasRingBackend:
    """``interpret=None`` auto-selects Pallas interpret mode off-TPU."""
    name: str = "pallas-ring"
    interpret: Optional[bool] = None
    wire_format: str = "fp32"
    topk_ratio: float = 0.05

    def bind_wire_format(self, wire_format: str,
                         topk_ratio: float) -> "PallasRingBackend":
        import dataclasses
        return dataclasses.replace(self, wire_format=wire_format,
                                   topk_ratio=topk_ratio)

    def _check(self, x: jax.Array, dim: int) -> None:
        if dim != 0 or x.ndim != 1:
            raise NotImplementedError(
                "PallasRingBackend implements the schedules' canonical 1-D "
                f"fusion-buffer form (dim=0); got dim={dim}, "
                f"shape={x.shape}. Flatten first (see collectives."
                "flatten_pad) or use LaxBackend.")

    def part_reduce(self, x: jax.Array, axis_name: AxisNames,
                    dim: int = 0) -> jax.Array:
        self._check(x, dim)
        G = axis_size(axis_name)
        if G == 1:
            return x
        if x.size % G:
            raise ValueError(
                f"buffer size {x.size} not a strip multiple of group {G}")
        p = flat_group_index(axis_name)
        chunks = x.reshape(G, x.size // G)
        perm = _ring_perm(G)
        if self.wire_format == "int8":
            return self._part_reduce_int8(chunks, axis_name, p, perm)
        if self.wire_format == "topk":
            return self._part_reduce_topk(chunks, axis_name, p, perm)
        send = chunks[jnp.mod(p - 1, G)]
        for s in range(G - 1):
            recv = lax.ppermute(send, axis_name, perm=perm)
            c = jnp.mod(p - 2 - s, G)
            send = ring_hop_accum(chunks, recv, c, interpret=self.interpret)
        return send

    def _part_reduce_int8(self, chunks, axis_name, p, perm) -> jax.Array:
        """The same ring with (int8, scale) wire messages; every combine is
        the fused dequantize-accumulate-requantize hop kernel."""
        G = chunks.shape[0]
        chunks = chunks.astype(jnp.float32)
        q, s = int8_quantize(chunks[jnp.mod(p - 1, G)],
                             interpret=self.interpret)
        for step in range(G - 1):
            qr = lax.ppermute(q, axis_name, perm=perm)
            sr = lax.ppermute(s, axis_name, perm=perm)
            c = jnp.mod(p - 2 - step, G)
            q, s = ring_hop_int8(chunks, qr, sr, c, interpret=self.interpret)
        # the owned strip leaves the wire once, at the very end
        return q.astype(jnp.float32) * s[0]

    def _part_reduce_topk(self, chunks, axis_name, p, perm) -> jax.Array:
        """The same ring with (values, indices) sparse messages; the hop
        kernel scatter-adds them dense, re-selection precedes each forward
        (never the final hop — the owned strip keeps the dense sum)."""
        G, n = chunks.shape
        chunks = chunks.astype(jnp.float32)
        k = topk_chunk_k(n, self.topk_ratio)
        vals, idx = _topk_select(chunks[jnp.mod(p - 1, G)], k)
        dense = chunks[jnp.mod(p - 1, G)]
        for step in range(G - 1):
            vr = lax.ppermute(vals, axis_name, perm=perm)
            ir = lax.ppermute(idx, axis_name, perm=perm)
            c = jnp.mod(p - 2 - step, G)
            dense = ring_hop_topk(chunks, vr, ir, c,
                                  interpret=self.interpret)
            if step < G - 2:
                vals, idx = _topk_select(dense, k)
        return dense

    def part_broadcast(self, x: jax.Array, axis_name: AxisNames,
                       dim: int = 0) -> jax.Array:
        self._check(x, dim)
        G = axis_size(axis_name)
        if G == 1:
            return x
        p = flat_group_index(axis_name)
        perm = _ring_perm(G)
        out = jnp.zeros((G, x.size), x.dtype).at[p].set(x)
        send = x
        for s in range(G - 1):
            recv = lax.ppermute(send, axis_name, perm=perm)
            out = out.at[jnp.mod(p - 1 - s, G)].set(recv)
            send = recv
        return out.reshape(G * x.size)

    def psum(self, x: jax.Array, axis_name: AxisNames) -> jax.Array:
        G = axis_size(axis_name)
        if G == 1:
            return x
        flat = flatten_pad(x, G)
        strips = self.part_reduce(flat, axis_name)
        return unflatten(self.part_broadcast(strips, axis_name), x.shape)
