"""Pluggable collective backends — the extension point behind the schedule
seam (``repro.comm.schedule``).

A backend is one implementation of the paper's group collectives
(part-reduce / part-broadcast / psum), called INSIDE ``jax.shard_map`` over
a mesh axis or axis tuple.  The schedules (``FlatSchedule`` /
``HierarchicalSchedule``) own everything else — bucket layout, wire-dtype
casts, the two-level pod composition — so a new backend only has to honor
the :class:`~repro.comm.backends.base.CollectiveBackend` contract:

**Strip ownership.**  ``part_reduce`` splits the buffer into G equal chunks
along ``dim`` and must deliver fully-reduced chunk i to the group member
whose flat index (``core.collectives.flat_group_index`` — row-major over
the axis tuple) is i.  ``part_broadcast`` is the exact inverse: chunks
reassembled in owner order.  This is the ``lax.psum_scatter(tiled=True)``
convention; the ZeRO-1 strip update slices params with the same index, so
a backend with a different owner mapping would silently corrupt training —
the equivalence tests (zero1 == serial per backend) pin it.

**Wire-dtype semantics.**  Backends are dtype-transparent: they reduce in
whatever dtype the schedule hands them (the "wire" arithmetic — a bf16
reduce accumulates in bf16 on the wire) and never cast.  The schedule
layer owns the fp32 accumulate after each stage and the always-fp32
cross-pod hop and weight broadcast.

**Compressed wire formats.**  ``CommConfig.wire_format in {"int8",
"topk"}`` is bound into a backend via its optional ``bind_wire_format``
method (``schedule.bind_wire_format`` probes with ``getattr`` — a backend
without it, e.g. gossip, only supports the dense formats and ``MODE_CAPS``
enforces that).  A compressed ``part_reduce`` takes f32 buffers and
returns f32 strips, owning quantize/dequantize internally: int8 moves
(int8 message, per-message f32 max-abs scale) pairs with f32 accumulation
per hop; topk moves (values, int32 indices) with per-hop re-selection.
``LaxBackend`` runs these as an explicit jnp ppermute ring (the
``kernels.ref`` oracle math — the fallback reference), ``PallasRingBackend``
fuses the combine into ``kernels/ring.py`` hop kernels.

**Shapes.**  The schedules only ever pass 1-D fusion buffers whose size is
a multiple of the group (``bucketer`` pads every bucket); a backend may
reject anything else with ``NotImplementedError`` (``PallasRingBackend``
does; ``LaxBackend`` is shape-general).

Selection is by name end-to-end: ``CommConfig(backend=...)`` →
``make_schedule`` → here.  ``HierarchicalSchedule`` takes one backend per
level, so e.g. the Pallas ring can run in-pod while the cross-pod hop
stays on lax (the default pairing).  Adding a backend — host NCCL/Gloo,
compressed wire formats — means one module here, a ``COLLECTIVE_BACKENDS``
entry, and per-backend constants in ``core.balance.RING_BACKEND_MODELS``;
every schedule, update builder, overlap hook, launcher flag and benchmark
picks it up.

Backends:

``lax`` (:class:`LaxBackend`, the default)
    ``jax.lax`` collectives — XLA's own ring/tree selection.  Bit-for-bit
    the seed behavior; ``core.collectives`` is its internals.
``pallas-ring`` (:class:`PallasRingBackend`)
    The paper's §3.4 ring explicitly: ``lax.ppermute`` neighbor exchange
    with the per-hop combine in a Pallas kernel (``kernels/ring.py``, whose
    stacked form is oracle-validated in interpret mode).
``gossip`` (:class:`GossipBackend`)
    GossipGraD partner exchange: one chunk-sized ``lax.ppermute`` message
    per step under the rotating pairing ``partner = (rank + step + 1) %
    world_size`` instead of the full ring reduction.  NOT a drop-in ring
    replacement — ``part_reduce`` delivers the rotating PAIR mean, a
    deliberate consistency-model change selected by ``parallel="gossip"``
    (``api.spec.MODE_CAPS`` rejects it under the synchronous modes).  Its
    partner rotation is step-scheduled: bind the train step with
    ``bind_step`` / ``schedule.bind_step``.
"""
from __future__ import annotations

from typing import Union

from repro.comm.backends.base import CollectiveBackend  # noqa: F401
from repro.comm.backends.gossip import GossipBackend
from repro.comm.backends.lax_backend import LaxBackend
from repro.comm.backends.pallas_ring import PallasRingBackend

COLLECTIVE_BACKENDS = ("lax", "pallas-ring", "gossip")

_FACTORIES = {"lax": LaxBackend, "pallas-ring": PallasRingBackend,
              "gossip": GossipBackend}


def get_backend(backend: Union[str, CollectiveBackend]) -> CollectiveBackend:
    """Resolve a backend name to an instance; instances pass through (so
    callers can hand in a pre-configured or third-party backend)."""
    if isinstance(backend, str):
        try:
            return _FACTORIES[backend]()
        except KeyError:
            raise ValueError(
                f"unknown collective backend {backend!r}; "
                f"known: {COLLECTIVE_BACKENDS}") from None
    return backend
