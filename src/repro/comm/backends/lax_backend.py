"""The reference backend: ``jax.lax`` collectives (XLA picks the wire
algorithm).  Reproduces the seed behavior bit-for-bit — it IS the seed
path, with ``core.collectives`` as its internals.

The compressed wire formats (``wire_format in {"int8", "topk"}``, bound by
the schedule layer via ``bind_wire_format``) have no dense ``psum_scatter``
equivalent, so for them this backend runs the SAME ring schedule as
``PallasRingBackend`` but with the per-hop combine as plain jnp — literally
the ``kernels.ref`` oracles — making it the jnp fallback path the Pallas
ring is equivalence-tested against.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.collectives import (
    AxisNames,
    axis_size,
    flat_group_index,
    part_broadcast,
    part_reduce,
)


@dataclass(frozen=True)
class LaxBackend:
    """``lax.psum_scatter`` / ``lax.all_gather`` / ``lax.psum`` — on TPU,
    XLA lowers these to the same bidirectional ICI ring the §3.4 cost model
    assumes (``core.balance.ring_collective_time(backend="lax")``)."""
    name: str = "lax"
    wire_format: str = "fp32"
    topk_ratio: float = 0.05

    def bind_wire_format(self, wire_format: str,
                         topk_ratio: float) -> "LaxBackend":
        return dataclasses.replace(self, wire_format=wire_format,
                                   topk_ratio=topk_ratio)

    def part_reduce(self, x: jax.Array, axis_name: AxisNames,
                    dim: int = 0) -> jax.Array:
        if self.wire_format in ("int8", "topk"):
            return self._compressed_part_reduce(x, axis_name, dim)
        return part_reduce(x, axis_name, dim)

    def part_broadcast(self, x: jax.Array, axis_name: AxisNames,
                       dim: int = 0) -> jax.Array:
        return part_broadcast(x, axis_name, dim)

    def psum(self, x: jax.Array, axis_name: AxisNames) -> jax.Array:
        return lax.psum(x, axis_name)

    def _compressed_part_reduce(self, x: jax.Array, axis_name: AxisNames,
                                dim: int) -> jax.Array:
        """The §3.4 ring schedule with compressed wire messages, hop math
        straight from the ``kernels.ref`` oracles (jnp, no Pallas)."""
        from repro.comm.backends.pallas_ring import topk_chunk_k
        from repro.kernels import ref as kref

        if dim != 0 or x.ndim != 1:
            raise NotImplementedError(
                "compressed wire formats operate on the schedules' "
                f"canonical 1-D fusion-buffer form (dim=0); got dim={dim}, "
                f"shape={x.shape}")
        G = axis_size(axis_name)
        if G == 1:
            return x
        if x.size % G:
            raise ValueError(
                f"buffer size {x.size} not a strip multiple of group {G}")
        p = flat_group_index(axis_name)
        chunks = x.reshape(G, x.size // G).astype(jnp.float32)
        perm = [(i, (i + 1) % G) for i in range(G)]
        if self.wire_format == "int8":
            q, s = kref.int8_quantize_ref(chunks[jnp.mod(p - 1, G)])
            for step in range(G - 1):
                qr = lax.ppermute(q, axis_name, perm=perm)
                sr = lax.ppermute(s, axis_name, perm=perm)
                c = jnp.mod(p - 2 - step, G)
                q, s = kref.ring_hop_int8_ref(chunks, qr, sr, c)
            return kref.int8_dequantize_ref(q, s)
        n = chunks.shape[1]
        k = topk_chunk_k(n, self.topk_ratio)
        vals, idx = kref.topk_select_ref(chunks[jnp.mod(p - 1, G)], k)
        dense = chunks[jnp.mod(p - 1, G)]
        for step in range(G - 1):
            vr = lax.ppermute(vals, axis_name, perm=perm)
            ir = lax.ppermute(idx, axis_name, perm=perm)
            c = jnp.mod(p - 2 - step, G)
            dense = kref.ring_hop_topk_ref(chunks, vr, ir, c)
            if step < G - 2:
                vals, idx = kref.topk_select_ref(dense, k)
        return dense
