"""The reference backend: ``jax.lax`` collectives (XLA picks the wire
algorithm).  Reproduces the seed behavior bit-for-bit — it IS the seed
path, with ``core.collectives`` as its internals."""
from __future__ import annotations

from dataclasses import dataclass

import jax
from jax import lax

from repro.core.collectives import AxisNames, part_broadcast, part_reduce


@dataclass(frozen=True)
class LaxBackend:
    """``lax.psum_scatter`` / ``lax.all_gather`` / ``lax.psum`` — on TPU,
    XLA lowers these to the same bidirectional ICI ring the §3.4 cost model
    assumes (``core.balance.ring_collective_time(backend="lax")``)."""
    name: str = "lax"

    def part_reduce(self, x: jax.Array, axis_name: AxisNames,
                    dim: int = 0) -> jax.Array:
        return part_reduce(x, axis_name, dim)

    def part_broadcast(self, x: jax.Array, axis_name: AxisNames,
                       dim: int = 0) -> jax.Array:
        return part_broadcast(x, axis_name, dim)

    def psum(self, x: jax.Array, axis_name: AxisNames) -> jax.Array:
        return lax.psum(x, axis_name)
