"""GossipGraD-style partner exchange as a :class:`CollectiveBackend`.

The synchronous ring fully reduces every bucket each step: 2*(G-1)
messages, every member sees every other member's gradient.  GossipGraD
(Daily et al.; see SNIPPETS.md §3) replaces the full reduction with ONE
partner exchange per step under a rotating pairing,

    partner(rank, step) = (rank + step + 1) % world_size

so each member mixes gradients with a single peer per step and the
rotation walks the whole group every G-1 steps — the mixing matrix of any
one step is doubly stochastic (each row averages two members; each member
sends to exactly one peer), and the rotation makes the product of G-1
consecutive matrices fully dense, which is what the gossip convergence
analyses lean on.

Composed with the ZeRO-1 strip update the consistency story is clean:
``part_reduce`` hands strip owner i the PAIR mean (members i and
i - shift) instead of the group mean, the strip optimizer runs on it, and
``part_broadcast`` all-gathers the updated strips exactly as in the
synchronous schedule — so params (and optimizer strips) stay bit-identical
across members every step.  What changes is only the gradient estimator:
each strip's update uses a rotating 2-member subset mean — unbiased, with
higher variance that the rotation mixes away over steps.  Checkpoint
layout is therefore identical to zero1's (the interop tests pin this).

Wire cost per bucket: the exchange is ONE chunk-sized message per member
(each member sends the chunk its downstream partner owns), i.e.
``SWlat + (n/G)/BW`` on the reduce side versus the ring's
``(G-1)*(SWlat + (n/G)/BW)`` — the latency win GossipGraD exists for.  The
strip all-gather is unchanged (params must stay replicated).
``core.balance.gossip_exchange_time`` is the model.

Scaling convention: the schedules divide reduce output by G for the
synchronous mean, so ``part_reduce`` returns the pair SUM scaled by G/2 —
the caller's /G then yields the pair mean.  This composes unchanged
through ``HierarchicalSchedule`` (in-pod pair sum * G_in/2, cross-pod sum
over G_out pods, /G total = mean of the 2*G_out mixed members).

The partner shift depends on the STEP, which is a traced scalar inside the
train step while ``lax.ppermute`` needs a static permutation — so the
exchange branches over the G-1 possible shifts with ``lax.switch``
(shift = 1 + step % (G-1); G == 1 degenerates to the identity).  Bind the
step with :meth:`GossipBackend.bind_step` (``comm.schedule.bind_step``
does it for every step-scheduled backend).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.collectives import (
    AxisNames,
    axis_size,
    flat_group_index,
    flatten_pad,
    part_broadcast,
    unflatten,
)


def _shift_perm(G: int, s: int) -> List[Tuple[int, int]]:
    """Member i sends to (i + s) % G — so i RECEIVES from (i - s) % G."""
    return [(i, (i + s) % G) for i in range(G)]


@dataclass(frozen=True)
class GossipBackend:
    """``step`` selects the partner rotation; 0 (the default) pairs each
    member with its +1 neighbor.  ``bind_step`` rebinds per train step —
    a traced scalar is fine (the shift dispatch is a ``lax.switch``)."""
    name: str = "gossip"
    step: Any = 0

    def bind_step(self, step) -> "GossipBackend":
        return dataclasses.replace(self, step=step)

    def _check(self, x: jax.Array, dim: int) -> None:
        if dim != 0 or x.ndim != 1:
            raise NotImplementedError(
                "GossipBackend implements the schedules' canonical 1-D "
                f"fusion-buffer form (dim=0); got dim={dim}, "
                f"shape={x.shape}. Flatten first (see collectives."
                "flatten_pad) or use LaxBackend.")

    def _pair_chunk(self, chunks: jax.Array, axis_name: AxisNames,
                    G: int) -> jax.Array:
        """This member's chunk of (own + partner's) buffer: each member
        sends the one chunk its send-partner owns, receives the chunk IT
        owns from its receive-partner — chunk-sized messages only."""
        p = flat_group_index(axis_name)

        def shift_branch(s):
            def branch(ch):
                send = ch[jnp.mod(p + s, G)]
                return lax.ppermute(send, axis_name,
                                    perm=_shift_perm(G, s))
            return branch

        idx = jnp.mod(jnp.asarray(self.step, jnp.int32), G - 1)
        recv = lax.switch(idx, [shift_branch(s) for s in range(1, G)],
                          chunks)
        return chunks[p] + recv

    def part_reduce(self, x: jax.Array, axis_name: AxisNames,
                    dim: int = 0) -> jax.Array:
        self._check(x, dim)
        G = axis_size(axis_name)
        if G == 1:
            return x
        if x.size % G:
            raise ValueError(
                f"buffer size {x.size} not a strip multiple of group {G}")
        chunks = x.reshape(G, x.size // G)
        # pair sum scaled so the schedule-level /G yields the pair MEAN
        return self._pair_chunk(chunks, axis_name, G) * (G / 2.0)

    def part_broadcast(self, x: jax.Array, axis_name: AxisNames,
                       dim: int = 0) -> jax.Array:
        # updated strips all-gather exactly as in the synchronous schedule:
        # params stay replicated, only the gradient mixing is partial
        return part_broadcast(x, axis_name, dim)

    def psum(self, x: jax.Array, axis_name: AxisNames) -> jax.Array:
        """The gossip 'all-reduce': part_broadcast(part_reduce(x)) — every
        member ends with the same strip-wise pair-mixed sum."""
        G = axis_size(axis_name)
        if G == 1:
            return x
        flat = flatten_pad(x, G)
        strips = self.part_reduce(flat, axis_name)
        return unflatten(self.part_broadcast(strips, axis_name), x.shape)
