"""Gradient bucketing: coalesce a tensor tree into fixed-byte fusion buffers.

The plan is computed host-side from static leaf shapes (greedy first-fit in
tree order, like PyTorch DDP's gradient buckets), so every offset below is a
Python int and ``pack``/``unpack`` trace to pure reshape/concat/slice ops —
no dynamic shapes inside jit.  Each bucket is padded to a multiple of the
group size ``G`` so that one ``part_reduce``/``part_broadcast`` pair moves
the whole bucket and every member owns an equal 1-D strip of it (the paper's
§3.4 strip scheme, applied per bucket instead of per tensor).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.collectives import padded_size

#: supported gradient wire formats (``CommConfig.wire_format``): how the
#: part-reduce encodes bytes on the wire.  ``fp32``/``bf16`` are the dense
#: dtypes the schedule always supported; ``int8`` quantizes each message
#: against a per-message max-abs scale (fp32 accumulate per hop, so error
#: does not compound across the G-1 hops); ``topk`` sends (values, indices)
#: of the largest-|g| entries with a local error-feedback residual carried
#: in strip state (``optim.dist.make_topk_ef_update``).
WIRE_FORMATS = ("fp32", "bf16", "int8", "topk")

#: wire_format implied by each reduce_dtype when ``wire_format`` is unset
_DTYPE_FORMATS = {"float32": "fp32", "bfloat16": "bf16"}


@dataclass(frozen=True)
class CommConfig:
    """Knobs of the gradient-communication subsystem (see package docstring
    for the paper-section mapping).

    bucket_bytes:  target fusion-buffer size.  ``<= 0`` disables fusion
                   (one bucket per tensor — the legacy per-tensor schedule).
                   A single tensor larger than ``bucket_bytes`` gets a
                   bucket of its own (buckets never split a tensor).
    reduce_dtype:  wire dtype of the gradient part-reduce, ``"float32"`` or
                   ``"bfloat16"``.  fp32 accumulate after every stage.
    hierarchical:  use the two-level in-pod + cross-pod schedule when the
                   data axes are a 2-tuple like ``("pod", "data")``.
    overlap:       issue each bucket's part-reduce inside the BACKWARD pass,
                   the moment the bucket's last contributing leaf gradient
                   materializes (the paper's §3.1 bubble schedule), instead
                   of reducing the whole tree after ``value_and_grad``
                   returns.  See :mod:`repro.comm.overlap`.
    backend:       collective backend name (``repro.comm.backends``):
                   ``"lax"`` (XLA collectives — the seed behavior) or
                   ``"pallas-ring"`` (the paper's explicit §3.4 ring with
                   the per-hop combine in a Pallas kernel).  Under the
                   hierarchical schedule this drives the IN-POD level.
    cross_backend: collective backend for the CROSS-POD hop of the
                   hierarchical schedule (ignored by the flat one).
                   Defaults to ``"lax"`` — on a real cluster the pod axis
                   is the process boundary (``launch.mesh.make_cluster_mesh``)
                   and lax lowers to the runtime's cross-host collectives
                   (gloo on CPU), which is the backend slot the multi-host
                   subsystem fills.
    wire_format:   bytes-on-wire encoding of the gradient part-reduce, one
                   of :data:`WIRE_FORMATS`.  ``None`` (the default) derives
                   it from ``reduce_dtype`` (``float32 -> "fp32"``,
                   ``bfloat16 -> "bf16"``) so existing configs keep their
                   meaning.  ``"int8"``/``"topk"`` compress the reduce side
                   only — the part-broadcast of updated weights is always
                   full precision.
    topk_ratio:    fraction of bucket elements kept per message when
                   ``wire_format == "topk"`` (0 < ratio <= 1).
    """
    bucket_bytes: int = 4 * 2**20
    reduce_dtype: str = "float32"
    hierarchical: bool = False
    overlap: bool = False
    backend: str = "lax"
    cross_backend: str = "lax"
    wire_format: Optional[str] = None
    topk_ratio: float = 0.05

    def __post_init__(self):
        # real exceptions, not asserts: config validation must survive -O
        if self.reduce_dtype not in _DTYPE_FORMATS:
            raise ValueError(
                f"reduce_dtype must be one of "
                f"{tuple(sorted(_DTYPE_FORMATS))}, got {self.reduce_dtype!r}")
        if self.wire_format is None:
            object.__setattr__(
                self, "wire_format", _DTYPE_FORMATS[self.reduce_dtype])
        if self.wire_format not in WIRE_FORMATS:
            raise ValueError(
                f"wire_format must be one of {WIRE_FORMATS}, "
                f"got {self.wire_format!r}")
        if (self.reduce_dtype == "bfloat16"
                and self.wire_format != "bf16"):
            raise ValueError(
                f"reduce_dtype='bfloat16' implies wire_format='bf16'; "
                f"got conflicting wire_format={self.wire_format!r}")
        if not (0.0 < self.topk_ratio <= 1.0):
            raise ValueError(
                f"topk_ratio must be in (0, 1], got {self.topk_ratio!r}")
        from repro.comm.backends import COLLECTIVE_BACKENDS
        for fld in ("backend", "cross_backend"):
            if getattr(self, fld) not in COLLECTIVE_BACKENDS:
                raise ValueError(
                    f"{fld} must be one of {COLLECTIVE_BACKENDS}, "
                    f"got {getattr(self, fld)!r}")

    @property
    def wire_dtype(self):
        """Dense dtype buffers are cast to before ``part_reduce`` — the
        compressed formats quantize from fp32 inside the backend, so only
        ``bf16`` changes the handed-off dtype."""
        return jnp.bfloat16 if self.wire_format == "bf16" else jnp.float32

    @property
    def compressed(self) -> bool:
        """Whether the reduce wire uses a non-dense encoding."""
        return self.wire_format in ("int8", "topk")


@dataclass(frozen=True)
class LeafSlot:
    """Where one tree leaf lives inside its bucket's packed buffer."""
    index: int                 # leaf position in the flattened tree
    shape: Tuple[int, ...]
    size: int                  # number of elements (== prod(shape))
    offset: int                # element offset inside the bucket buffer
    dtype: Optional[str] = None  # leaf dtype name; None = unknown (shape-
                                 # only planning, e.g. the sweep benchmark)


@dataclass(frozen=True)
class Bucket:
    slots: Tuple[LeafSlot, ...]
    size: int                  # payload elements (sum of slot sizes)
    padded_size: int           # size rounded up to a multiple of the group

    @property
    def trigger_index(self) -> int:
        """The leaf (flat tree index) whose gradient completes this bucket.
        Backprop materializes leaf gradients in REVERSE tree order (the last
        layer's weight gradient first), so the bucket becomes reducible when
        its EARLIEST tree-order leaf — the latest in backprop — arrives."""
        return min(s.index for s in self.slots)


@dataclass(frozen=True)
class BucketPlan:
    buckets: Tuple[Bucket, ...]
    group: int                 # G: members of the part-reduce group
    n_leaves: int

    @property
    def n_collectives(self) -> int:
        """Collective pairs per step — the quantity bucketing shrinks from
        O(#tensors) to O(total_bytes / bucket_bytes)."""
        return len(self.buckets)

    @property
    def backprop_order(self) -> Tuple[int, ...]:
        """Bucket indices in backprop readiness order — the order the §3.1
        overlap schedule issues the part-reduces.  Descending trigger leaf:
        the bucket holding the LAST tree-order (= first materialized) leaves
        is ready first.  Ties (one leaf feeding two per-tensor buckets can't
        happen, but equal triggers under a custom leaf order can) break
        toward the later bucket — the one ordering rule, defined in
        ``core.balance.issue_order``."""
        from repro.core.balance import issue_order
        return issue_order(tuple(b.trigger_index for b in self.buckets))

    @property
    def total_elements(self) -> int:
        return sum(b.size for b in self.buckets)

    @property
    def total_padded(self) -> int:
        return sum(b.padded_size for b in self.buckets)


def plan_buckets(tree: Any, group: int, bucket_bytes: int,
                 itemsize: int = 4) -> BucketPlan:
    """Greedy first-fit bucket assignment over ``tree``'s leaves in tree
    order.  Shapes only — no array data is touched.  Buckets never mix
    dtypes (concatenating mixed leaves would silently promote them), so a
    dtype change in tree order also closes the current bucket; ``itemsize``
    is only the fallback for shape-only leaves with no ``.dtype``."""
    leaves = jax.tree.leaves(tree)
    cap = math.inf if bucket_bytes is None else bucket_bytes
    buckets: List[Bucket] = []
    slots: List[LeafSlot] = []
    fill = fill_bytes = 0
    cur_dtype: Optional[str] = None

    def close():
        nonlocal slots, fill, fill_bytes
        if slots:
            buckets.append(Bucket(tuple(slots), fill,
                                  padded_size(fill, group)))
        slots, fill, fill_bytes = [], 0, 0

    for i, leaf in enumerate(leaves):
        size = int(leaf.size) if hasattr(leaf, "size") else int(
            math.prod(leaf.shape))
        dt = getattr(leaf, "dtype", None)
        dt_name = None if dt is None else np.dtype(dt).name
        isz = itemsize if dt is None else np.dtype(dt).itemsize
        nbytes = size * isz
        if cap <= 0:
            # fusion disabled: per-tensor buckets (legacy schedule)
            buckets.append(Bucket(
                (LeafSlot(i, tuple(leaf.shape), size, 0, dt_name),), size,
                padded_size(size, group)))
            continue
        if slots and (fill_bytes + nbytes > cap or dt_name != cur_dtype):
            close()
        cur_dtype = dt_name
        slots.append(LeafSlot(i, tuple(leaf.shape), size, fill, dt_name))
        fill += size
        fill_bytes += nbytes
        if fill_bytes >= cap:
            close()
    close()
    return BucketPlan(tuple(buckets), group, len(leaves))


def pack_bucket(flat_leaves: Sequence[jax.Array], bucket: Bucket) -> jax.Array:
    """Concatenate the bucket's leaves into one padded 1-D fusion buffer."""
    parts = [flat_leaves[s.index].reshape(-1) for s in bucket.slots]
    pad = bucket.padded_size - bucket.size
    if pad:
        parts.append(jnp.zeros((pad,), parts[0].dtype))
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def unpack_buckets(buffers: Sequence[jax.Array],
                   plan: BucketPlan) -> List[jax.Array]:
    """Slice the fusion buffers back into leaves (tree order), restoring
    each leaf's recorded dtype (the optimizer may have promoted the bucket
    buffer, e.g. bf16 params updated against fp32 gradient strips)."""
    out: List[jax.Array] = [None] * plan.n_leaves
    for buf, bucket in zip(buffers, plan.buckets):
        for s in bucket.slots:
            leaf = jax.lax.slice(
                buf, (s.offset,), (s.offset + s.size,)).reshape(s.shape)
            if s.dtype is not None and leaf.dtype != np.dtype(s.dtype):
                leaf = leaf.astype(s.dtype)
            out[s.index] = leaf
    return out
