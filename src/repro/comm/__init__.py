"""Gradient-communication subsystem: bucketed, hierarchical part-reduce /
part-broadcast (paper §3.2–§3.4).

The paper's §3.4 schedule is one part-reduce (MPI_Reduce_scatter) before the
optimizer and one part-broadcast (MPI_Allgather) after it.  Issued per tensor
— as ``optim/dist.py`` originally did — every small conv/bias tensor pays the
full per-message software latency (the paper's SWlat, §3.2 Eq. for comms_sys),
which is exactly the latency-bound regime the §3.2 balance model says kills
scaling for VGG-A's many small tensors.  This package fixes that with three
knobs, all carried by :class:`~repro.comm.bucketer.CommConfig`:

``bucket_bytes`` (paper §3.2, the latency term)
    The flattened gradient tree is coalesced into fixed-byte fusion buffers
    ("buckets"); each bucket is ONE part-reduce/part-broadcast pair instead of
    one pair per tensor, so the per-step collective count drops from
    O(#tensors) to O(total_bytes / bucket_bytes).  The optimal size trades
    per-message latency against pipeline fill and is predicted by
    ``core.balance.optimal_bucket_bytes`` (sqrt(B · SWlat · comms_sys · G)).

``reduce_dtype`` (paper §3.1, the size_data factor)
    Wire dtype for the gradient reduction: ``"float32"`` (paper baseline,
    size_data=4) or ``"bfloat16"`` (halves every comm term in the §3.1
    balance equations).  Gradients are cast back to fp32 immediately after
    each collective stage, so the optimizer always accumulates in fp32; the
    updated-weight part-broadcast is always fp32 (weights are never
    quantized on the wire).

``hierarchical`` (paper §3.3/§3.4 group composition)
    Two-level schedule for ``("pod", "data")``-style axis tuples: an in-pod
    reduce-scatter followed by a cross-pod hop on the 1/G_pod strips (and the
    inverse all-gathers for part-broadcast).  This is the paper's composition
    of node groups — the cross-pod link moves strip_bytes instead of joining
    one flat ring that spans pods — and the cross-pod stage always
    accumulates in fp32 even when the in-pod wire dtype is bf16.

``overlap`` (paper §3.1, the bubble schedule)
    Issue each bucket's part-reduce INSIDE the backward pass — at the point
    where the bucket's last contributing leaf gradient materializes — via
    ``jax.custom_vjp`` comm hooks, instead of reducing the whole tree after
    ``value_and_grad`` returns.  Only each transfer's "bubble" (the §3.1
    closed form, ``core.balance.bucket_bubble_schedule``) stays exposed.

``wire_format`` (post-paper: compressed bytes-on-wire)
    How the gradient part-reduce encodes its messages: ``"fp32"`` /
    ``"bf16"`` (the dense dtypes above), ``"int8"`` (per-message max-abs
    scales, f32 accumulation per hop so quantization error stays additive
    across the G-1 hops), or ``"topk"`` ((values, indices) sparse messages
    with a local error-feedback residual carried in strip state —
    ``optim.dist.make_topk_ef_update``).  Compression is fused into the
    ring hop kernels (``kernels/ring.py``) behind the backend seam; the
    weight part-broadcast is never compressed.  See
    :data:`~repro.comm.bucketer.WIRE_FORMATS`.

``backend`` (paper §3.4, the collective implementation)
    Which wire implementation the schedules drive: ``"lax"`` (XLA's
    collectives, the seed behavior) or ``"pallas-ring"`` (the paper's ring
    explicitly — ``lax.ppermute`` neighbor exchange with the per-hop chunk
    combine in a Pallas kernel).  Under the hierarchical schedule the
    backend applies in-pod and the cross-pod hop stays on lax.  The
    extension-point contract lives in :mod:`repro.comm.backends`.

Layout: :mod:`repro.comm.bucketer` owns the static bucket plan and the
pack/unpack of leaves into fusion buffers; :mod:`repro.comm.schedule` owns
the collective schedules (flat and hierarchical) that run inside
``jax.shard_map``; :mod:`repro.comm.backends` owns the wire collectives
those schedules drive; :mod:`repro.comm.overlap` owns the backprop-overlap
hooks and the bucket→layer readiness metadata.  The consumers are
``optim.dist.make_distributed_update`` / ``make_overlapped_update`` and the
explicit ZeRO-1 train steps (``train.make_train_step(dist_update=...)`` and
``train.make_overlapped_train_step``).
"""
from repro.comm.backends import (  # noqa: F401
    COLLECTIVE_BACKENDS,
    CollectiveBackend,
    LaxBackend,
    PallasRingBackend,
    get_backend,
)
from repro.comm.bucketer import (  # noqa: F401
    WIRE_FORMATS,
    Bucket,
    BucketPlan,
    CommConfig,
    LeafSlot,
    pack_bucket,
    plan_buckets,
    unpack_buckets,
)
from repro.comm.overlap import (  # noqa: F401
    bucket_triggers,
    exposed_comm,
    issue_order,
    make_overlap_grad,
)
from repro.comm.schedule import (  # noqa: F401
    FlatSchedule,
    HierarchicalSchedule,
    group_axes,
    make_schedule,
)
