"""Collective schedules for the bucketed gradient reduction (paper §3.4).

Both schedules implement the same contract, to be called INSIDE
``jax.shard_map``: ``reduce`` turns a replicated-shape fusion buffer of
partial sums into this member's 1-D strip (sum over the group, fp32 out),
``broadcast`` is its exact inverse on updated strips, and ``owner_index`` is
the flat strip index the member owns — ``reduce`` scatters strip ``i`` to
the member whose ``owner_index() == i``, and params must be sliced with the
same index for the ZeRO-1 strip update to line up.

The schedules are also the BACKEND seam: the actual wire collectives go
through a :class:`~repro.comm.backends.CollectiveBackend` (``lax`` — the
seed behavior — or ``pallas-ring``, the paper's explicit ring; see
``repro.comm.backends``).  Schedules own bucket layout, wire-dtype casts
and level composition; backends own the group collectives, so swapping one
never touches the optimizer rewiring.

FlatSchedule
    One ring over the (possibly composed) group: backend part-reduce /
    part-broadcast over the axis tuple, exactly the seed per-tensor path
    but per bucket.  Wire dtype applies to the single reduce stage.

HierarchicalSchedule (paper §3.3/§3.4 group composition)
    For ``axes == (outer, inner)`` — canonically ``("pod", "data")``: the
    in-pod reduce-scatter runs over ``inner`` first (wire dtype, ring of
    G_in members, full bucket bytes), then the cross-pod hop reduce-scatters
    the 1/G_in strips over ``outer`` in fp32 (fp32 accumulate across pods,
    strip bytes only on the slow link).  Member ``(p, d)`` owns flat strip
    ``d * G_out + p``; ``broadcast`` inverts with all-gathers in the
    opposite order.  Each level takes its own backend — the intended
    pairing is the Pallas ring in-pod (fast uniform links) with lax on the
    cross-pod hop.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh

from repro.comm.backends import CollectiveBackend, LaxBackend, get_backend
from repro.core.collectives import AxisNames, axis_size, flat_group_index


def group_axes(mesh: Mesh, data_axes) -> Tuple[Tuple[str, ...], AxisNames, int]:
    """(axes, axis_arg, G) for the data-parallel group actually present on
    ``mesh``: requested axes filtered to the mesh, the single-name-or-tuple
    form the collectives take, and the group size.  The one derivation every
    consumer of a schedule (update builders, the overlapped train step) must
    agree on."""
    axes = tuple(a for a in data_axes if a in mesh.axis_names)
    axis_arg = axes if len(axes) > 1 else axes[0]
    G = 1
    for a in axes:
        G *= mesh.shape[a]
    return axes, axis_arg, G


@dataclass(frozen=True)
class FlatSchedule:
    """Single-level ring over all data axes at once."""
    axes: AxisNames
    backend: CollectiveBackend = field(default_factory=LaxBackend)

    def group_size(self) -> int:
        return axis_size(self.axes)

    def owner_index(self) -> jax.Array:
        return flat_group_index(self.axes)

    def reduce(self, buf: jax.Array, wire_dtype=jnp.float32) -> jax.Array:
        strip = self.backend.part_reduce(buf.astype(wire_dtype), self.axes,
                                         dim=0)
        return strip.astype(jnp.float32)

    def broadcast(self, strip: jax.Array) -> jax.Array:
        return self.backend.part_broadcast(strip, self.axes, dim=0)


@dataclass(frozen=True)
class HierarchicalSchedule:
    """Two-level in-pod (``inner``) + cross-pod (``outer``) schedule, with
    a backend per level."""
    outer: str
    inner: str
    inner_backend: CollectiveBackend = field(default_factory=LaxBackend)
    outer_backend: CollectiveBackend = field(default_factory=LaxBackend)

    def group_size(self) -> int:
        return lax.axis_size(self.outer) * lax.axis_size(self.inner)

    def owner_index(self) -> jax.Array:
        # stage 1 scatters chunk d to inner member d; stage 2 scatters
        # sub-chunk p of chunk d to outer member p -> flat strip d*G_out + p
        return (lax.axis_index(self.inner) * lax.axis_size(self.outer)
                + lax.axis_index(self.outer))

    def reduce(self, buf: jax.Array, wire_dtype=jnp.float32) -> jax.Array:
        in_pod = self.inner_backend.part_reduce(buf.astype(wire_dtype),
                                                self.inner, dim=0)
        # cross-pod hop: strip bytes only, always fp32 accumulate
        return self.outer_backend.part_reduce(in_pod.astype(jnp.float32),
                                              self.outer, dim=0)

    def broadcast(self, strip: jax.Array) -> jax.Array:
        in_pod = self.outer_backend.part_broadcast(strip, self.outer, dim=0)
        return self.inner_backend.part_broadcast(in_pod, self.inner, dim=0)


Schedule = Union[FlatSchedule, HierarchicalSchedule]


def bind_step(backend: CollectiveBackend, step) -> CollectiveBackend:
    """Bind the train-step index into a STEP-SCHEDULED backend (the gossip
    partner rotation).  Step-free backends (lax, pallas-ring) have no
    ``bind_step`` method and pass through untouched, so the update
    builders can bind unconditionally — ``step`` may be a traced scalar."""
    binder = getattr(backend, "bind_step", None)
    return backend if binder is None else binder(step)


def bind_wire_format(backend: CollectiveBackend, wire_format: Optional[str],
                     topk_ratio: float = 0.05) -> CollectiveBackend:
    """Bind a compressed wire format (``CommConfig.wire_format``) into a
    backend that supports one.  Same getattr convention as
    :func:`bind_step`: backends without ``bind_wire_format`` (gossip) pass
    through — ``MODE_CAPS`` already restricts which formats reach them.
    ``None`` and the dense formats bind too (a no-op for fp32/bf16 — the
    dense dtype ride stays with the schedule's wire-dtype cast)."""
    if wire_format is None:
        return backend
    binder = getattr(backend, "bind_wire_format", None)
    return backend if binder is None else binder(wire_format, topk_ratio)


def reduce_mean(sched: Schedule, buf: jax.Array, wire_dtype,
                G: int) -> jax.Array:
    """THE reduce phase for one fusion buffer: wire-dtype part-reduce
    through the schedule, mean in fp32.  The single definition shared by
    the monolithic pipeline (``optim.dist.UpdatePlan.reduce``) and the
    §3.1 backward-pass hooks (``comm.overlap``) — the two issue points can
    never disagree on the math."""
    return sched.reduce(buf, wire_dtype) / G


def make_schedule(axes: Union[str, Tuple[str, ...]],
                  hierarchical: bool = False,
                  backend: Union[str, CollectiveBackend] = "lax",
                  cross_backend: Union[str, CollectiveBackend, None] = None,
                  step=None, wire_format: Optional[str] = None,
                  topk_ratio: float = 0.05) -> Schedule:
    """Pick the schedule for ``axes`` and bind its backend(s).

    The hierarchical form needs exactly two axes ``(outer, inner)``; one
    axis degrades to the flat ring (a one-axis "hierarchy" IS the flat
    ring), and more than two is a config error — there is no defined
    composition order, so it raises instead of silently going flat.

    ``backend`` drives the flat ring, or the IN-POD level of the
    hierarchical schedule.  ``cross_backend`` sets the cross-pod hop and
    defaults to ``"lax"``: the hop crosses the slow inter-pod link where
    XLA's collective is the right tool (and an in-kernel ring buys
    nothing), which is the mixed pairing the backends package documents.

    ``step`` (may be traced) is bound into step-scheduled backends via
    :func:`bind_step` — the gossip partner rotation advances with it;
    step-free backends ignore it.

    ``wire_format`` binds a compressed encoding (:func:`bind_wire_format`)
    into BOTH levels: in-pod hops move compressed messages, and because the
    hierarchical reduce casts the in-pod strips back to f32 before the
    cross-pod hop, the bound outer backend's ``part_reduce`` re-encodes
    exactly once there — the cross-pod hop is the natural re-quantization
    point (compressed in-pod, one fresh quantization across pods).
    """
    def resolve(b):
        b = get_backend(b)
        b = b if step is None else bind_step(b, step)
        return bind_wire_format(b, wire_format, topk_ratio)

    if hierarchical and not isinstance(axes, str) and len(axes) > 2:
        raise ValueError(
            "hierarchical schedule composes exactly two axes "
            f"(outer, inner); got {len(axes)}: {axes}. Fold the extra axes "
            "into the mesh topology (e.g. one 'pod' x one 'data' axis) or "
            "use hierarchical=False for a single flat ring.")
    if hierarchical and not isinstance(axes, str) and len(axes) == 2:
        return HierarchicalSchedule(
            outer=axes[0], inner=axes[1],
            inner_backend=resolve(backend),
            outer_backend=resolve(
                "lax" if cross_backend is None else cross_backend))
    return FlatSchedule(axes=axes, backend=resolve(backend))
