"""Collective schedules for the bucketed gradient reduction (paper §3.4).

Both schedules implement the same contract, to be called INSIDE
``jax.shard_map``: ``reduce`` turns a replicated-shape fusion buffer of
partial sums into this member's 1-D strip (sum over the group, fp32 out),
``broadcast`` is its exact inverse on updated strips, and ``owner_index`` is
the flat strip index the member owns — ``reduce`` scatters strip ``i`` to
the member whose ``owner_index() == i``, and params must be sliced with the
same index for the ZeRO-1 strip update to line up.

FlatSchedule
    One ring over the (possibly composed) group: ``psum_scatter`` /
    ``all_gather`` over the axis tuple, exactly the seed per-tensor path but
    per bucket.  Wire dtype applies to the single reduce stage.

HierarchicalSchedule (paper §3.3/§3.4 group composition)
    For ``axes == (outer, inner)`` — canonically ``("pod", "data")``: the
    in-pod reduce-scatter runs over ``inner`` first (wire dtype, ring of
    G_in members, full bucket bytes), then the cross-pod hop reduce-scatters
    the 1/G_in strips over ``outer`` in fp32 (fp32 accumulate across pods,
    strip bytes only on the slow link).  Member ``(p, d)`` owns flat strip
    ``d * G_out + p``; ``broadcast`` inverts with all-gathers in the
    opposite order.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh

from repro.core.collectives import (
    AxisNames, axis_size, part_broadcast, part_reduce,
)


def group_axes(mesh: Mesh, data_axes) -> Tuple[Tuple[str, ...], AxisNames, int]:
    """(axes, axis_arg, G) for the data-parallel group actually present on
    ``mesh``: requested axes filtered to the mesh, the single-name-or-tuple
    form the collectives take, and the group size.  The one derivation every
    consumer of a schedule (update builders, the overlapped train step) must
    agree on."""
    axes = tuple(a for a in data_axes if a in mesh.axis_names)
    axis_arg = axes if len(axes) > 1 else axes[0]
    G = 1
    for a in axes:
        G *= mesh.shape[a]
    return axes, axis_arg, G


def _flat_index(axis_names: AxisNames) -> jax.Array:
    if isinstance(axis_names, str):
        return lax.axis_index(axis_names)
    idx = jnp.zeros((), jnp.int32)
    for a in axis_names:
        idx = idx * lax.axis_size(a) + lax.axis_index(a)
    return idx


@dataclass(frozen=True)
class FlatSchedule:
    """Single-level ring over all data axes at once."""
    axes: AxisNames

    def group_size(self) -> int:
        return axis_size(self.axes)

    def owner_index(self) -> jax.Array:
        return _flat_index(self.axes)

    def reduce(self, buf: jax.Array, wire_dtype=jnp.float32) -> jax.Array:
        strip = part_reduce(buf.astype(wire_dtype), self.axes, dim=0)
        return strip.astype(jnp.float32)

    def broadcast(self, strip: jax.Array) -> jax.Array:
        return part_broadcast(strip, self.axes, dim=0)


@dataclass(frozen=True)
class HierarchicalSchedule:
    """Two-level in-pod (``inner``) + cross-pod (``outer``) schedule."""
    outer: str
    inner: str

    def group_size(self) -> int:
        return lax.axis_size(self.outer) * lax.axis_size(self.inner)

    def owner_index(self) -> jax.Array:
        # stage 1 scatters chunk d to inner member d; stage 2 scatters
        # sub-chunk p of chunk d to outer member p -> flat strip d*G_out + p
        return (lax.axis_index(self.inner) * lax.axis_size(self.outer)
                + lax.axis_index(self.outer))

    def reduce(self, buf: jax.Array, wire_dtype=jnp.float32) -> jax.Array:
        in_pod = part_reduce(buf.astype(wire_dtype), self.inner, dim=0)
        # cross-pod hop: strip bytes only, always fp32 accumulate
        return part_reduce(in_pod.astype(jnp.float32), self.outer, dim=0)

    def broadcast(self, strip: jax.Array) -> jax.Array:
        in_pod = part_broadcast(strip, self.outer, dim=0)
        return part_broadcast(in_pod, self.inner, dim=0)


Schedule = Union[FlatSchedule, HierarchicalSchedule]


def make_schedule(axes: Union[str, Tuple[str, ...]],
                  hierarchical: bool = False) -> Schedule:
    """Pick the schedule for ``axes``.  The hierarchical form needs exactly
    two axes ``(outer, inner)``; anything else falls back to the flat ring
    (a one-axis "hierarchy" IS the flat ring)."""
    if hierarchical and not isinstance(axes, str) and len(axes) == 2:
        return HierarchicalSchedule(outer=axes[0], inner=axes[1])
    return FlatSchedule(axes=axes)
