"""Backprop-overlapped bucket reduction — the paper's §3.1 bubble schedule,
executable.

The monolithic zero1 path reduces the whole gradient tree only after
``value_and_grad`` returns, so every byte of communication is exposed.  The
paper's overlap model instead issues each layer's weight-gradient
communication as soon as that layer's backprop finishes: the last layer's
gradients materialize first, and all but the "bubble" of each transfer hides
under the remaining backprop (plus the next forward pass).

This module realizes that schedule per fusion BUCKET with ``jax.custom_vjp``
comm hooks.  Each bucket's leaves pass through an identity ``tap`` on the
forward pass; the tap's backward rule packs the bucket's leaf cotangents into
the fusion buffer and issues the ``part_reduce`` right there — the reduce
enters the backward graph at the point where the bucket's LAST contributing
leaf gradient materializes (``Bucket.trigger_index``), with no data
dependency on the rest of backprop, so the compiler is free to overlap it
with the remaining layers' gradient computation.

The reduced strip leaves the backward pass through a gradient side channel:
every tap takes a zero-valued fp32 ``sink`` of strip shape whose custom
cotangent IS the bucket's reduced mean-gradient strip, so
``value_and_grad(hooked_loss, argnums=sinks)`` returns the strips directly
(the same trick flax's ``Module.perturb`` uses to surface intermediate
cotangents).  No monolithic post-grad reduction remains: the strips feed
``optim.dist.make_overlapped_update``, which slices, updates and
part-broadcasts exactly like the §3.4 strip update.

Everything here runs INSIDE ``jax.shard_map`` over the data axes — each
member computes the loss of its local batch shard, and the per-bucket
reduces sum the members' local gradients (divided by G: the synchronous-SGD
mean).  For the scan-based transformer stacks the param leaves are stacked
across layers, so a bucket's cotangent completes only when the whole scan
backward finishes — the schedule degrades to coarser granularity but stays
correct (the hooks are purely data-driven).

The analytic counterpart — which buckets' transfers stay exposed — is
``core.balance.bucket_bubble_schedule``, fed by :func:`bucket_triggers` /
:func:`issue_order` below; with one bucket per layer it reduces exactly to
the paper's per-layer ``bubble_schedule`` closed form (property-tested).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.comm.bucketer import Bucket, BucketPlan, CommConfig, plan_buckets
from repro.comm.schedule import Schedule, make_schedule, reduce_mean
from repro.core.collectives import AxisNames


# ---------------------------------------------------------------------------
# readiness metadata: bucket -> issue point of the §3.1 schedule
# ---------------------------------------------------------------------------
def bucket_triggers(plan: BucketPlan,
                    leaf_layer: Optional[Sequence[int]] = None
                    ) -> Tuple[int, ...]:
    """Per bucket, the FORWARD-order layer whose weight-gradient pass
    completes the bucket.  Backprop visits layers last-to-first, so a
    bucket's trigger is the MINIMUM layer over its leaves — the earliest
    forward layer is the last to deliver its gradient.

    ``leaf_layer`` maps flat leaf index -> forward layer index (e.g. parsed
    from the family's param-spec names); ``None`` treats each leaf as its
    own layer in tree order (``Bucket.trigger_index``).
    """
    if leaf_layer is None:
        return tuple(b.trigger_index for b in plan.buckets)
    return tuple(min(leaf_layer[s.index] for s in b.slots)
                 for b in plan.buckets)


def issue_order(triggers: Sequence[int]) -> Tuple[int, ...]:
    """Bucket indices in backprop issue order: descending trigger layer
    (a bucket completed by a LATER layer is ready earlier in backprop);
    ties break toward the later tree-order bucket.  Delegates to the single
    definition in ``core.balance.issue_order`` so the executable schedule
    and the analytic closed forms can never disagree on ordering."""
    from repro.core.balance import issue_order as _rule
    return _rule(triggers)


# ---------------------------------------------------------------------------
# the comm hooks
# ---------------------------------------------------------------------------
def _bucket_tap(bucket: Bucket, sched: Schedule, wire_dtype, G: int):
    """Identity on the bucket's leaves whose BACKWARD packs their cotangents
    into the fusion buffer and issues the part-reduce.  The reduced mean
    strip exits as the cotangent of the zero ``sink`` argument."""

    @jax.custom_vjp
    def tap(leaves, sink):
        return leaves

    def fwd(leaves, sink):
        return leaves, None

    def bwd(_, ct):
        parts = [c.reshape(-1) for c in ct]
        pad = bucket.padded_size - bucket.size
        if pad:
            parts.append(jnp.zeros((pad,), parts[0].dtype))
        buf = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
        strip = reduce_mean(sched, buf, wire_dtype, G)
        # leaf cotangents pass through untouched — upstream backprop is
        # unaffected; the strip rides the sink's gradient channel
        return tuple(ct), strip

    tap.defvjp(fwd, bwd)
    return tap


def make_overlap_grad(loss_fn: Callable, axes: AxisNames, comm: CommConfig,
                      G: int) -> Callable:
    """Build ``overlap_grad(params, batch) -> (loss, g_strips)``, to be
    called INSIDE ``shard_map`` over ``axes``.

    ``loss_fn(params, batch)`` is the member-LOCAL loss (mesh-free ctx);
    ``loss`` returned is still local — psum/G it for the global mean.
    ``g_strips`` is one fully-reduced fp32 mean-gradient strip per bucket of
    ``plan_buckets(params, G, comm.bucket_bytes)`` — the same plan (and the
    same owner layout) ``make_overlapped_update`` consumes.  The reduces
    issued by the hooks go through ``comm.backend``'s collectives.
    """
    # wire_format rides the schedule seam here too (int8 overlap works —
    # stateless); topk never reaches this path: its error-feedback residual
    # has nowhere to live in a stateless tap, so MODE_CAPS rejects the combo
    sched = make_schedule(axes, comm.hierarchical, comm.backend,
                          comm.cross_backend, wire_format=comm.wire_format,
                          topk_ratio=comm.topk_ratio)

    def overlap_grad(params, batch):
        plan = plan_buckets(params, G, comm.bucket_bytes)
        flat, treedef = jax.tree.flatten(params)

        def hooked_loss(flat_leaves, sinks):
            out = list(flat_leaves)
            for b, sink in zip(plan.buckets, sinks):
                tapped = _bucket_tap(b, sched, comm.wire_dtype, G)(
                    tuple(out[s.index] for s in b.slots), sink)
                for s, leaf in zip(b.slots, tapped):
                    out[s.index] = leaf
            return loss_fn(jax.tree.unflatten(treedef, out), batch)

        sinks = tuple(jnp.zeros((b.padded_size // G,), jnp.float32)
                      for b in plan.buckets)
        loss, strips = jax.value_and_grad(hooked_loss, argnums=1)(
            tuple(flat), sinks)
        return loss, list(strips)

    return overlap_grad


# ---------------------------------------------------------------------------
# analytic exposure: what the schedule is predicted to hide
# ---------------------------------------------------------------------------
def exposed_comm(plan: BucketPlan, comm_times: Sequence[float],
                 layer_comps: Sequence[float], hw,
                 leaf_layer: Optional[Sequence[int]] = None,
                 efficiency: float = 1.0) -> Tuple[float, float, List[float]]:
    """(exposed_off, exposed_on, bubbles): predicted exposed-comm seconds
    with the monolithic schedule (everything after backprop — the full
    ``sum(comm_times)``) vs. the §3.1 overlap schedule
    (``core.balance.overlap_exposed_time`` on the shared-link timeline).
    ``bubbles`` are the per-bucket §3.1 closed-form bubbles
    (``bucket_bubble_schedule``) for diagnosis — which transfers the
    schedule fails to hide.  All driven by this plan's readiness metadata."""
    from repro.core.balance import bucket_bubble_schedule, overlap_exposed_time
    triggers = bucket_triggers(plan, leaf_layer)
    bubbles = bucket_bubble_schedule(comm_times, triggers, layer_comps, hw,
                                     efficiency)
    off = float(sum(comm_times))
    on = float(overlap_exposed_time(comm_times, triggers, layer_comps, hw,
                                    efficiency))
    return off, on, bubbles
