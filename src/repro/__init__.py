"""repro — Distributed synchronous-SGD training/inference framework in JAX.

Reproduction (and TPU adaptation) of Das et al. 2016, "Distributed Deep
Learning Using Synchronous Stochastic Gradient Descent" (Intel PCL-DNN):
hybrid data/model parallelism, part-reduce/part-broadcast collectives,
balance-equation-driven placement, and blocking-solver-driven Pallas kernels
— extended to ten modern architectures across dense/MoE/SSM/hybrid/VLM/audio
families.  See DESIGN.md.
"""
__version__ = "1.0.0"

from repro import jaxcompat  # noqa: E402,F401  (backfills jax>=0.6 APIs on 0.4.x)
