"""Backfill newer-jax public APIs onto jax 0.4.x so the codebase runs on
both (the code targets the jax>=0.6 names; CPU containers may pin 0.4.x).

Imported for its side effects from ``repro/__init__.py`` (and prepended to
the subprocess snippets in tests/test_distributed.py, which touch jax before
importing repro).  Every patch is gated on the attribute being absent, so on
a jax that already provides the API this module is inert.

Backfills:
  * ``jax.sharding.AxisType`` — enum accepted (and ignored: 0.4 meshes are
    all auto) by the ``make_mesh`` wrapper below.
  * ``jax.make_mesh(..., axis_types=...)`` — drops the kwarg on 0.4.
  * ``jax.shard_map(..., check_vma=...)`` — maps to
    ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.
  * ``jax.set_mesh(mesh)`` — returns the mesh itself, whose context-manager
    protocol on 0.4 establishes the same ambient mesh that ``set_mesh``
    provides on newer jax (all call sites use ``with jax.set_mesh(m):``).
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax
import jax.sharding


if not hasattr(jax.sharding, "AxisType"):
    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
    _make_mesh = jax.make_mesh

    @functools.wraps(_make_mesh)
    def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        return _make_mesh(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = make_mesh


_AM = jax.sharding.AbstractMesh
if "shape_tuple" in inspect.signature(_AM.__init__).parameters:
    # 0.4.x signature: AbstractMesh(((name, size), ...)); newer jax:
    # AbstractMesh(axis_sizes, axis_names).  Factory keeps the new call form.
    def AbstractMesh(axis_sizes, axis_names=None, *a, **kw):
        if axis_names is None:
            return _AM(axis_sizes, *a, **kw)
        return _AM(tuple(zip(axis_names, axis_sizes)), *a, **kw)

    jax.sharding.AbstractMesh = AbstractMesh


if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                  check_vma=None, check_rep=None, **kw):
        if check_vma is not None:
            check_rep = check_vma
        if check_rep is not None:
            kw["check_rep"] = check_rep
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)

    jax.shard_map = shard_map


if not hasattr(jax.lax, "axis_size"):
    def _axis_size(axis_name):
        # psum of a unit constant is the historical spelling: it constant-
        # folds to the (static) size of the named axis
        return jax.lax.psum(1, axis_name)

    jax.lax.axis_size = _axis_size


if not hasattr(jax, "set_mesh"):
    def set_mesh(mesh):
        # Mesh is a context manager on 0.4; entering it is the ambient-mesh
        # effect set_mesh has on newer jax
        return mesh

    jax.set_mesh = set_mesh
