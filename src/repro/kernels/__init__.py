"""Pallas TPU kernels (validated in interpret mode on CPU) + jnp oracles.

Kernels implement the paper's §2 single-node efficiency layer, adapted from
x86 cache/register blocking to VMEM/MXU blocking — see DESIGN.md §2.
"""
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.blocked_matmul import blocked_matmul  # noqa: F401
from repro.kernels.conv2d import conv2d_nhwc  # noqa: F401
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.ring import ring_all_gather, ring_hop_accum, ring_reduce_scatter  # noqa: F401
