"""Paper §3.4 ring collectives as Pallas kernels.

The paper's part-reduce / part-broadcast pair is bandwidth-optimal when run
as a RING: each of the G members repeatedly sends one 1/G chunk to its right
neighbor and combines the chunk it receives from the left — G-1 neighbor
exchanges move 2*(G-1)/G of the buffer per member (``core.balance.
ring_collective_time``).  This module implements that schedule explicitly:

``ring_reduce_scatter`` / ``ring_all_gather``
    The full §3.4 ring over a stacked ``(G, N)`` buffer (member p's partial
    in row p) in ONE kernel: grid ``(G-1 steps, G members)``, executed in
    step-major order, with a double-buffered mailbox ``(2, G, chunk)``
    standing in for the neighbor RDMA slots — program ``(s, p)`` writes the
    chunk it "sends" into the slot program ``(s+1, p+1)`` reads, alternating
    buffer parity per step exactly like the double-buffered remote-copy ring
    of the Pallas TPU guide (send/recv slot = step % 2).  On a real slice
    the same schedule runs one program per chip with
    ``pltpu.make_async_remote_copy`` to the right neighbor; the stacked
    single-core form keeps the rotation/parity logic identical and runs
    under ``interpret=True`` on CPU, where it is validated against the
    ``kernels.ref`` oracles (tests/test_kernels.py).

``ring_hop_accum``
    The per-hop combine of the distributed ring — ``recv + chunks[c]`` with
    the chunk index prefetched as a scalar — used by
    ``repro.comm.backends.PallasRingBackend`` inside ``shard_map``: there
    the neighbor exchange itself is a ``lax.ppermute`` (XLA's ICI neighbor
    DMA), and this kernel is the compute the ring overlaps with it.

Chunk/owner convention (must match ``lax.psum_scatter(tiled=True)`` so the
backends are interchangeable): the buffer splits into G equal chunks along
dim 0 and flat group member i ends up owning chunk i.  At step s, member p
receives the partial sum of chunk ``(p - 2 - s) % G``, adds its own
contribution, and forwards it — after G-1 hops the fully-reduced chunk p
lands on member p.  All-gather inverts it: member p's strip visits every
member in G-1 hops, arriving at member p+k as chunk ``(p) = ((p+k) - k)``.

Accumulation happens in the input dtype: the ring's hop-adds ARE the wire
arithmetic, so a bf16 wire dtype accumulates in bf16 per hop (the schedule
layer casts back to fp32 after the reduce, and the cross-pod hop of the
hierarchical schedule always runs fp32 — see ``repro.comm.schedule``).

``int8_quantize`` / ``ring_hop_int8`` / ``ring_hop_topk``
    The compressed wire formats (``CommConfig.wire_format``), fused into
    the per-hop combine: ``ring_hop_int8`` dequantizes the received int8
    message against its per-message scale, adds the local chunk partial in
    **f32**, and re-quantizes against a fresh max-abs scale — one rounding
    per hop, so quantization error stays additive across the G-1 hops
    instead of compounding.  ``ring_hop_topk`` scatter-adds a received
    (values, indices) sparse message dense and adds the local partial; the
    top-k RE-selection for the next hop is plain ``lax.top_k`` in the
    backend (selection is not a memory-bound combine, fusing it buys
    nothing).  Like the stacked ring, these run under interpret mode on
    this container; Mosaic bring-up shares the (8, 128)-tile padding TODO
    of the hop kernel (ROADMAP, PR 4 remainder).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# the full §3.4 ring, one kernel (stacked single-core form)
# ---------------------------------------------------------------------------
def _reduce_scatter_kernel(x_ref, out_ref, buf_ref):
    """Program (s, p): member p's step-s hop of the ring reduce-scatter.

    x_ref    (G, G, n)  member p's local partials, split into G chunks
    out_ref  (G, n)     member p's fully-reduced strip (written at s=G-2)
    buf_ref  (2, G, n)  double-buffered mailboxes: slot ``(s+1) % 2, q`` is
                        what q's left neighbor sent it for step s+1
    """
    s, p = pl.program_id(0), pl.program_id(1)
    G = pl.num_programs(1)
    steps = pl.num_programs(0)
    c = jnp.mod(p - 2 - s, G)       # chunk whose partial arrives this step
    left = jnp.mod(p - 1, G)
    recv = jax.lax.cond(
        s == 0,
        # first hop: the left neighbor sends its RAW local chunk
        lambda: x_ref[left, c],
        lambda: buf_ref[jnp.mod(s, 2), p])
    acc = recv + x_ref[p, c]
    # "send" to the right neighbor: the mailbox it reads at step s+1
    buf_ref[jnp.mod(s + 1, 2), jnp.mod(p + 1, G)] = acc

    @pl.when(s == steps - 1)
    def _():
        out_ref[p] = acc            # c == p at the final step


def _all_gather_kernel(x_ref, out_ref, buf_ref):
    """Program (s, p): member p's step-s hop of the ring all-gather.

    x_ref    (G, n)     member p's strip in row p
    out_ref  (G, G, n)  row p = member p's gathered copy, chunk o = strip o
    buf_ref  (2, G, n)  double-buffered mailboxes (parity = step % 2)
    """
    s, p = pl.program_id(0), pl.program_id(1)
    G = pl.num_programs(1)
    o = jnp.mod(p - 1 - s, G)       # owner of the strip arriving this step
    left = jnp.mod(p - 1, G)

    @pl.when(s == 0)
    def _():
        out_ref[p, p] = x_ref[p]    # own strip needs no hop

    recv = jax.lax.cond(
        s == 0,
        lambda: x_ref[left],
        lambda: buf_ref[jnp.mod(s, 2), p])
    out_ref[p, o] = recv
    buf_ref[jnp.mod(s + 1, 2), jnp.mod(p + 1, G)] = recv


def ring_reduce_scatter(stacked: jax.Array, *,
                        interpret: Optional[bool] = None) -> jax.Array:
    """Reduce-scatter a stacked ``(G, N)`` buffer of per-member partials:
    row p of the ``(G, N // G)`` result is the fully-reduced chunk p —
    member p's strip under the §3.4 owner convention.  ``N % G == 0``
    (fusion buckets are padded to a strip multiple by ``repro.comm``)."""
    G, N = stacked.shape
    if N % G:
        raise ValueError(f"buffer size {N} not divisible by group {G}")
    n = N // G
    if G == 1:
        return stacked.reshape(1, N)
    out, _ = pl.pallas_call(
        _reduce_scatter_kernel,
        grid=(G - 1, G),
        out_shape=(jax.ShapeDtypeStruct((G, n), stacked.dtype),
                   jax.ShapeDtypeStruct((2, G, n), stacked.dtype)),
        interpret=_auto_interpret(interpret),
    )(stacked.reshape(G, G, n))
    return out


def ring_all_gather(strips: jax.Array, *,
                    interpret: Optional[bool] = None) -> jax.Array:
    """All-gather per-member ``(G, n)`` strips into ``(G, G * n)``: every
    row is the full buffer, strips concatenated in owner order (the §3.4
    part-broadcast)."""
    G, n = strips.shape
    if G == 1:
        return strips
    out, _ = pl.pallas_call(
        _all_gather_kernel,
        grid=(G - 1, G),
        out_shape=(jax.ShapeDtypeStruct((G, G, n), strips.dtype),
                   jax.ShapeDtypeStruct((2, G, n), strips.dtype)),
        interpret=_auto_interpret(interpret),
    )(strips)
    return out.reshape(G, G * n)


# ---------------------------------------------------------------------------
# the per-hop combine of the distributed ring (used inside shard_map)
# ---------------------------------------------------------------------------
def _hop_accum_kernel(c_ref, chunk_ref, recv_ref, out_ref):
    # chunk_ref is the (1, n) block the index map selected with the
    # prefetched chunk index — the rest of the local buffer never moves
    out_ref[...] = recv_ref[...] + chunk_ref[0]


def ring_hop_accum(chunks: jax.Array, recv: jax.Array, c: jax.Array, *,
                   interpret: Optional[bool] = None) -> jax.Array:
    """One ring hop: add this member's local partial of chunk ``c`` (a
    traced index — it depends on ``lax.axis_index``) to the partial just
    received from the left neighbor.  ``chunks`` is ``(G, n)``, ``recv``
    and the result are ``(n,)``.

    ``c`` rides in as a scalar-prefetch argument driving the chunks
    BlockSpec index map, so only the selected ``(1, n)`` block is brought
    into VMEM per hop — O(n) traffic, not O(G*n) (the G-1 hops of one
    reduce would otherwise stream the whole buffer G-1 times)."""
    from jax.experimental.pallas import tpu as pltpu
    G, n = chunks.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, n), lambda i, c_ref: (c_ref[0], 0)),
                  pl.BlockSpec((n,), lambda i, c_ref: (0,))],
        out_specs=pl.BlockSpec((n,), lambda i, c_ref: (0,)),
    )
    return pl.pallas_call(
        _hop_accum_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(recv.shape, recv.dtype),
        interpret=_auto_interpret(interpret),
    )(jnp.asarray(c, jnp.int32).reshape(1), chunks, recv)


# ---------------------------------------------------------------------------
# compressed wire formats fused into the hop (CommConfig.wire_format)
# ---------------------------------------------------------------------------
def _int8_quantize_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    s = jnp.max(jnp.abs(x)) / 127.0
    s = jnp.where(s > 0, s, 1.0)   # all-zero message: keep dequant defined
    q_ref[...] = jnp.round(x / s).astype(jnp.int8)
    s_ref[0] = s


def int8_quantize(x: jax.Array, *,
                  interpret: Optional[bool] = None) -> tuple:
    """Quantize a 1-D f32 message to ``(q int8 (n,), scale f32 (1,))``
    with a symmetric per-message max-abs scale (``kernels.ref.
    int8_quantize_ref`` is the oracle).  Used for the FIRST send of the
    int8 ring — every later hop re-quantizes inside ``ring_hop_int8``."""
    n, = x.shape
    return pl.pallas_call(
        _int8_quantize_kernel,
        out_shape=(jax.ShapeDtypeStruct((n,), jnp.int8),
                   jax.ShapeDtypeStruct((1,), jnp.float32)),
        interpret=_auto_interpret(interpret),
    )(x)


def _hop_int8_kernel(c_ref, chunk_ref, q_ref, s_ref, qout_ref, sout_ref):
    del c_ref  # consumed by the chunk BlockSpec index map
    acc = q_ref[...].astype(jnp.float32) * s_ref[0] \
        + chunk_ref[0].astype(jnp.float32)
    s = jnp.max(jnp.abs(acc)) / 127.0
    s = jnp.where(s > 0, s, 1.0)
    qout_ref[...] = jnp.round(acc / s).astype(jnp.int8)
    sout_ref[0] = s


def ring_hop_int8(chunks: jax.Array, q: jax.Array, scale: jax.Array,
                  c: jax.Array, *,
                  interpret: Optional[bool] = None) -> tuple:
    """One int8 ring hop, fully fused: dequantize the received message
    ``(q, scale)``, add this member's local partial of chunk ``c`` in f32,
    re-quantize against a fresh max-abs scale.  Returns the next wire
    message ``(q' int8 (n,), scale' f32 (1,))``.  Same scalar-prefetch
    chunk selection as :func:`ring_hop_accum`."""
    from jax.experimental.pallas import tpu as pltpu
    G, n = chunks.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, n), lambda i, c_ref: (c_ref[0], 0)),
                  pl.BlockSpec((n,), lambda i, c_ref: (0,)),
                  pl.BlockSpec((1,), lambda i, c_ref: (0,))],
        out_specs=[pl.BlockSpec((n,), lambda i, c_ref: (0,)),
                   pl.BlockSpec((1,), lambda i, c_ref: (0,))],
    )
    return pl.pallas_call(
        _hop_int8_kernel,
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((n,), jnp.int8),
                   jax.ShapeDtypeStruct((1,), jnp.float32)),
        interpret=_auto_interpret(interpret),
    )(jnp.asarray(c, jnp.int32).reshape(1), chunks, q, scale)


def _hop_topk_kernel(c_ref, chunk_ref, val_ref, idx_ref, out_ref):
    del c_ref
    n = out_ref.shape[0]
    dense = jnp.zeros((n,), jnp.float32).at[idx_ref[...]].add(
        val_ref[...].astype(jnp.float32))
    out_ref[...] = dense + chunk_ref[0].astype(jnp.float32)


def ring_hop_topk(chunks: jax.Array, vals: jax.Array, idx: jax.Array,
                  c: jax.Array, *,
                  interpret: Optional[bool] = None) -> jax.Array:
    """One top-k ring hop combine: scatter-add the received sparse message
    ``(vals, idx)`` into a dense f32 buffer and add this member's local
    partial of chunk ``c``.  Returns the dense ``(n,)`` accumulator — the
    backend re-selects its top-k before forwarding (and keeps the dense
    result on the final hop, so the LAST combine loses nothing)."""
    from jax.experimental.pallas import tpu as pltpu
    G, n = chunks.shape
    k, = vals.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, n), lambda i, c_ref: (c_ref[0], 0)),
                  pl.BlockSpec((k,), lambda i, c_ref: (0,)),
                  pl.BlockSpec((k,), lambda i, c_ref: (0,))],
        out_specs=pl.BlockSpec((n,), lambda i, c_ref: (0,)),
    )
    return pl.pallas_call(
        _hop_topk_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=_auto_interpret(interpret),
    )(jnp.asarray(c, jnp.int32).reshape(1), chunks, vals,
      jnp.asarray(idx, jnp.int32))
