"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel test sweeps shapes/dtypes and asserts the Pallas implementation
(interpret mode on CPU) matches these references to tight tolerances.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


def matmul_ref(a: jax.Array, b: jax.Array,
               out_dtype=jnp.float32) -> jax.Array:
    """C = A @ B with f32 accumulation (MXU semantics)."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def conv2d_ref(x: jax.Array, w: jax.Array, stride: int = 1,
               padding: int = 0, out_dtype=jnp.float32) -> jax.Array:
    """NHWC x HWIO -> NHWC direct convolution (the paper's Algorithm 1,
    adapted to the TPU-native lane-contiguous channel-innermost layout)."""
    out = lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out.astype(out_dtype)


def _softcap(logits: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(logits / cap) * cap if cap > 0 else logits


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: int = 0,
                  logit_softcap: float = 0.0,
                  scale: Optional[float] = None,
                  out_dtype=None) -> jax.Array:
    """Multi-head attention oracle.

    q: (B, Sq, Hq, D);  k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0 (GQA).
    ``window`` > 0 enables sliding-window causal attention (each query sees
    keys in (pos - window, pos]).  Softmax in f32.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if g > 1:
        kf = jnp.repeat(kf, g, axis=2)
        vf = jnp.repeat(vf, g, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    logits = _softcap(logits, logit_softcap)
    q_pos = jnp.arange(Sq)[:, None] + (Skv - Sq)   # right-aligned
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window and window > 0:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(out_dtype or q.dtype)


def ring_reduce_scatter_ref(stacked: jax.Array) -> jax.Array:
    """Oracle for ``kernels.ring.ring_reduce_scatter``: row p of the result
    is the sum over members of chunk p (f32 accumulation; the ring kernel
    accumulates hop-by-hop in the wire dtype, so bf16 compares to
    tolerance)."""
    G, N = stacked.shape
    full = stacked.astype(jnp.float32).sum(axis=0)
    return full.reshape(G, N // G).astype(stacked.dtype)


def ring_all_gather_ref(strips: jax.Array) -> jax.Array:
    """Oracle for ``kernels.ring.ring_all_gather``: every member ends up
    with the full buffer — strips concatenated in owner order."""
    G, n = strips.shape
    return jnp.broadcast_to(strips.reshape(1, G * n), (G, G * n))


def int8_quantize_ref(x: jax.Array):
    """Oracle for ``kernels.ring.int8_quantize``: symmetric per-message
    max-abs quantization.  Returns ``(q int8 (n,), scale f32 (1,))`` with
    ``scale = max|x| / 127`` (1.0 for an all-zero message so dequantize is
    well defined); round-to-nearest keeps ``|q| <= 127`` by construction."""
    xf = x.astype(jnp.float32)
    s = jnp.max(jnp.abs(xf)) / 127.0
    s = jnp.where(s > 0, s, 1.0)
    q = jnp.round(xf / s).astype(jnp.int8)
    return q, s.reshape(1)


def int8_dequantize_ref(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`int8_quantize_ref` (f32 result)."""
    return q.astype(jnp.float32) * scale.reshape(())[None]


def ring_hop_int8_ref(chunks: jax.Array, q: jax.Array, scale: jax.Array,
                      c) -> tuple:
    """Oracle for ``kernels.ring.ring_hop_int8``: dequantize the received
    message, accumulate the local partial of chunk ``c`` in f32, and
    re-quantize against a FRESH max-abs scale — per-hop f32 accumulation is
    what keeps the quantization error additive (one rounding per hop)
    instead of compounding across the G-1 hops."""
    acc = int8_dequantize_ref(q, scale) + chunks[c].astype(jnp.float32)
    return int8_quantize_ref(acc)


def topk_select_ref(x: jax.Array, k: int) -> tuple:
    """Top-k sparsification oracle: the ``k`` largest-|x| entries as a
    ``(values f32 (k,), indices int32 (k,))`` wire message."""
    xf = x.astype(jnp.float32)
    _, idx = lax.top_k(jnp.abs(xf), k)
    return xf[idx], idx.astype(jnp.int32)


def topk_scatter_ref(vals: jax.Array, idx: jax.Array, n: int) -> jax.Array:
    """Densify a (values, indices) message into an ``(n,)`` f32 buffer
    (duplicate indices accumulate, matching the kernel's scatter-add)."""
    return jnp.zeros((n,), jnp.float32).at[idx].add(
        vals.astype(jnp.float32))


def ring_hop_topk_ref(chunks: jax.Array, vals: jax.Array, idx: jax.Array,
                      c) -> jax.Array:
    """Oracle for ``kernels.ring.ring_hop_topk``: scatter the received
    sparse message dense and add the local partial of chunk ``c`` (f32).
    Re-selection of the next hop's top-k stays OUTSIDE the kernel (the
    backend calls :func:`topk_select_ref`-equivalent jnp on the result)."""
    return topk_scatter_ref(vals, idx, chunks.shape[1]) \
        + chunks[c].astype(jnp.float32)


def topk_mask_ref(x: jax.Array, k: int) -> jax.Array:
    """Keep the ``k`` largest-|x| entries of ``x`` in place, zero the rest
    — the bucket-level sparsifier of the error-feedback update
    (``optim.dist.make_topk_ef_update``); the residual is ``x - mask``."""
    _, idx = lax.top_k(jnp.abs(x.astype(jnp.float32)), k)
    return jnp.zeros_like(x).at[idx].set(x[idx])


def paged_decode_attention_ref(q: jax.Array, pages_k: jax.Array,
                               pages_v: jax.Array, page_table: jax.Array,
                               lengths: jax.Array, *, window: int = 0,
                               logit_softcap: float = 0.0) -> jax.Array:
    """One-token attention over a PAGED KV cache (oracle for
    ``kernels.paged_attn.paged_decode_attention``).

    q: (B, Hq, D); pages_k/pages_v: (P, ps, Hkv, D) — the physical page
    pool; page_table: (B, n) int32 physical page id per logical page;
    lengths: (B,) int32 — number of VALID tokens per request (including the
    one just written).  Logical position ``p`` of request ``b`` lives in
    page ``page_table[b, p // ps]`` at offset ``p % ps``.  Positions
    >= lengths are masked; ``window`` > 0 additionally masks positions
    <= lengths - 1 - window (the ring-buffer SWA retention set: the last
    ``window`` tokens).  Requires lengths >= 1 (a fully-masked request's
    softmax would be degenerate — the serving engine never attends an
    empty cache).
    """
    B, Hq, D = q.shape
    _, ps, Hkv, _ = pages_k.shape
    n = page_table.shape[1]
    g = Hq // Hkv
    scale = D ** -0.5
    kg = pages_k[page_table].reshape(B, n * ps, Hkv, D).astype(jnp.float32)
    vg = pages_v[page_table].reshape(B, n * ps, Hkv, D).astype(jnp.float32)
    if g > 1:
        kg = jnp.repeat(kg, g, axis=2)
        vg = jnp.repeat(vg, g, axis=2)
    qf = q.astype(jnp.float32) * scale                 # (B, Hq, D)
    logits = jnp.einsum("bhd,bkhd->bhk", qf, kg)
    logits = _softcap(logits, logit_softcap)
    pos = jnp.arange(n * ps)[None, :]
    valid = pos < lengths[:, None]
    if window and window > 0:
        valid &= pos > lengths[:, None] - 1 - window
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", p, vg)
    return out.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         cache_len, *, window: int = 0,
                         logit_softcap: float = 0.0) -> jax.Array:
    """One-token attention against a (possibly ring-buffered) cache.

    q: (B, 1, Hq, D); caches: (B, C, Hkv, D); cache_len: (B,) valid lengths.
    Entries at index >= cache_len are masked.  With a ring buffer the caller
    guarantees only the most recent ``window`` entries are resident, so no
    extra position masking is needed beyond validity.
    """
    B, C, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    g = Hq // Hkv
    scale = D ** -0.5
    qf = q[:, 0].astype(jnp.float32) * scale           # (B, Hq, D)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    if g > 1:
        kf = jnp.repeat(kf, g, axis=2)
        vf = jnp.repeat(vf, g, axis=2)
    logits = jnp.einsum("bhd,bkhd->bhk", qf, kf)
    logits = _softcap(logits, logit_softcap)
    valid = jnp.arange(C)[None, :] < cache_len[:, None]
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", p, vf)
    return out[:, None].astype(q.dtype)
