"""Blocked online-softmax (flash) attention Pallas kernel.

This is the transformer hot-spot analogue of the paper's §2 single-node
optimization: the score/softmax/PV pipeline never materializes the (Sq, Skv)
score matrix in HBM.  Blocking follows the same B/F logic as §2.2 — the
working set per grid step is (bq x D) queries, (bkv x D) keys/values and the
(bq x D) f32 accumulator, all VMEM-resident; bkv rides the lane dimension.

Supports causal masking, sliding windows (gemma2 local layers, mistral-style
SWA), attention-logit softcapping (gemma2) and GQA (Hq % Hkv == 0) — the
feature set the ten assigned architectures need.

Grid: (batch, q_head, q_block, kv_block); the running max/denominator/output
accumulators live in VMEM scratch and persist across the innermost kv steps
(the 'resident register block' of the paper's Algorithm 2, adapted).
Fully-masked kv blocks (beyond the causal frontier or the window) are skipped
with ``pl.when`` — on TPU this halves causal compute.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bkv: int, n_kv: int, sq: int, skv: int,
                  causal: bool, window: int, softcap: float, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions (right-aligned when sq < skv, e.g. chunked prefill)
    q_start = qi * bq + (skv - sq)
    k_start = ki * bkv
    # block-level skip predicate: any (q, k) pair in range?
    needed = True
    if causal:
        needed = jnp.logical_and(needed, k_start <= q_start + bq - 1)
    if window > 0:
        needed = jnp.logical_and(needed, k_start + bkv - 1 > q_start - window)

    @pl.when(needed)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale      # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)              # (bkv, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bkv)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                    # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        lsum = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / lsum).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    logit_softcap: float = 0.0,
                    scale: Optional[float] = None,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D); returns (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(bq, Sq)
    bkv = min(bkv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0, (Sq, Skv, bq, bkv)
    grid = (B, Hq, Sq // bq, Skv // bkv)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bkv=bkv, n_kv=Skv // bkv, sq=Sq, skv=Skv,
        causal=causal, window=window, softcap=logit_softcap, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bkv, 1, D),
                         lambda b, h, qi, ki, g=group: (b, ki, h // g, 0)),
            pl.BlockSpec((1, bkv, 1, D),
                         lambda b, h, qi, ki, g=group: (b, ki, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
