"""Blocked GEMM Pallas kernel — paper §2.2/§2.4 adapted to the TPU MXU.

The paper's cache blocking picks (block_m, block_n, block_k) by B/F
minimization under the on-chip capacity; its register blocking keeps a tile
of accumulators live across the K loop.  TPU translation (DESIGN.md §2):

  * the capacity is VMEM; ``core.blocking.solve_gemm_blocking`` performs the
    paper's brute-force search and its result parameterizes the BlockSpecs;
  * the accumulator tile (bm x bn, f32) stays resident in the output VMEM
    block across the K grid steps — the MXU analogue of the paper's
    10..15-register VFMA block (latency hiding is the systolic pipeline's
    job, residency is ours);
  * the lane dimension (bn, multiples of 128) is innermost-contiguous —
    the analogue of the paper's SIMD-width-innermost data layout (§2.3).

Grid iteration order is (m, n, k) with k innermost so the output tile is
revisited consecutively (the paper's 'traverse consecutive blocks along a
dimension to reuse' observation, applied to the accumulator).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.blocking import GemmBlocking, solve_gemm_blocking


def _matmul_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)


def blocked_matmul(a: jax.Array, b: jax.Array, *,
                   blocking: Optional[GemmBlocking] = None,
                   vmem_bytes: int = 8 * 2**20,
                   interpret: bool = False) -> jax.Array:
    """C[M,N] = A[M,K] @ B[K,N], f32 accumulation, tiles from the §2.2 solver."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    if blocking is None:
        blocking = solve_gemm_blocking(M, N, K, vmem_bytes=vmem_bytes,
                                       size_data=a.dtype.itemsize)
    bm, bn, bk = blocking.bm, blocking.bn, blocking.bk
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out
