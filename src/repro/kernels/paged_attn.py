"""Paged-decode attention Pallas kernel: gather non-contiguous KV pages.

One decode token attends over a request's KV history scattered across
fixed-size pages of a physical pool (``serve.kvcache.PagedKVCache`` owns the
free-list; ``models.layers.PagedAttnCache`` is the device-side pytree).  The
kernel never materializes the gathered (B, n*ps, Hkv, D) cache that the
jnp reference path builds: the grid walks each request's LOGICAL pages and
the page table rides in as a scalar-prefetch argument driving the page-pool
BlockSpec index map — the same trick ``kernels.ring.ring_hop_accum`` uses to
select its chunk — so only the one (ps, Hkv, D) physical page the program
needs is copied into VMEM per step, wherever it sits in the pool.

Online softmax accumulates across a request's pages in VMEM scratch exactly
like ``kernels.flash_attention`` accumulates across kv blocks; pages fully
outside the valid set (beyond ``lengths`` or, for sliding-window layers,
older than the retention window) are skipped with ``pl.when``.

Correctness contract: ``kernels.ref.paged_decode_attention_ref``, swept in
tests/test_kernels.py under interpret mode (auto-enabled off-TPU, as with
the ring kernels).  As with the ring, the compiled Mosaic path is
unexercised on this CPU container: the in-kernel GQA ``jnp.repeat`` and the
(Hq, ps) score shapes likely want (8, 128)-tile padding for a first real-TPU
bring-up.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, ps: int, n_pages: int,
                  window: int, softcap: float, scale: float, g: int):
    b, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]                       # valid tokens (incl. current)
    start = i * ps
    # page-level skip: any position of this logical page in the valid set?
    needed = start < length
    if window > 0:
        needed = jnp.logical_and(needed, start + ps - 1 > length - 1 - window)

    @pl.when(needed)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (Hq, D)
        k = k_ref[0].astype(jnp.float32)                  # (ps, Hkv, D)
        v = v_ref[0].astype(jnp.float32)
        if g > 1:
            k = jnp.repeat(k, g, axis=1)                  # (ps, Hq, D)
            v = jnp.repeat(v, g, axis=1)
        s = jnp.einsum("hd,phd->hp", q, k)                # (Hq, ps)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        Hq = q.shape[0]
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (Hq, ps), 1)
        mask = pos < length
        if window > 0:
            mask = jnp.logical_and(mask, pos > length - 1 - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # (Hq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.einsum("hp,phd->hd", p, v)
        m_ref[...] = m_new

    @pl.when(i == n_pages - 1)
    def _finalize():
        lsum = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / lsum).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, pages_k: jax.Array,
                           pages_v: jax.Array, page_table: jax.Array,
                           lengths: jax.Array, *, window: int = 0,
                           logit_softcap: float = 0.0,
                           interpret: Optional[bool] = None) -> jax.Array:
    """One-token attention over paged KV.  q: (B, Hq, D); pages_k/pages_v:
    (P, ps, Hkv, D); page_table: (B, n) int32; lengths: (B,) int32 valid
    token counts (>= 1 per request — see the oracle's contract).  Returns
    (B, Hq, D)."""
    B, Hq, D = q.shape
    P, ps, Hkv, _ = pages_k.shape
    n = page_table.shape[1]
    if Hq % Hkv:
        raise ValueError(f"GQA needs Hq % Hkv == 0, got {Hq}/{Hkv}")
    g = Hq // Hkv
    scale = D ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # page_table, lengths
        grid=(B, n),
        in_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, i, pt, ln: (b, 0, 0)),
            pl.BlockSpec((1, ps, Hkv, D),
                         lambda b, i, pt, ln: (pt[b, i], 0, 0, 0)),
            pl.BlockSpec((1, ps, Hkv, D),
                         lambda b, i, pt, ln: (pt[b, i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, i, pt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hq, D), jnp.float32),
            pltpu.VMEM((Hq, 1), jnp.float32),
            pltpu.VMEM((Hq, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_kernel, ps=ps, n_pages=n, window=window,
        softcap=logit_softcap, scale=scale, g=g)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        interpret=_auto_interpret(interpret),
    )(jnp.asarray(page_table, jnp.int32), jnp.asarray(lengths, jnp.int32),
      q, pages_k, pages_v)
