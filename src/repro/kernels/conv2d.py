"""Direct-convolution Pallas kernel — the paper's Algorithm 2 on TPU.

Paper (x86): 10-nested loop, cache blocking over (ifm, ofm), register
blocking over (out_h, out_w), SIMD over an ofm group of width SW, FMAs of a
broadcast input against a weight vector.

TPU adaptation (DESIGN.md §2):
  * layout NHWC / HWIO — the channel (lane) dim innermost, the TPU-native
    equivalent of the paper's ``N x C/SW x H x W x SW`` blocked layout;
  * blocking over (ifm, ofm) exactly as Algorithm 2 lines 2-3: the grid is
    (batch, ofm_blocks, ifm_blocks) and ``core.blocking.solve_conv_blocking``
    (the paper's §2.2 search) picks the channel block sizes under the VMEM
    budget;
  * the kh/kw loops become ``bofm x bifm`` MXU matmuls over shifted input
    windows — the broadcast-FMA of Algorithm 2 line 23 widened from an AVX2
    vector to a systolic contraction (register block -> resident output
    feature-map accumulator, revisited across ifm grid steps);
  * spatial dims stay whole inside the block: for ImageNet-scale CNN layers
    one (H_in, W_in, bifm) slab fits VMEM once the solver shrinks bifm
    (VGG-A conv1: 226*226*3*4B = 0.6 MiB).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.blocking import solve_conv_blocking


def _conv_kernel(x_ref, w_ref, o_ref, *, kernel: int, stride: int,
                 out_h: int, out_w: int):
    i_ifm = pl.program_id(2)

    @pl.when(i_ifm == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]            # (H_in, W_in, bifm)
    w = w_ref[...]          # (K, K, bifm, bofm)
    acc = o_ref[0]          # (OH, OW, bofm), accumulated across ifm steps
    for kh in range(kernel):        # Algorithm 2 lines 13-14 (kh/kw loops)
        for kw in range(kernel):
            xs = jax.lax.slice(
                x, (kh, kw, 0),
                (kh + (out_h - 1) * stride + 1,
                 kw + (out_w - 1) * stride + 1, x.shape[2]),
                (stride, stride, 1))              # (OH, OW, bifm)
            acc += jnp.dot(
                xs.reshape(out_h * out_w, -1), w[kh, kw],
                preferred_element_type=jnp.float32,
            ).reshape(out_h, out_w, -1)           # MXU 'broadcast-FMA'
    o_ref[0] = acc


def conv2d_nhwc(x: jax.Array, w: jax.Array, *, stride: int = 1,
                padding: int = 0,
                bifm: Optional[int] = None, bofm: Optional[int] = None,
                vmem_bytes: int = 8 * 2**20,
                interpret: bool = False) -> jax.Array:
    """x: (N, H, W, IFM), w: (K, K, IFM, OFM) -> (N, OH, OW, OFM), f32."""
    N, H, W, IFM = x.shape
    K, _, _, OFM = w.shape
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
        H, W = H + 2 * padding, W + 2 * padding
    OH = (H - K) // stride + 1
    OW = (W - K) // stride + 1
    if bifm is None or bofm is None:
        blk = solve_conv_blocking(1, IFM, OFM, OH, K, stride,
                                  cache_bytes=vmem_bytes,
                                  simd=min(128, OFM))
        bifm = bifm or blk.b_ifm
        bofm = bofm or blk.b_ofm
    bifm = max(1, min(bifm, IFM))
    bofm = max(1, min(bofm, OFM))
    while IFM % bifm:
        bifm -= 1
    while OFM % bofm:
        bofm -= 1
    grid = (N, OFM // bofm, IFM // bifm)
    out = pl.pallas_call(
        functools.partial(_conv_kernel, kernel=K, stride=stride,
                          out_h=OH, out_w=OW),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, W, bifm), lambda n, f, c: (n, 0, 0, c)),
            pl.BlockSpec((K, K, bifm, bofm), lambda n, f, c: (0, 0, c, f)),
        ],
        out_specs=pl.BlockSpec((1, OH, OW, bofm), lambda n, f, c: (n, 0, 0, f)),
        out_shape=jax.ShapeDtypeStruct((N, OH, OW, OFM), jnp.float32),
        interpret=interpret,
    )(x, w)
    return out
