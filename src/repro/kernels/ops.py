"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel bodies execute in Python/XLA for correctness validation; on TPU they
compile to Mosaic.  ``attention`` carries a ``jax.custom_vjp`` whose backward
pass is the pure-jnp reference gradient (recompute, no score materialization
in fwd) so the kernel is usable inside ``train_step``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.blocked_matmul import blocked_matmul
from repro.kernels.conv2d import conv2d_nhwc
from repro.kernels.flash_attention import flash_attention


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul(a: jax.Array, b: jax.Array, interpret: Optional[bool] = None):
    it = _on_cpu() if interpret is None else interpret
    return blocked_matmul(a, b, interpret=it)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "interpret"))
def conv2d(x: jax.Array, w: jax.Array, stride: int = 1, padding: int = 0,
           interpret: Optional[bool] = None):
    it = _on_cpu() if interpret is None else interpret
    return conv2d_nhwc(x, w, stride=stride, padding=padding, interpret=it)


# ---------------------------------------------------------------------------
# attention with kernel forward + reference backward
# ---------------------------------------------------------------------------
def _pad_seq(x, multiple):
    s = x.shape[1]
    pad = (-s) % multiple
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return x, pad


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def attention(q, k, v, causal: bool = True, window: int = 0,
              logit_softcap: float = 0.0):
    """Flash-attention kernel with GQA/SWA/softcap; (B,S,H,D) layout."""
    return _attention_fwd(q, k, v, causal, window, logit_softcap)[0]


def _attention_fwd(q, k, v, causal, window, logit_softcap):
    sq, skv = q.shape[1], k.shape[1]
    bq = min(128, sq)
    bkv = min(128, skv)
    if sq % bq or skv % bkv or (skv - sq) % 1:
        qp, pq = _pad_seq(q, bq)
        kp, pk = _pad_seq(k, bkv)
        vp, _ = _pad_seq(v, bkv)
    else:
        qp, kp, vp, pq, pk = q, k, v, 0, 0
    if pq or pk:
        # padded keys must be masked: right-aligned layout breaks with pads,
        # fall back to the reference for ragged shapes (rare in practice).
        out = ref.attention_ref(q, k, v, causal=causal, window=window,
                                logit_softcap=logit_softcap)
    else:
        out = flash_attention(qp, kp, vp, causal=causal, window=window,
                              logit_softcap=logit_softcap, bq=bq, bkv=bkv,
                              interpret=_on_cpu())
    return out, (q, k, v)


def _attention_bwd(causal, window, logit_softcap, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention_ref(
            q_, k_, v_, causal=causal, window=window,
            logit_softcap=logit_softcap), q, k, v)
    return vjp(g)


attention.defvjp(_attention_fwd, _attention_bwd)
