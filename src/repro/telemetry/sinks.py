"""Telemetry sinks: JSONL event stream + Chrome-trace exporter + merge.

Per-process layout under a ``--trace-dir``:

    trace_p<i>.jsonl   every event of cluster process i, one JSON per line
                       (written live by :class:`JsonlSink`; the last line
                       is the final metrics snapshot)
    trace.json         the merged Chrome trace — load it in
                       ``chrome://tracing`` or https://ui.perfetto.dev

Single-process runs merge their own lone file at ``Run.close``; cluster
runs leave the per-process files to the SUPERVISOR's merge
(``launch.cluster``) — workers cannot merge, they'd race each other.
Timestamps are per-process ``time.monotonic``; the merge rebases each
process to its own first event so the timelines align at 0 (cross-process
skew is not meaningful across monotonic clocks and is not implied).
"""
from __future__ import annotations

import glob
import json
import os
import re
from typing import Dict, List, Optional

_SPAN_META = ("kind", "ph", "t0", "t1", "dur", "depth")


def trace_path(trace_dir: str, process_index: int) -> str:
    return os.path.join(trace_dir, f"trace_p{process_index}.jsonl")


class JsonlSink:
    """Recorder listener that streams every event to a JSONL file.
    Line-buffered so a SIGKILLed worker loses at most one event; writes
    after ``close`` are dropped (the recorder may outlive the sink)."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "w", buffering=1)
        self._closed = False

    def __call__(self, ev: dict) -> None:
        if not self._closed:
            self._f.write(json.dumps(ev, separators=(",", ":")) + "\n")

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._f.close()


def read_jsonl(path: str) -> List[dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def to_chrome_events(events: List[dict], pid: int = 0,
                     name: Optional[str] = None,
                     ts_offset: Optional[float] = None) -> List[dict]:
    """Recorder events -> Chrome trace ``traceEvents`` (``ph: "X"``
    complete spans, ``ph: "i"`` instants; ``ts``/``dur`` in microseconds).
    ``ts_offset`` rebases timestamps (defaults to the earliest ``t0``)."""
    spans = [e for e in events if "t0" in e]
    if ts_offset is None:
        ts_offset = min((e["t0"] for e in spans), default=0.0)
    out = []
    if name:
        out.append({"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                    "args": {"name": name}})
    for e in spans:
        args = {k: v for k, v in e.items() if k not in _SPAN_META}
        ts = (e["t0"] - ts_offset) * 1e6
        if e.get("ph") == "span":
            # nested spans share tid 0 — Chrome stacks "X" events that
            # nest in time into the flame rows itself
            out.append({"name": e["kind"], "cat": "span", "ph": "X",
                        "ts": ts, "dur": e["dur"] * 1e6, "pid": pid,
                        "tid": 0, "args": args})
        else:
            out.append({"name": e["kind"], "cat": "instant", "ph": "i",
                        "ts": ts, "pid": pid, "tid": 0, "s": "p",
                        "args": args})
    return out


def write_chrome_trace(path: str,
                       events_by_pid: Dict[int, List[dict]],
                       names: Optional[Dict[int, str]] = None) -> str:
    trace_events = []
    for pid in sorted(events_by_pid):
        nm = (names or {}).get(pid)
        trace_events.extend(to_chrome_events(events_by_pid[pid], pid=pid,
                                             name=nm))
    with open(path, "w") as f:
        json.dump({"traceEvents": trace_events, "displayTimeUnit": "ms"}, f)
    return path


def merge_process_traces(trace_dir: str,
                         out_name: str = "trace.json") -> Optional[str]:
    """Merge every ``trace_p*.jsonl`` in ``trace_dir`` into one Chrome
    trace (one pid per cluster process).  Returns the merged path, or
    ``None`` when no per-process files exist yet."""
    files = sorted(glob.glob(os.path.join(trace_dir, "trace_p*.jsonl")))
    if not files:
        return None
    by_pid: Dict[int, List[dict]] = {}
    names: Dict[int, str] = {}
    for path in files:
        m = re.search(r"trace_p(\d+)\.jsonl$", path)
        pid = int(m.group(1)) if m else len(by_pid)
        events = read_jsonl(path)
        by_pid[pid] = events
        meta = next((e for e in events if e.get("kind") == "meta"), {})
        names[pid] = f"{meta.get('process', 'proc')}[{pid}]"
    return write_chrome_trace(os.path.join(trace_dir, out_name),
                              by_pid, names)
