"""``comm="auto"``: close the §3.2 loop with MEASURED comm constants.

The balance model (``core.balance``) predicts the optimal fusion-buffer
size as ``b* = sqrt(B * SWlat * BW * G)`` — but until now SWlat/BW came
from the ``backend_hw`` hardware table.  Here they are measured: before
the optimizer strips are laid out (the ZeRO-1 state layout depends on the
bucket plan, so the size must be fixed BEFORE ``init_fn`` — see
``checkpoint.replan``, which refuses mid-run bucket changes), the
autotuner drives the run's REAL collective schedule over the run's REAL
mesh with per-bucket roundtrips at several candidate bucket sizes, fits

    t(bucket) = 2*(G-1)*SWlat + 2*(G-1)/G * bytes/BW

by least squares over the timed samples (``ring_collective_time``'s exact
form), and hands the fitted constants to ``optimal_bucket_bytes``.  Each
timed roundtrip is recorded as a ``collective`` telemetry span and the
chosen plan as an ``autotune_plan`` event, so the decision is auditable in
the trace.

The probe buffers are dummies in the wire dtype — only shapes matter for
timing — and every bucket's roundtrip goes through one shared jitted
function, so XLA compiles once per DISTINCT padded size, not per bucket.
In multi-process runs every process probes in lockstep (same deterministic
plan); the per-sample times are allgathered and averaged so every process
fits identical constants and picks the SAME plan — divergent bucket plans
across members would deadlock the first real collective.

The probes time the DENSE fp32 roundtrip; compressed wire formats
(``CommConfig.wire_format``) are then predicted analytically from the
fitted (SWlat, BW) via ``core.balance``'s bytes-on-wire models, and the
winner is the jointly-best (backend, wire_format, bucket_bytes) triple.
``topk`` is never auto-chosen — it is lossy AND stateful (error-feedback
residual in the optimizer state), so it stays an explicit opt-in.

When ``cache_path`` is set (the cluster launcher exports
``ENV_AUTOTUNE_CACHE`` pointing into the run dir), the chosen plan is
persisted keyed by the probe inputs (group size, axes, gradient bytes,
candidate sets) and an elastic relaunch with the SAME key skips the probe
entirely; a world-size change misses the key and re-probes — the
elastic supervisor also deletes the file outright on shrink/grow so stale
ring constants can never leak across a topology change.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm.bucketer import CommConfig, plan_buckets
from repro.comm.schedule import group_axes, make_schedule
from repro.configs.base import HardwareConfig
from repro.core.balance import optimal_bucket_bytes, wire_reduce_factor

# clamps for degenerate fits (a 1-member group, or noise driving the least
# squares negative): keep the constants positive and finite so the closed
# form — and the JSON the plan event serializes to — stay well-defined
MIN_LATENCY_S = 1e-9
MAX_BANDWIDTH = 1e15

# env var the cluster launcher sets on every worker: path of the per-run
# autotune plan cache (see module docstring)
ENV_AUTOTUNE_CACHE = "REPRO_AUTOTUNE_CACHE"


@dataclass(frozen=True)
class CommProbe:
    """One timed roundtrip of one fusion buffer."""
    nbytes: int          # wire bytes of the bucket (padded_size * itemsize)
    seconds: float       # best-of-reps wall time of reduce+broadcast
    backend: str


def measured_hw(sw_latency: float, link_bw: float,
                name: str = "measured") -> HardwareConfig:
    """A ``HardwareConfig`` carrying MEASURED comm constants — the compute
    fields are placeholders (the bucket optimum never reads them)."""
    return HardwareConfig(name=name, peak_flops=1.0, mem_bw=1.0,
                          link_bw=max(link_bw, 1.0),
                          sw_latency=max(sw_latency, MIN_LATENCY_S))


def fit_comm_model(probes: Sequence[CommProbe],
                   G: int) -> Tuple[float, float]:
    """Least-squares (SWlat, BW) from per-bucket roundtrip times under the
    §3.2 ring model ``t = 2*(G-1)*SWlat + 2*(G-1)/G * nbytes/BW``.

    Exact on a synthetic table generated from the model (tested); on real
    measurements the clamps keep a noisy fit physical."""
    if G <= 1 or not probes:
        return MIN_LATENCY_S, MAX_BANDWIDTH
    A = np.array([[2.0 * (G - 1), 2.0 * (G - 1) / G * p.nbytes]
                  for p in probes])
    y = np.array([p.seconds for p in probes])
    sol, *_ = np.linalg.lstsq(A, y, rcond=None)
    lat = float(max(sol[0], MIN_LATENCY_S))
    inv_bw = float(max(sol[1], 1.0 / MAX_BANDWIDTH))
    return lat, min(1.0 / inv_bw, MAX_BANDWIDTH)


def choose_bucket_bytes(total_bytes: int, G: int, sw_latency: float,
                        link_bw: float, wire_format: str = "fp32",
                        topk_ratio: float = 0.05) -> int:
    """``optimal_bucket_bytes`` with measured constants in place of the
    ``backend_hw`` table (G<=1 degenerates to one whole-tree bucket).
    ``wire_format`` applies the bytes-on-wire factor — a compressed reduce
    wire amortizes the latency term over a larger optimal bucket."""
    b = optimal_bucket_bytes(float(total_bytes), G,
                             measured_hw(sw_latency, link_bw),
                             wire_format=wire_format, topk_ratio=topk_ratio)
    return max(1, int(b))


def _cache_key(G, axes, total_bytes, backends, wire_formats) -> dict:
    return {"G": int(G), "axes": list(axes),
            "total_bytes": int(total_bytes),
            "backends": list(backends), "wire_formats": list(wire_formats)}


def _load_cached_plan(path: str, key: dict):
    """The persisted plan, or None on any miss (absent/corrupt/other key)."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return None
    return data.get("plan") if data.get("key") == key else None


def _save_cached_plan(path: str, key: dict, plan: dict) -> None:
    """Atomic write (tmp + rename) — co-located workers may race."""
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "w") as f:
            json.dump({"key": key, "plan": plan}, f, indent=1)
        os.replace(tmp, path)
    except OSError:
        pass  # an unwritable cache just means re-probing next launch


def _probe_sizes(params, G: int, total_bytes: int,
                 itemsize: int, max_sizes: int = 6) -> List[int]:
    """Distinct padded bucket sizes (elements) across a ladder of candidate
    bucket byte-sizes — the model needs >= 2 distinct message sizes to
    separate the latency and bandwidth terms, so a degenerate tree (one
    big tensor) gets a synthetic small buffer added."""
    sizes = set()
    for divisor in (16, 4, 1):
        cand = max(total_bytes // divisor, itemsize)
        for b in plan_buckets(params, G, cand).buckets:
            sizes.add(b.padded_size)
    if len(sizes) < 2:
        # ~1/32 of the largest buffer, rounded up to a multiple of G (the
        # padding contract every real bucket obeys)
        small = max(-(-(max(sizes) // 32) // G) * G, G)
        sizes.add(small)
    ranked = sorted(sizes)
    if len(ranked) > max_sizes:
        idx = np.linspace(0, len(ranked) - 1, max_sizes).astype(int)
        ranked = [ranked[i] for i in sorted(set(idx.tolist()))]
    return ranked


def _roundtrip_fn(mesh, axis_arg, base: CommConfig, backend: str, G: int):
    """One jitted replicated-in/replicated-out reduce+broadcast roundtrip —
    the exact wire path ``optim.dist.UpdatePlan`` drives, minus the
    optimizer.  ``step=0`` binds the step-scheduled backends (gossip)."""
    wire = base.wire_dtype
    sched = make_schedule(axis_arg, base.hierarchical, backend,
                          base.cross_backend, step=0)

    def rt(buf):
        return sched.broadcast(sched.reduce(buf, wire) / G)

    return jax.jit(jax.shard_map(rt, mesh=mesh, in_specs=(P(),),
                                 out_specs=P(), check_vma=False))


def _time_backend(mesh, axis_arg, base: CommConfig, backend: str, G: int,
                  sizes: Sequence[int], reps: int, recorder,
                  clock=time.perf_counter) -> List[CommProbe]:
    """Best-of-``reps`` roundtrip time per buffer size on one backend;
    every timed rep is a ``collective`` telemetry span."""
    wire = base.wire_dtype
    itemsize = np.dtype(wire).itemsize
    fn = _roundtrip_fn(mesh, axis_arg, base, backend, G)
    probes = []
    with jax.set_mesh(mesh):
        for n in sizes:
            buf = jnp.zeros((int(n),), wire)
            jax.block_until_ready(fn(buf))          # compile outside timing
            best = float("inf")
            for r in range(reps):
                with recorder.span("collective", phase="autotune-probe",
                                   backend=backend, elements=int(n),
                                   nbytes=int(n) * itemsize, rep=r):
                    t0 = clock()
                    jax.block_until_ready(fn(buf))
                    best = min(best, clock() - t0)
            probes.append(CommProbe(nbytes=int(n) * itemsize,
                                    seconds=best, backend=backend))
    return probes


def _sync_times(probes: List[CommProbe]) -> List[CommProbe]:
    """Average each probe's time across cluster processes so every member
    fits the same constants (identical plans or the group deadlocks)."""
    if jax.process_count() <= 1:
        return probes
    from jax.experimental import multihost_utils
    times = np.array([p.seconds for p in probes], np.float64)
    gathered = multihost_utils.process_allgather(times)
    mean = np.asarray(gathered).reshape(jax.process_count(), -1).mean(0)
    return [dataclasses.replace(p, seconds=float(t))
            for p, t in zip(probes, mean)]


def autotune_comm(params, mesh, data_axes, base: CommConfig,
                  recorder=None, backends: Optional[Sequence[str]] = None,
                  reps: int = 2, log=print,
                  wire_formats: Optional[Sequence[str]] = None,
                  cache_path: Optional[str] = None) -> CommConfig:
    """Measure, fit, choose: returns ``base`` with ``bucket_bytes`` (and
    possibly ``backend`` / ``wire_format``) replaced by the jointly
    optimal measured plan.

    ``backends`` is the candidate set (the mode's ``MODE_CAPS.backends``);
    ``base.backend`` is always probed first and is the fallback when an
    alternative fails to build or run on this mesh.  ``wire_formats`` is
    the mode's wire-format capability set; ``topk`` is filtered out (lossy
    AND stateful — explicit opt-in only, see module docstring).
    ``cache_path`` short-circuits the probe when a persisted plan's key
    matches this launch."""
    from repro.telemetry.events import NULL_RECORDER
    recorder = recorder if recorder is not None else NULL_RECORDER
    axes, axis_arg, G = group_axes(mesh, data_axes)
    wire_itemsize = np.dtype(base.wire_dtype).itemsize
    leaves = jax.tree.leaves(params)
    total_bytes = sum(leaf.size for leaf in leaves) * wire_itemsize
    sizes = _probe_sizes(params, G, total_bytes, wire_itemsize)

    candidates = [base.backend]
    for b in backends or ():
        if b not in candidates:
            candidates.append(b)
    formats = [base.wire_format]
    for fmt in wire_formats or ():
        if fmt != "topk" and fmt not in formats:
            formats.append(fmt)

    key = _cache_key(G, axes, total_bytes, candidates, formats)
    if cache_path:
        plan = _load_cached_plan(cache_path, key)
        if plan is not None:
            comm = dataclasses.replace(
                base, bucket_bytes=int(plan["bucket_bytes"]),
                backend=plan["chosen_backend"],
                wire_format=plan["chosen_wire_format"])
            recorder.event("autotune_plan", group=G, cached=True,
                           total_bytes=int(total_bytes), probes=0, **plan)
            log(f"comm=auto: cached plan ({cache_path}) -> "
                f"bucket_bytes={comm.bucket_bytes} backend={comm.backend} "
                f"wire_format={comm.wire_format}")
            return comm

    fits = {}
    all_probes: List[CommProbe] = []
    for backend in candidates:
        try:
            probes = _sync_times(_time_backend(
                mesh, axis_arg, base, backend, G, sizes, reps, recorder))
        except Exception as e:  # an alt backend that can't run here is
            #                     skipped, not fatal — base always works
            if backend == base.backend:
                raise
            log(f"comm=auto: backend {backend!r} probe failed "
                f"({type(e).__name__}: {e}); skipping")
            continue
        all_probes.extend(probes)
        fits[backend] = fit_comm_model(probes, G)

    # joint choice: for each surviving backend's fitted constants, predict
    # the step wire time of every candidate format at ITS OWN optimal
    # bucket — compressed formats shrink only the reduce side (the weight
    # all-gather stays dense fp32, see core.balance.compressed_allreduce_time)
    plans = {}
    for backend, (lat, bw) in fits.items():
        for fmt in formats:
            b_star = choose_bucket_bytes(total_bytes, G, lat, bw,
                                         wire_format=fmt,
                                         topk_ratio=base.topk_ratio)
            n_coll = plan_buckets(params, G, b_star).n_collectives
            f = wire_reduce_factor(fmt, base.topk_ratio)
            t_pred = (n_coll * 2.0 * (G - 1) * lat
                      + (G - 1) / G * (1.0 + f) * total_bytes / bw) \
                if G > 1 else 0.0
            plans[(backend, fmt)] = {
                "sw_latency_s": lat, "link_bw_Bps": bw,
                "bucket_bytes": b_star, "n_collectives": n_coll,
                "predicted_s": t_pred}

    winner = min(plans, key=lambda k: (plans[k]["predicted_s"],
                                       k[0] != base.backend,
                                       k[1] != base.wire_format))
    w_backend, w_fmt = winner
    chosen = dict(plans[winner], chosen_backend=w_backend,
                  chosen_wire_format=w_fmt)
    comm = dataclasses.replace(base, bucket_bytes=chosen["bucket_bytes"],
                               backend=w_backend, wire_format=w_fmt)
    if cache_path:
        _save_cached_plan(cache_path, key, chosen)
    recorder.event("autotune_plan", group=G, total_bytes=int(total_bytes),
                   probes=len(all_probes), backends=list(fits),
                   wire_formats=list(formats), **chosen)
    log(f"comm=auto: G={G} measured SWlat={chosen['sw_latency_s']:.2e}s "
        f"BW={chosen['link_bw_Bps'] / 2 ** 30:.2f}GiB/s over "
        f"{len(all_probes)} collective probes -> "
        f"bucket_bytes={chosen['bucket_bytes']} "
        f"({chosen['bucket_bytes'] / 2 ** 20:.2f}MiB, "
        f"{chosen['n_collectives']} collectives) backend={w_backend} "
        f"wire_format={w_fmt}")
    return comm
