"""Typed span/event recorder with monotonic timestamps.

The repo's phases — step, data-wait, compile, ckpt-write, per-bucket
collective, serve prefill/decode/preempt — each become a SPAN: a dict
``{"kind", "ph": "span", "t0", "t1", "dur", "depth", **attrs}`` stamped
from ``time.monotonic()`` (never wall clock: spans must survive NTP jumps,
which is also why the cluster heartbeat rides these events — see the
``cluster.elastic`` staleness fix).  Instant events use ``"ph": "instant"``.

Listeners are the fan-out: sinks (``telemetry.sinks.JsonlSink``), the
cluster heartbeat writer, and tests all attach with ``add_listener`` and
see every completed event.  Span DURATIONS auto-feed a histogram per kind
(``hist("span/<kind>_s")``), so p50/p99 per phase come for free.

``NULL_RECORDER`` is the no-op default: untraced code paths pay one
attribute lookup and a constant context manager — no allocation, no
timestamp read.
"""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, List, Optional

from repro.telemetry.metrics import (
    NULL_HISTOGRAM,
    Counter,
    Gauge,
    Histogram,
    metrics_summary,
)

# the cluster process index env var (repro.cluster.spec.ClusterSpec.env);
# read directly so telemetry stays importable without the cluster package
ENV_PROCESS_ID = "REPRO_PROCESS_ID"


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullRecorder:
    """The do-nothing recorder: every method is a constant-cost no-op, so
    library code can thread ``recorder.span(...)`` unconditionally."""
    __slots__ = ()
    enabled = False
    sync = False
    trace_dir = None
    process_index = 0

    def span(self, kind: str, **attrs):
        return _NULL_SPAN

    def event(self, kind: str, **attrs) -> None:
        pass

    def count(self, name: str, n: int = 1) -> None:
        pass

    def gauge(self, name: str, v: float) -> None:
        pass

    def hist(self, name: str):
        return NULL_HISTOGRAM

    def add_listener(self, fn: Callable) -> None:
        pass

    def close(self) -> None:
        pass


NULL_RECORDER = NullRecorder()


class _Span:
    """One open span; a context manager handed out by ``Recorder.span``."""
    __slots__ = ("rec", "kind", "attrs", "t0")

    def __init__(self, rec: "Recorder", kind: str, attrs: dict):
        self.rec = rec
        self.kind = kind
        self.attrs = attrs
        self.t0 = 0.0

    def __enter__(self) -> "_Span":
        self.t0 = self.rec._clock()
        self.rec._stack.append(self)
        return self

    def __exit__(self, *exc) -> bool:
        self.rec._finish_span(self)
        return False


class Recorder:
    """Collects completed events, notifies listeners, and aggregates
    metrics.  ``clock`` is injectable for deterministic tests.

    ``keep_events=False`` bounds memory for long runs: listeners and
    histograms still see everything, only the in-process ``events`` list
    stays empty.  ``sync`` is advisory — the trainer blocks on each step's
    result when set, trading async dispatch for honest span durations
    (set by ``make_recorder`` iff a trace is being written)."""
    enabled = True

    def __init__(self, process: str = "main", process_index: int = 0,
                 clock: Callable[[], float] = time.monotonic,
                 keep_events: bool = True, sync: bool = False):
        self.process = process
        self.process_index = process_index
        self.events: List[dict] = []
        self.trace_dir: Optional[str] = None
        self.sync = sync
        self._clock = clock
        self._keep = keep_events
        self._stack: List[_Span] = []
        self._listeners: List[Callable] = []
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}
        self._closed = False

    # -- spans / events ------------------------------------------------
    def span(self, kind: str, **attrs) -> _Span:
        return _Span(self, kind, attrs)

    def _finish_span(self, span: _Span) -> None:
        t1 = self._clock()
        # LIFO pop; tolerate out-of-order exits rather than corrupting depth
        if self._stack and self._stack[-1] is span:
            self._stack.pop()
        elif span in self._stack:
            self._stack.remove(span)
        ev = {"kind": span.kind, "ph": "span", "t0": span.t0, "t1": t1,
              "dur": t1 - span.t0, "depth": len(self._stack)}
        ev.update(span.attrs)
        self.hist(f"span/{span.kind}_s").observe(ev["dur"])
        self._emit(ev)

    def event(self, kind: str, **attrs) -> None:
        ev = {"kind": kind, "ph": "instant", "t0": self._clock()}
        ev.update(attrs)
        self._emit(ev)

    def _emit(self, ev: dict) -> None:
        if self._keep:
            self.events.append(ev)
        for fn in self._listeners:
            fn(ev)

    # -- metrics -------------------------------------------------------
    def count(self, name: str, n: int = 1) -> None:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        c.add(n)

    def gauge(self, name: str, v: float) -> None:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        g.set(v)

    def hist(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram()
        return h

    def metrics(self) -> dict:
        return metrics_summary(self._counters, self._gauges, self._hists)

    # -- lifecycle -----------------------------------------------------
    def add_listener(self, fn: Callable) -> None:
        self._listeners.append(fn)

    def close(self) -> None:
        """Emit the final metrics snapshot and close closable listeners.
        Idempotent — a second close is a no-op."""
        if self._closed:
            return
        self._closed = True
        self.event("metrics", **self.metrics())
        for fn in self._listeners:
            closer = getattr(fn, "close", None)
            if closer is not None:
                closer()


def make_recorder(tspec=None, process: str = "train") -> Recorder:
    """Build the recorder for one run from a ``TelemetrySpec`` (or None).

    Always a LIVE recorder — event listeners (the cluster heartbeat) must
    work untraced — but without ``trace_dir`` nothing touches disk and the
    event list is left unbounded only for traced runs.  The process index
    comes from the cluster env (``REPRO_PROCESS_ID``) so per-process trace
    files never collide in multi-host runs."""
    idx = int(os.environ.get(ENV_PROCESS_ID, "0") or "0")
    trace_dir = getattr(tspec, "trace_dir", None)
    rec = Recorder(process=process, process_index=idx,
                   keep_events=bool(trace_dir), sync=bool(trace_dir))
    if trace_dir:
        from repro.telemetry.sinks import JsonlSink, trace_path
        os.makedirs(trace_dir, exist_ok=True)
        rec.trace_dir = trace_dir
        rec.add_listener(JsonlSink(trace_path(trace_dir, idx)))
        rec.event("meta", process=process, process_index=idx,
                  pid=os.getpid(), clock="monotonic")
    return rec
