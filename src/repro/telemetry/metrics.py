"""Counters / gauges / histograms for the telemetry subsystem.

The paper's analysis (§3.1–3.2) is about DISTRIBUTIONS of per-phase times,
not single samples — exposed communication is a tail phenomenon.  So the
aggregation primitive here is a reservoir-free histogram that keeps raw
observations (runs are short enough that exact percentiles beat bucketed
approximations) and computes numpy-convention percentiles, which is what
``Server.latency_stats`` and ``benchmarks/serve_load.py`` report instead of
re-sorting request lists by hand.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


class Counter:
    """Monotonically increasing count (steps, tokens, preemptions...)."""
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def add(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value (active slots, free pages...)."""
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Exact-sample histogram: keeps every observation and answers
    numpy-convention percentiles (linear interpolation — the same numbers
    ``np.percentile`` gives, asserted in tests/test_telemetry.py)."""
    __slots__ = ("_values",)

    def __init__(self) -> None:
        self._values: List[float] = []

    def observe(self, v: float) -> None:
        self._values.append(float(v))

    @property
    def count(self) -> int:
        return len(self._values)

    @property
    def total(self) -> float:
        return float(sum(self._values))

    def percentile(self, p: float) -> Optional[float]:
        if not self._values:
            return None
        return float(np.percentile(np.asarray(self._values), p))

    def summary(self) -> dict:
        """{count, mean, p50, p99, max} — ``None`` stats when empty."""
        if not self._values:
            return {"count": 0, "mean": None, "p50": None, "p99": None,
                    "max": None}
        a = np.asarray(self._values)
        return {"count": int(a.size), "mean": float(a.mean()),
                "p50": float(np.percentile(a, 50)),
                "p99": float(np.percentile(a, 99)),
                "max": float(a.max())}


class NullHistogram:
    """The no-op twin ``NullRecorder.hist`` hands out: observing costs one
    attribute lookup and a pass."""
    __slots__ = ()
    count = 0
    total = 0.0

    def observe(self, v: float) -> None:
        pass

    def percentile(self, p: float) -> Optional[float]:
        return None

    def summary(self) -> dict:
        return {"count": 0, "mean": None, "p50": None, "p99": None,
                "max": None}


NULL_HISTOGRAM = NullHistogram()


def metrics_summary(counters: Dict[str, Counter],
                    gauges: Dict[str, Gauge],
                    histograms: Dict[str, Histogram]) -> dict:
    """One JSON-ready snapshot of every metric a recorder accumulated —
    the final line of the JSONL sink."""
    return {
        "counters": {k: c.value for k, c in sorted(counters.items())},
        "gauges": {k: g.value for k, g in sorted(gauges.items())},
        "histograms": {k: h.summary()
                       for k, h in sorted(histograms.items())},
    }
