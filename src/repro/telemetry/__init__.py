"""Structured telemetry: spans/counters -> sinks -> the autotune loop.

``events`` records, ``sinks`` persist (JSONL + Chrome trace), ``metrics``
aggregate (p50/p99 histograms), and ``autotune`` closes the loop — feeding
measured per-bucket collective times back into the §3.2 balance model that
picks the fusion-buffer size (``RunSpec.comm="auto"``)."""
from repro.telemetry.autotune import (
    CommProbe,
    autotune_comm,
    choose_bucket_bytes,
    fit_comm_model,
    measured_hw,
)
from repro.telemetry.events import (
    NULL_RECORDER,
    NullRecorder,
    Recorder,
    make_recorder,
)
from repro.telemetry.metrics import Counter, Gauge, Histogram
from repro.telemetry.sinks import (
    JsonlSink,
    merge_process_traces,
    read_jsonl,
    to_chrome_events,
    trace_path,
    write_chrome_trace,
)

__all__ = [
    "CommProbe", "autotune_comm", "choose_bucket_bytes", "fit_comm_model",
    "measured_hw", "NULL_RECORDER", "NullRecorder", "Recorder",
    "make_recorder", "Counter", "Gauge", "Histogram", "JsonlSink",
    "merge_process_traces", "read_jsonl", "to_chrome_events", "trace_path",
    "write_chrome_trace",
]
