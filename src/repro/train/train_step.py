"""train_step assembly: loss -> grads -> synchronous-SGD update.

Two equivalent realizations of the paper's §3.4 update:

  * ``make_train_step`` (production, pjit/GSPMD): the batch is sharded over
    the data axes, so the gradient all-reduce is implicit; when
    ``zero1=True`` the optimizer state is sharded over the data axes and XLA
    factorizes the all-reduce into reduce-scatter (part-reduce) + all-gather
    (part-broadcast) around the update — the paper's exact schedule.
  * ``optim.dist.make_distributed_update`` (explicit shard_map, bucketed
    through ``repro.comm``) — used in examples/tests; equivalence is
    property-tested.  Passing its ``update_fn`` as ``dist_update`` below
    routes the whole ZeRO-1 train step through the bucketed fusion-buffer
    collectives instead of the serial ``optimizer.update``.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.sharding import ShardingRules


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def make_train_step(loss_fn: Callable, optimizer, lr_schedule,
                    grad_clip: float = 1.0,
                    dist_update: Optional[Callable] = None):
    """loss_fn(params, batch) -> scalar loss.  Returns
    step(params, opt_state, step_idx, batch) -> (params, opt_state, metrics).

    ``dist_update`` (optional): an explicit distributed update
    ``(params, grads, opt_state, lr) -> (new_params, new_opt_state)`` — the
    ``update_fn`` built by ``optim.dist.make_distributed_update`` — replacing
    the serial ``optimizer.update``.  This is the explicit ZeRO-1 path: the
    step's gradients flow through the bucketed part-reduce, the strip
    optimizer, and the bucketed part-broadcast of ``repro.comm``.  The
    matching ``opt_state`` must come from the same builder's ``init_fn``.
    """
    def train_step(params, opt_state, step_idx, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        gnorm = global_norm(grads)
        if grad_clip > 0:
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        lr = lr_schedule(step_idx)
        if dist_update is not None:
            new_params, new_state = dist_update(params, grads, opt_state, lr)
        else:
            new_params, new_state = optimizer.update(grads, opt_state,
                                                     params, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_params, new_state, metrics

    return train_step


def zero1_state_shardings(opt_state, param_axes, mesh: Mesh,
                          rules: ShardingRules):
    """ZeRO-1 (the paper's strip scheme via GSPMD): optimizer-state tensors
    take the param sharding PLUS 'data' on the first dim that is unsharded
    and divisible — gradients then arrive by reduce-scatter and the updated
    params leave by all-gather."""
    def one(s, axes):
        if getattr(s, "ndim", 0) == 0:
            return NamedSharding(mesh, P())
        spec = list(rules.spec(axes, s.shape, mesh))
        spec += [None] * (s.ndim - len(spec))
        used = set()
        for entry in spec:
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                if a is not None:
                    used.add(a)
        extra = tuple(a for a in ("pod", "data")
                      if a in mesh.axis_names and a not in used)
        extent = 1
        for a in extra:
            extent *= mesh.shape[a]
        if extra and extent > 1:
            for i, (ax, dim) in enumerate(zip(spec, s.shape)):
                if ax is None and dim % extent == 0:
                    spec[i] = extra if len(extra) > 1 else extra[0]
                    break
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    # opt_state mirrors the param tree per field (mu/nu/velocity) + scalars;
    # match leaves to param axes cyclically (field trees flatten in the same
    # order as the param tree), skipping scalars
    flat_axes = jax.tree.leaves(
        param_axes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))

    leaves, treedef = jax.tree.flatten(opt_state)
    # state fields repeat the param tree: match cyclically by shape count
    out = []
    n = len(flat_axes)
    pi = 0
    for leaf in leaves:
        if getattr(leaf, "ndim", 0) == 0:
            out.append(NamedSharding(mesh, P()))
            continue
        out.append(one(leaf, flat_axes[pi % n]))
        pi += 1
    return jax.tree.unflatten(treedef, out)
