"""train_step assembly: loss -> grads -> synchronous-SGD update.

Two equivalent realizations of the paper's §3.4 update:

  * ``make_train_step`` (production, pjit/GSPMD): the batch is sharded over
    the data axes, so the gradient all-reduce is implicit; when
    ``zero1=True`` the optimizer state is sharded over the data axes and XLA
    factorizes the all-reduce into reduce-scatter (part-reduce) + all-gather
    (part-broadcast) around the update — the paper's exact schedule.
  * ``optim.dist.make_distributed_update`` (explicit shard_map, bucketed
    through ``repro.comm``) — used in examples/tests; equivalence is
    property-tested.  Passing its ``update_fn`` as ``dist_update`` below
    routes the whole ZeRO-1 train step through the bucketed fusion-buffer
    collectives instead of the serial ``optimizer.update``.

``make_overlapped_train_step`` is the third realization — the paper's §3.1
overlap schedule: the whole step runs inside one shard_map and each gradient
bucket's part-reduce is issued INSIDE the backward pass (repro.comm.overlap)
instead of after ``value_and_grad`` returns.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.sharding import ShardingRules


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def make_train_step(loss_fn: Callable, optimizer, lr_schedule,
                    grad_clip: float = 1.0,
                    dist_update: Optional[Callable] = None):
    """loss_fn(params, batch) -> scalar loss.  Returns
    step(params, opt_state, step_idx, batch) -> (params, opt_state, metrics).

    ``dist_update`` (optional): an explicit distributed update
    ``(params, grads, opt_state, lr, step) -> (new_params, new_opt_state)``
    — the ``update_fn`` built by ``optim.dist.make_distributed_update`` /
    ``make_stale_sync_update`` — replacing the serial ``optimizer.update``.
    This is the explicit ZeRO-1 path: the step's gradients flow through the
    bucketed part-reduce, the strip optimizer, and the bucketed
    part-broadcast of ``repro.comm``.  ``step_idx`` is forwarded so
    step-scheduled modes (the gossip partner rotation, the staleness carry)
    see the train step; step-free modes ignore it.  The matching
    ``opt_state`` must come from the same builder's ``init_fn``.
    """
    def train_step(params, opt_state, step_idx, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        gnorm = global_norm(grads)
        if grad_clip > 0:
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        lr = lr_schedule(step_idx)
        if dist_update is not None:
            new_params, new_state = dist_update(params, grads, opt_state, lr,
                                                step_idx)
        else:
            new_params, new_state = optimizer.update(grads, opt_state,
                                                     params, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_params, new_state, metrics

    return train_step


def make_overlapped_train_step(loss_fn: Callable, lr_schedule,
                               mesh: Mesh, data_axes, comm,
                               local_update: Callable,
                               grad_clip: float = 1.0):
    """The §3.1 backprop-overlapped realization of the explicit ZeRO-1 step.

    The WHOLE step — local loss, hooked backprop, strip optimizer,
    part-broadcast — runs inside one ``shard_map`` over the data axes: each
    member computes the loss of ITS batch shard, and every gradient
    bucket's part-reduce is issued in the backward pass the moment the
    bucket's last leaf gradient materializes (``repro.comm.overlap``), so
    the compiler may hide it under the remaining backprop instead of
    serializing the whole tree reduction after ``value_and_grad``.

    ``loss_fn(params, batch)`` must be the mesh-free (serial-ctx) loss:
    inside shard_map every member's compute is local, so GSPMD sharding
    constraints do not apply.  ``local_update`` comes from
    ``optim.dist.make_overlapped_update`` (same comm config).  Matches
    ``make_train_step`` — loss, clip, metrics — to float tolerance;
    property-tested in tests/test_distributed.py.
    """
    from repro.comm.overlap import make_overlap_grad
    from repro.comm.schedule import group_axes

    _, axis_arg, G = group_axes(mesh, data_axes)
    overlap_grad = make_overlap_grad(loss_fn, axis_arg, comm, G)

    def local_step(params, opt_state, step_idx, batch):
        loss, g_strips = overlap_grad(params, batch)
        loss = lax.psum(loss, axis_arg) / G
        # global grad norm from the reduced strips: every element of the
        # mean gradient lives in exactly one member's strip (bucket padding
        # is zeros), so the psum of local square-sums is the full norm²
        sq = sum(jnp.sum(jnp.square(s)) for s in g_strips)
        gnorm = jnp.sqrt(lax.psum(sq, axis_arg))
        if grad_clip > 0:
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
            g_strips = [g * scale for g in g_strips]
        lr = lr_schedule(step_idx)
        new_params, new_state = local_update(params, g_strips, opt_state, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_params, new_state, metrics

    def train_step(params, opt_state, step_idx, batch):
        pspec = jax.tree.map(lambda _: P(), params)
        sspec = jax.tree.map(
            lambda s: P(axis_arg) if getattr(s, "ndim", 0) >= 2 else P(),
            opt_state)
        bspec = jax.tree.map(lambda _: P(axis_arg), batch)
        mspec = {"loss": P(), "grad_norm": P(), "lr": P()}
        fn = jax.shard_map(
            local_step, mesh=mesh,
            in_specs=(pspec, sspec, P(), bspec),
            out_specs=(pspec, sspec, mspec),
            check_vma=False)
        return fn(params, opt_state, step_idx, batch)

    return train_step


def zero1_state_shardings(opt_state, param_axes, mesh: Mesh,
                          rules: ShardingRules):
    """ZeRO-1 (the paper's strip scheme via GSPMD): optimizer-state tensors
    take the param sharding PLUS 'data' on the first dim that is unsharded
    and divisible — gradients then arrive by reduce-scatter and the updated
    params leave by all-gather."""
    def one(s, axes):
        if getattr(s, "ndim", 0) == 0:
            return NamedSharding(mesh, P())
        spec = list(rules.spec(axes, s.shape, mesh))
        spec += [None] * (s.ndim - len(spec))
        used = set()
        for entry in spec:
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                if a is not None:
                    used.add(a)
        extra = tuple(a for a in ("pod", "data")
                      if a in mesh.axis_names and a not in used)
        extent = 1
        for a in extra:
            extent *= mesh.shape[a]
        if extra and extent > 1:
            for i, (ax, dim) in enumerate(zip(spec, s.shape)):
                if ax is None and dim % extent == 0:
                    spec[i] = extra if len(extra) > 1 else extra[0]
                    break
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    # opt_state mirrors the param tree per field (mu/nu/velocity) + scalars;
    # match leaves to param axes cyclically (field trees flatten in the same
    # order as the param tree), skipping scalars
    flat_axes = jax.tree.leaves(
        param_axes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))

    leaves, treedef = jax.tree.flatten(opt_state)
    # state fields repeat the param tree: match cyclically by shape count
    out = []
    n = len(flat_axes)
    pi = 0
    for leaf in leaves:
        if getattr(leaf, "ndim", 0) == 0:
            out.append(NamedSharding(mesh, P()))
            continue
        out.append(one(leaf, flat_axes[pi % n]))
        pi += 1
    return jax.tree.unflatten(treedef, out)
