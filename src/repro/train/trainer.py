"""Training loop: metrics, timing, periodic checkpointing.

The paper's framework design (§4) separates data handling, compute and
communication; here the data pipeline prefetches on a background thread
(data/pipeline.py), compute+comm are one jit'd train_step (XLA owns the
overlap), and checkpointing is host-side."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Optional

import jax

from repro.checkpoint import ckpt as ckpt_lib
from repro.telemetry.events import NULL_RECORDER


def _batch_items(batch) -> tuple:
    """(count, unit) of work in one batch for throughput accounting.

    LM/VLM batches carry a ``tokens`` (or codebook-label) tensor and report
    tok/s; the paper's own vision/ASR workloads (vgg-a, overfeat-fast,
    cd-dnn) have no token tensor — count batch rows and report samples/s
    instead of a flat 0 tok/s."""
    if "tokens" in batch:
        return int(batch["tokens"].size), "tok"
    if "codebook_labels" in batch:            # audio LM: seq x codebooks
        return int(batch["codebook_labels"].size), "tok"
    for v in batch.values():
        shape = getattr(v, "shape", ())
        if shape:
            return int(shape[0]), "samples"
    return 0, "samples"


@dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0            # 0 = disabled
    ckpt_dir: Optional[str] = None
    ckpt_meta: Optional[dict] = None   # stored in the checkpoint manifest
    #                                    (zero1 world layout for elastic
    #                                    world-size replan — see
    #                                    checkpoint.replan)
    recorder: Optional[Any] = None     # telemetry Recorder; every phase of
    #                                    the loop becomes a span (step,
    #                                    data_wait, compile, ckpt_write) and
    #                                    listeners see each completed event
    #                                    — the general hook that replaced
    #                                    the bare on_step heartbeat callback
    #                                    (the cluster heartbeat now rides
    #                                    the "step" span's end event).
    #                                    None = NULL_RECORDER (no-op).


@dataclass
class Trainer:
    train_step: Callable            # (params, opt_state, step, batch) -> ...
    cfg: TrainerConfig = field(default_factory=TrainerConfig)
    jit: bool = True                # False: train_step is already jitted
    #                                 (e.g. Run.jit_step's shared cache)
    warm: bool = False              # True: step_fn has executed before —
    #                                 first step is NOT a compile, time it
    #                                 like any other (Run re-fit/resume)

    def fit(self, params, opt_state, data_iter: Iterable,
            start_step: int = 0, log_fn=print):
        history = []
        step_fn = jax.jit(self.train_step, donate_argnums=(0, 1)) \
            if self.jit else self.train_step
        rec = self.cfg.recorder if self.cfg.recorder is not None \
            else NULL_RECORDER
        sync = getattr(rec, "sync", False)
        t0 = time.perf_counter()
        t_compile = 0.0
        items_seen, unit = 0, "tok"
        for step in range(start_step, self.cfg.total_steps):
            try:
                with rec.span("data_wait", step=step + 1):
                    batch = next(data_iter)
            except StopIteration:
                # finite source ran dry (Prefetcher signals exhaustion as
                # StopIteration): end training with the progress made, do
                # not lose params/opt_state/history to an escaping exception
                log_fn(f"data exhausted at step {step} "
                       f"(of {self.cfg.total_steps}); stopping")
                break
            first = step == start_step and not self.warm
            with rec.span("step", step=step + 1):
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     step, batch)
                if first:
                    # the first step is dominated by jit compile: block,
                    # report it separately, and restart the throughput clock
                    # so items/s measures steady-state steps only
                    with rec.span("compile", step=step + 1):
                        jax.block_until_ready(metrics["loss"])
                elif sync:
                    # traced runs trade async dispatch for honest span
                    # durations; untraced runs never block here
                    jax.block_until_ready(metrics["loss"])
            if first:
                t_compile = time.perf_counter() - t0
                t0 = time.perf_counter()
            else:
                n, unit = _batch_items(batch)
                items_seen += n
                rec.count(f"items_{unit}", n)
            rec.count("steps")
            # the FINAL step always logs, so history[-1] is the true end
            # state (callers label checkpoints / report final loss from it)
            if ((step + 1) % self.cfg.log_every == 0 or step == start_step
                    or step + 1 == self.cfg.total_steps):
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                rate = items_seen / dt if dt > 0 else 0.0
                tail = (f"compile {t_compile:6.1f} s" if first
                        else f"{rate:9.0f} {unit}/s")
                log_fn(f"step {step + 1:5d}  loss {loss:8.4f}  "
                       f"gnorm {float(metrics['grad_norm']):7.3f}  "
                       f"lr {float(metrics['lr']):.2e}  {tail}")
                history.append(dict(step=step + 1, loss=loss,
                                    grad_norm=float(metrics["grad_norm"])))
            if (self.cfg.ckpt_every and self.cfg.ckpt_dir
                    and (step + 1) % self.cfg.ckpt_every == 0):
                with rec.span("ckpt_write", step=step + 1):
                    ckpt_lib.save(self.cfg.ckpt_dir, step + 1,
                                  meta=self.cfg.ckpt_meta,
                                  params=params, opt_state=opt_state)
        return params, opt_state, history
