from repro.train.train_step import (  # noqa: F401
    make_overlapped_train_step,
    make_train_step,
    zero1_state_shardings,
)
from repro.train.trainer import Trainer, TrainerConfig  # noqa: F401
