from repro.train.train_step import make_train_step, zero1_state_shardings  # noqa: F401
from repro.train.trainer import Trainer, TrainerConfig  # noqa: F401
