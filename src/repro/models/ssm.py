"""State-space / recurrent blocks: Mamba2 (SSD), xLSTM (mLSTM + sLSTM).

Training uses the chunk-parallel forms (quadratic within a chunk, linear
scan across chunks) — the TPU-friendly formulation: chunk-local einsums hit
the MXU, the cross-chunk scan is O(S/chunk) sequential steps.  Decode uses
the recurrences directly with carried states.  Property tests check the
chunked forms against naive per-step recurrences.

Sharding: heads / inner dims carry the "ssm_heads"/"ssm_inner" logical axes
(model-parallel); states are small and live per-device.  The sequence dim is
never sharded (the scan is sequential) — per the paper's §3.3 argument that
partitioning beyond minibatch+feature dims is sub-optimal.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core.params import Spec
from repro.core.sharding import ShardingCtx
from repro.models.layers import rms_norm


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================
def mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    din = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads or max(1, din // 64)
    P = din // H
    return din, H, P


def mamba_specs(cfg: ModelConfig) -> dict:
    d, N = cfg.d_model, cfg.ssm_state
    din, H, P = mamba_dims(cfg)
    cw = cfg.ssm_conv_width
    emb = "embed_fsdp" if cfg.fsdp else "embed"
    return {
        # order: [z(din), x(din), B(N), C(N), dt(H)]
        "in_proj": Spec((d, 2 * din + 2 * N + H), (emb, "ssm_inner")),
        "conv_w": Spec((cw, din + 2 * N), ("kernel", "ssm_inner"),
                       init="normal", scale=0.5),
        "conv_b": Spec((din + 2 * N,), ("ssm_inner",), init="zeros"),
        "A_log": Spec((H,), ("ssm_heads",), init="ones"),
        "D": Spec((H,), ("ssm_heads",), init="ones"),
        "dt_bias": Spec((H,), ("ssm_heads",), init="zeros"),
        "gate_norm": Spec((din,), ("ssm_inner",), init="zeros"),
        "out_proj": Spec((din, d), ("ssm_inner", emb)),
        "norm": Spec((d,), ("embed",), init="zeros"),
    }


@dataclasses.dataclass(frozen=True)
class MambaCache:
    state: jax.Array       # (B, H, P, N)
    conv: jax.Array        # (B, cw-1, din+2N) trailing inputs
    length: jax.Array


def init_mamba_cache(cfg: ModelConfig, batch: int,
                     dtype=jnp.float32) -> MambaCache:
    din, H, P = mamba_dims(cfg)
    N, cw = cfg.ssm_state, cfg.ssm_conv_width
    return MambaCache(jnp.zeros((batch, H, P, N), dtype),
                      jnp.zeros((batch, cw - 1, din + 2 * N), dtype),
                      jnp.zeros((), jnp.int32))


def mamba_cache_axes():
    return MambaCache(("batch", "ssm_heads", None, "ssm_state"),
                      ("batch", None, "ssm_inner"), ())


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv over seq.  xbc: (B,S,C); w: (cw,C)."""
    cw = w.shape[0]
    if prev is None:
        pad = jnp.zeros((xbc.shape[0], cw - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = prev.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(cw))
    return jax.nn.silu(out + b)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                Bm: jax.Array, Cm: jax.Array, chunk: int = 256,
                init_state: Optional[jax.Array] = None):
    """Chunk-parallel SSD (Mamba2, Dao & Gu 2024 minimal form).

    x: (B,S,H,P); dt: (B,S,H) (post-softplus); A: (H,) negative;
    Bm, Cm: (B,S,N) shared across heads.  Returns (y (B,S,H,P),
    final_state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    nc = S // chunk
    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    dA = dtc * A[None, None, None, :]                    # (b,c,l,h) negative
    cs = jnp.cumsum(dA, axis=2)                          # inclusive cumsum
    # intra-chunk decay matrix T[l,m] = exp(cs_l - cs_m), l >= m.
    # Mask the EXPONENT, not the result: for m > l the difference is
    # positive and exp() overflows, and `where(mask, inf, 0)` still sends
    # NaN through the backward pass.
    l_idx = jnp.arange(chunk)
    tri = l_idx[:, None] >= l_idx[None, :]
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]           # (b,c,l,m,h)
    diff = jnp.where(tri[None, None, :, :, None], diff, -1e30)
    Tmat = jnp.exp(diff)
    # Y_diag[l] = C_l . sum_m T[l,m] dt_m B_m x_m
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)       # (b,c,l,m)
    w_lm = scores[..., None] * Tmat * dtc[:, :, None, :, :]   # (b,c,l,m,h)
    y_diag = jnp.einsum("bclmh,bcmhp->bclhp", w_lm, xc)
    # chunk-final states: sum_m exp(cs_last - cs_m) dt_m B_m (x)_m
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)        # (b,c,l,h)
    states = jnp.einsum("bcln,bclh,bclh,bclhp->bchpn",
                        Bc, decay_to_end, dtc, xc)
    chunk_decay = jnp.exp(cs[:, :, -1, :])               # (b,c,h)

    def scan_fn(carry, inp):
        st, dec = inp                                    # (b,h,p,n), (b,h)
        prev = carry
        new = prev * dec[:, :, None, None] + st
        return new, prev

    init = (init_state if init_state is not None
            else jnp.zeros((Bsz, H, P, N), x.dtype))
    final, prev_states = lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # (b,c,h,p,n)
    # inter-chunk contribution: C_l . prev_state decayed to l
    in_decay = jnp.exp(cs)                               # (b,c,l,h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, prev_states, in_decay)
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, final


def mamba_block(p: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
                cache: Optional[MambaCache] = None):
    """Pre-norm Mamba2 block.  Returns (residual_out, new_cache_or_None)."""
    Bsz, S, d = x.shape
    din, H, P = mamba_dims(cfg)
    N = cfg.ssm_state
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    proj = h @ p["in_proj"].astype(h.dtype)
    z, xs, Bm, Cm, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + N, 2 * din + 2 * N], axis=-1)
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)

    new_cache = None
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    if cache is not None and S == 1:
        xbc_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], cache.conv)
        conv_new = jnp.concatenate([cache.conv[:, 1:], xbc], axis=1)
        xs_c, Bc, Cc = jnp.split(xbc_conv, [din, din + N], axis=-1)
        xh = xs_c.reshape(Bsz, 1, H, P)[:, 0]            # (b,h,p)
        dA = jnp.exp(dt[:, 0] * A[None, :])              # (b,h)
        st = cache.state * dA[:, :, None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt[:, 0], xh, Bc[:, 0])
        y = jnp.einsum("bhpn,bn->bhp", st, Cc[:, 0])
        y = y + p["D"].astype(y.dtype)[None, :, None] * xh
        y = y.reshape(Bsz, 1, din)
        new_cache = MambaCache(st, conv_new, cache.length + 1)
    else:
        xbc_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        xs_c, Bc, Cc = jnp.split(xbc_conv, [din, din + N], axis=-1)
        xh = xs_c.reshape(Bsz, S, H, P)
        xh = ctx.constrain(xh, "batch", "seq", "ssm_heads", None)
        y, final = ssd_chunked(xh.astype(jnp.float32), dt, A,
                               Bc.astype(jnp.float32),
                               Cc.astype(jnp.float32))
        y = y + p["D"].astype(y.dtype)[None, None, :, None] \
            * xh.astype(y.dtype)
        y = y.reshape(Bsz, S, din).astype(x.dtype)
        if cache is not None:
            cw = cfg.ssm_conv_width
            conv_new = xbc[:, -(cw - 1):].astype(jnp.float32)
            new_cache = MambaCache(final, conv_new,
                                   jnp.asarray(S, jnp.int32))
    # gated output norm (Mamba2): y * silu(z), RMS-normed
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["gate_norm"],
                 cfg.norm_eps)
    y = ctx.constrain(y, "batch", "seq", "ssm_inner")
    out = y @ p["out_proj"].astype(y.dtype)
    out = ctx.constrain(out, "batch", "seq", "embed")
    return x + out, new_cache


# ===========================================================================
# xLSTM — mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar, scan)
# ===========================================================================
def mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    din = cfg.ssm_expand * cfg.d_model
    H = cfg.num_heads
    P = din // H
    return din, H, P


def mlstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    din, H, P = mlstm_dims(cfg)
    emb = "embed_fsdp" if cfg.fsdp else "embed"
    return {
        "up_proj": Spec((d, 2 * din), (emb, "ssm_inner")),
        "wq": Spec((din, din), ("ssm_inner", None)),
        "wk": Spec((din, din), ("ssm_inner", None)),
        "wv": Spec((din, din), ("ssm_inner", None)),
        "w_if": Spec((din, 2 * H), ("ssm_inner", "ssm_heads")),
        "b_if": Spec((2 * H,), ("ssm_heads",), init="zeros"),
        "out_norm": Spec((din,), ("ssm_inner",), init="zeros"),
        "down_proj": Spec((din, d), ("ssm_inner", emb)),
        "norm": Spec((d,), ("embed",), init="zeros"),
    }


@dataclasses.dataclass(frozen=True)
class MlstmCache:
    C: jax.Array          # (B, H, P, P) matrix memory
    n: jax.Array          # (B, H, P) normalizer
    m: jax.Array          # (B, H) max-stabilizer (log domain)
    length: jax.Array


def init_mlstm_cache(cfg: ModelConfig, batch: int,
                     dtype=jnp.float32) -> MlstmCache:
    _, H, P = mlstm_dims(cfg)
    return MlstmCache(jnp.zeros((batch, H, P, P), dtype),
                      jnp.zeros((batch, H, P), dtype),
                      jnp.full((batch, H), -1e30, dtype),
                      jnp.zeros((), jnp.int32))


def mlstm_cache_axes():
    return MlstmCache(("batch", "ssm_heads", None, None),
                      ("batch", "ssm_heads", None),
                      ("batch", "ssm_heads"), ())


def _mlstm_chunk_scan(q, k, v, log_f, log_i, chunk: int,
                      cache: Optional[MlstmCache]):
    """Stabilized chunk-parallel mLSTM.

    q,k,v: (B,S,H,P); log_f, log_i: (B,S,H).  Returns (y, final cache parts).
    Recurrence: C_t = f_t C_{t-1} + i_t k_t v_t^T; n_t = f_t n_{t-1} + i_t k_t
                y_t = (C_t^T q_t) / max(|n_t . q_t|, exp(-m_t)).
    """
    B, S, H, P = q.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    nc = S // chunk
    def rs(t):
        return t.reshape(B, nc, chunk, *t.shape[2:]).transpose(
            1, 0, *range(2, t.ndim + 1))

    qc, kc, vc = rs(q), rs(k), rs(v)                 # (nc,B,l,H,P)
    fc, ic = rs(log_f), rs(log_i)                    # (nc,B,l,H)

    if cache is None:
        C0 = jnp.zeros((B, H, P, P), jnp.float32)
        n0 = jnp.zeros((B, H, P), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = (cache.C.astype(jnp.float32),
                      cache.n.astype(jnp.float32),
                      cache.m.astype(jnp.float32))

    tri = jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :]

    def body(carry, inp):
        Cp, np_, mp = carry
        qb, kb, vb, fb, ib = inp
        qb = qb / (P ** 0.5)                         # one consistent scale
        F = jnp.cumsum(fb, axis=1)                   # (B,l,H) inclusive
        # intra-chunk log weights D[l,m] = F_l - F_m + i_m  (m <= l)
        Dlm = F[:, :, None, :] - F[:, None, :, :] + ib[:, None, :, :]
        Dlm = jnp.where(tri[None, :, :, None], Dlm, -1e30)
        # inter-chunk log weight for query l: F_l + m_prev
        Dcarry = F + mp[:, None, :]                  # (B,l,H)
        M = jnp.maximum(Dlm.max(axis=2), Dcarry)     # (B,l,H) per-query max
        w_in = jnp.exp(Dlm - M[:, :, None, :])       # (B,l,m,H)
        w_car = jnp.exp(Dcarry - M)                  # (B,l,H)
        scores = jnp.einsum("blhp,bmhp->blmh", qb, kb)
        y_num = jnp.einsum("blmh,blmh,bmhp->blhp", scores, w_in, vb) \
            + jnp.einsum("blhp,bhpq,blh->blhq", qb, Cp, w_car)
        # normalizer: n_l = sum_m w_in[l,m] k_m + w_car[l] n_prev; denom = |n_l . q_l|
        n_vec = jnp.einsum("blmh,bmhp->blhp", w_in, kb) \
            + w_car[..., None] * np_[:, None]
        denom = jnp.abs(jnp.einsum("blhp,blhp->blh", n_vec, qb))
        y = y_num / jnp.maximum(denom, jnp.exp(-M))[..., None]
        # ---- carry update to end of chunk ----
        F_last = F[:, -1]                            # (B,H)
        m_new = jnp.maximum(F_last + mp, (F_last[:, None] - F + ib).max(1))
        w_state = jnp.exp(F_last[:, None] - F + ib - m_new[:, None])  # (B,l,H)
        C_new = jnp.exp(F_last + mp - m_new)[:, :, None, None] * Cp \
            + jnp.einsum("blh,blhp,blhq->bhpq", w_state, kb, vb)
        n_new = jnp.exp(F_last + mp - m_new)[..., None] * np_ \
            + jnp.einsum("blh,blhp->bhp", w_state, kb)
        return (C_new, n_new, m_new), y

    (Cf, nf, mf), ys = lax.scan(body, (C0, n0, m0), (qc, kc, vc, fc, ic))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y, (Cf, nf, mf)


def mlstm_block(p: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
                cache: Optional[MlstmCache] = None, chunk: int = 256):
    Bsz, S, d = x.shape
    din, H, P = mlstm_dims(cfg)
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    u, z = jnp.split(h @ p["up_proj"].astype(h.dtype), 2, axis=-1)
    u = ctx.constrain(u, "batch", "seq", "ssm_inner")
    q = (u @ p["wq"].astype(u.dtype)).reshape(Bsz, S, H, P).astype(jnp.float32)
    k = (u @ p["wk"].astype(u.dtype)).reshape(Bsz, S, H, P).astype(jnp.float32)
    v = (u @ p["wv"].astype(u.dtype)).reshape(Bsz, S, H, P).astype(jnp.float32)
    gates = u @ p["w_if"].astype(u.dtype) + p["b_if"].astype(u.dtype)
    log_i, f_pre = jnp.split(gates.reshape(Bsz, S, 2, H), 2, axis=2)
    log_i = log_i[:, :, 0].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(f_pre[:, :, 0].astype(jnp.float32))

    y, (Cf, nf, mf) = _mlstm_chunk_scan(q, k, v, log_f, log_i, chunk, cache)
    new_cache = None
    if cache is not None:
        new_cache = MlstmCache(Cf, nf, mf, cache.length + S)
    y = y.reshape(Bsz, S, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = y @ p["down_proj"].astype(y.dtype)
    out = ctx.constrain(out, "batch", "seq", "embed")
    return x + out, new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    P = d // H
    emb = "embed_fsdp" if cfg.fsdp else "embed"
    return {
        "W": Spec((d, 4 * d), (emb, "ssm_inner")),
        "R": Spec((H, P, 4 * P), ("ssm_heads", None, None), init="normal",
                  scale=0.02),
        "b": Spec((4 * d,), ("ssm_inner",), init="zeros"),
        "out_norm": Spec((d,), ("embed",), init="zeros"),
        "out_proj": Spec((d, d), (emb, emb)),
        "norm": Spec((d,), ("embed",), init="zeros"),
    }


@dataclasses.dataclass(frozen=True)
class SlstmCache:
    h: jax.Array   # (B, d)
    c: jax.Array   # (B, d)
    n: jax.Array   # (B, d)
    m: jax.Array   # (B, d)
    length: jax.Array


def init_slstm_cache(cfg: ModelConfig, batch: int,
                     dtype=jnp.float32) -> SlstmCache:
    d = cfg.d_model
    z = jnp.zeros((batch, d), dtype)
    return SlstmCache(z, z, z, jnp.full((batch, d), -1e30, dtype),
                      jnp.zeros((), jnp.int32))


def slstm_cache_axes():
    a = ("batch", "embed")
    return SlstmCache(a, a, a, a, ())


def _slstm_step(p, H, P, carry, wx):
    """One sLSTM step; wx: (B, 4d) = W x + b precomputed; carry (h,c,n,m)."""
    h, c, n, m = carry
    B = h.shape[0]
    hh = h.reshape(B, H, P)
    rec = jnp.einsum("bhp,hpq->bhq", hh, p["R"]).reshape(B, 4 * H * P)
    z_pre, i_pre, f_pre, o_pre = jnp.split(wx + rec, 4, axis=-1)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    i = jnp.exp(i_pre - m_new)
    f = jnp.exp(log_f + m - m_new)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new)


def slstm_block(p: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
                cache: Optional[SlstmCache] = None):
    Bsz, S, d = x.shape
    H = cfg.num_heads
    P = d // H
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    wx = (h @ p["W"].astype(h.dtype) + p["b"].astype(h.dtype)
          ).astype(jnp.float32)
    if cache is None:
        z = jnp.zeros((Bsz, d), jnp.float32)
        carry = (z, z, z, jnp.full((Bsz, d), -1e30, jnp.float32))
    else:
        carry = (cache.h.astype(jnp.float32), cache.c.astype(jnp.float32),
                 cache.n.astype(jnp.float32), cache.m.astype(jnp.float32))

    def step(cr, wxt):
        new = _slstm_step(p, H, P, cr, wxt)
        return new, new[0]

    (hf, cf, nf, mf), ys = lax.scan(step, carry, wx.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2).astype(x.dtype)           # (B,S,d)
    new_cache = None
    if cache is not None:
        new_cache = SlstmCache(hf, cf, nf, mf, cache.length + S)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(y.dtype)
    out = ctx.constrain(out, "batch", "seq", "embed")
    return x + out, new_cache
