"""Mixture-of-Experts layer: top-k token-choice routing.

Design notes (these are the paper's concerns mapped to MoE):
  * Dispatch is PER SAMPLE (cumsum over the sequence dim only), so routing
    needs zero cross-device communication under the hybrid mesh — the batch
    dim stays on the data axes, expert weights shard their hidden dim on the
    model axis ("tensor-parallel experts").  Neither assigned MoE arch has
    E divisible by 16 (qwen: 60, mixtral: 8), so classic expert-parallel
    all-to-all is not available on this mesh; see EXPERIMENTS.md §Perf for
    the padded-experts variant.
  * Train/prefill uses capacity-bounded scatter dispatch (tokens over
    capacity are dropped, standard practice); decode (S==1) gathers the k
    selected experts' weights instead — batched-einsum over all E experts
    would inflate decode FLOPs by E/k.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.params import Spec
from repro.core.sharding import ShardingCtx
from repro.models.layers import rms_norm


def moe_specs(cfg: ModelConfig) -> dict:
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    Ep = E + cfg.moe_expert_pad    # padded for expert-parallel sharding
    emb = "embed_fsdp" if cfg.fsdp else "embed"
    sp = {
        "router": Spec((d, E), ("embed", "experts")),
        "w_gate": Spec((Ep, d, ff), ("experts", emb, "moe_ff")),
        "w_up": Spec((Ep, d, ff), ("experts", emb, "moe_ff")),
        "w_down": Spec((Ep, ff, d), ("experts", "moe_ff", emb)),
        "norm": Spec((d,), ("embed",), init="zeros"),
    }
    if cfg.num_shared_experts:
        sff = cfg.shared_expert_d_ff
        sp.update({
            "sh_gate": Spec((d, sff), (emb, "ff")),
            "sh_up": Spec((d, sff), (emb, "ff")),
            "sh_down": Spec((sff, d), ("ff", emb)),
        })
    return sp


def _router(h: jax.Array, w: jax.Array, k: int):
    """h: (..., d) -> (weights (..., k), idx (..., k), aux_loss scalar)."""
    logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    E = w.shape[-1]
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    onehot = jax.nn.one_hot(top_i, E, dtype=jnp.float32).sum(-2)  # (..., E)
    f_e = onehot.reshape(-1, E).mean(0) / k
    p_e = probs.reshape(-1, E).mean(0)
    aux = E * jnp.sum(f_e * p_e)
    return top_w, top_i, aux


def _expert_ffn(x: jax.Array, wg, wu, wd) -> jax.Array:
    """x: (E, C, d) through per-expert SwiGLU."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, wg.astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", x, wu.astype(x.dtype))
    return jnp.einsum("ecf,efd->ecd", g * u, wd.astype(x.dtype))


def moe_ep_block(p: dict, x: jax.Array, cfg: ModelConfig,
                 ctx: ShardingCtx) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE via EXPLICIT collectives (shard_map +
    lax.all_to_all) — the beyond-paper §Perf optimization, in the paper's
    own §3.4 style: GSPMD cannot derive an all-to-all from a scatter into an
    expert-sharded buffer (measured: it replicates, 6x worse), so the
    dispatch is written manually, exactly as the paper writes part-reduce /
    part-broadcast manually.

    Layout: experts sharded on "model" (E+pad divisible); tokens arrive
    replicated across "model" (batch lives on the data axes).  Each model
    shard routes its 1/n slice of the token-assignments, all-to-alls them to
    the owning expert shards, runs its local experts, all-to-alls results
    back, and the per-slice outputs are combined with a psum — ring volume
    per layer ~ 2 x A2A(T/n tokens) + 2 x (B,S,d)/n vs TP-MoE's
    2 x all-reduce((B,E,C,d)).
    """
    from jax import lax
    mesh = ctx.mesh
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    Ep = E + cfg.moe_expert_pad
    n = mesh.shape["model"]
    E_loc = Ep // n
    cf = cfg.moe_capacity_factor

    h = rms_norm(x, p["norm"], cfg.norm_eps)
    h = ctx.constrain(h, "batch", "seq", "embed")
    _, _, aux = _router(h, p["router"], k)   # aux on full (replicated) stats

    P = jax.sharding.PartitionSpec
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0])

    def inner(h_loc, router_w, wg, wu, wd):
        # h_loc: (B_loc, S, d) — replicated across "model"
        B_loc = h_loc.shape[0]
        i_shard = lax.axis_index("model")
        T = B_loc * S * k
        Ts = T // n                                   # this shard's slice
        # route the full local batch, then take this shard's slice
        logits = h_loc.astype(jnp.float32) @ router_w.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_i = jax.lax.top_k(probs, k)
        top_w = (top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
                 ).reshape(T)
        flat_i = top_i.reshape(T)
        toks = jnp.repeat(h_loc.reshape(B_loc * S, d), k, axis=0)  # (T,d)
        def sl(t):
            return lax.dynamic_slice_in_dim(t, i_shard * Ts, Ts, 0)
        my_i, my_w, my_toks = sl(flat_i), sl(top_w), sl(toks)
        dest = my_i // E_loc                          # owning shard
        e_loc = my_i % E_loc
        # scatter my slice into per-dest buffers
        C = max(1, int(Ts / n * cf))
        oh = jax.nn.one_hot(dest, n, dtype=jnp.int32)
        pos = ((jnp.cumsum(oh, 0) - oh) * oh).sum(-1)          # pos in dest
        keep = pos < C
        pos_c = jnp.minimum(pos, C - 1)
        buf = jnp.zeros((n, C, d), h_loc.dtype).at[dest, pos_c].add(
            my_toks * keep[:, None].astype(h_loc.dtype))
        meta = jnp.full((n, C), -1, jnp.int32).at[dest, pos_c].max(
            jnp.where(keep, e_loc, -1))
        # ---- dispatch: tokens travel to their expert's shard ----
        recv = lax.all_to_all(buf, "model", split_axis=0, concat_axis=0,
                              tiled=True)             # (n, C, d)
        recv_e = lax.all_to_all(meta, "model", split_axis=0, concat_axis=0,
                                tiled=True)           # (n, C)
        rt = recv.reshape(n * C, d)
        re = recv_e.reshape(n * C)
        valid = re >= 0
        re_c = jnp.maximum(re, 0)
        # local dispatch to this shard's experts
        Ce = max(1, int(n * C / E_loc * cf))
        ohe = jax.nn.one_hot(re_c, E_loc, dtype=jnp.int32)
        pe = ((jnp.cumsum(ohe, 0) - ohe) * ohe).sum(-1)
        keep_e = (pe < Ce) & valid
        pe_c = jnp.minimum(pe, Ce - 1)
        xe = jnp.zeros((E_loc, Ce, d), rt.dtype).at[re_c, pe_c].add(
            rt * keep_e[:, None].astype(rt.dtype))
        ye = _expert_ffn(xe, wg, wu, wd)              # fully local
        out_t = ye[re_c, pe_c] * keep_e[:, None].astype(ye.dtype)
        # ---- return: results travel back to the token's home shard ----
        back = lax.all_to_all(out_t.reshape(n, C, d), "model",
                              split_axis=0, concat_axis=0, tiled=True)
        y_slice = back[dest, pos_c] * (keep[:, None]
                                       * my_w[:, None]).astype(back.dtype)
        # combine: fold the k assignments into token space FIRST (linear),
        # then one (B*S, d) psum over shards — 1/k the reduction volume
        tok_idx = (i_shard * Ts + jnp.arange(Ts)) // k
        y_tok = jnp.zeros((B_loc * S, d), y_slice.dtype).at[tok_idx].add(
            y_slice)
        y_tok = lax.psum(y_tok, "model")
        return y_tok.reshape(B_loc, S, d)

    y = jax.shard_map(
        inner, mesh=mesh,
        in_specs=(P(*bspec, None, None), P(), P("model"), P("model"),
                  P("model")),
        out_specs=P(*bspec, None, None),
        check_vma=False,
    )(h, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    y = ctx.constrain(y, "batch", "seq", "embed")

    if cfg.num_shared_experts:
        sg = jax.nn.silu(h @ p["sh_gate"].astype(h.dtype))
        su = h @ p["sh_up"].astype(h.dtype)
        sh = ctx.constrain(sg * su, "batch", "seq", "ff")
        y = y + sh @ p["sh_down"].astype(h.dtype)
        y = ctx.constrain(y, "batch", "seq", "embed")
    return x + y, aux * cfg.router_aux_loss_coef


def moe_block(p: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
              ) -> Tuple[jax.Array, jax.Array]:
    """Returns (residual_out, aux_loss)."""
    capacity_factor = cfg.moe_capacity_factor
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    if (cfg.moe_expert_pad and ctx.mesh is not None
            and "model" in ctx.mesh.axis_names and S > 1
            and (E + cfg.moe_expert_pad) % ctx.mesh.shape["model"] == 0):
        return moe_ep_block(p, x, cfg, ctx)
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    top_w, top_i, aux = _router(h, p["router"], k)      # (B,S,k)


    if S == 1:
        hv = h[:, 0]                                      # (B,d)
        # dense-all-experts wins when every expert's weights are touched
        # anyway (B*k >= E) — EXCEPT for FSDP weights on a single-pod mesh,
        # where batch and weight-d_model contend for the same 'data' axis
        # and GSPMD all-gathers the full expert weights (measured §Perf:
        # 80 ms gather vs 457 ms dense on mixtral decode_32k 16x16, but
        # 3.44 s gather vs 247 ms dense on 2x16x16).
        dense_ok = B * k >= E and (
            not cfg.fsdp or (ctx.mesh is not None
                             and "pod" in ctx.mesh.axis_names))
        if dense_ok:
            # ---- batched decode: dense-all-experts. Decode is
            # bandwidth-bound; with B*k >= E every expert's weights are
            # read anyway, so computing all experts on all tokens and
            # combining by the router one-hot moves each weight ONCE and
            # keeps FSDP-sharded contractions local (tiny psums) — the
            # per-token weight-gather alternative all-gathers (B,k,d,ff)
            # slices (§Perf: 3 GB/layer on mixtral multi-pod decode).
            g = jax.nn.silu(jnp.einsum("bd,edf->bef", hv,
                                       p["w_gate"].astype(hv.dtype)))
            u = jnp.einsum("bd,edf->bef", hv, p["w_up"].astype(hv.dtype))
            gu = ctx.constrain(g * u, "batch", None, "moe_ff")
            ye = jnp.einsum("bef,efd->bed", gu, p["w_down"].astype(hv.dtype))
            sel = jax.nn.one_hot(top_i[:, 0], ye.shape[1],
                                 dtype=ye.dtype)          # (B,k,E[+pad])
            y = jnp.einsum("bed,bke,bk->bd", ye, sel,
                           top_w[:, 0].astype(ye.dtype))[:, None]
        else:
            # ---- sparse decode: gather the k experts' weights per token
            wg = jnp.take(p["w_gate"], top_i[:, 0], axis=0)  # (B,k,d,ff)
            wu = jnp.take(p["w_up"], top_i[:, 0], axis=0)
            wd = jnp.take(p["w_down"], top_i[:, 0], axis=0)
            g = jax.nn.silu(jnp.einsum("bd,bkdf->bkf", hv,
                                       wg.astype(hv.dtype)))
            u = jnp.einsum("bd,bkdf->bkf", hv, wu.astype(hv.dtype))
            ye = jnp.einsum("bkf,bkfd->bkd", g * u, wd.astype(hv.dtype))
            y = jnp.einsum("bkd,bk->bd", ye,
                           top_w.astype(ye.dtype)[:, 0])[:, None]
    else:
        # ---- train/prefill: per-sample capacity-bounded scatter dispatch,
        # written batch-leading (no vmap) so GSPMD keeps every tensor
        # batch-sharded on the data axes and the expert einsums are local
        # TP matmuls (moe_ff on 'model') — zero routing communication.
        #
        # Perf knobs (EXPERIMENTS.md §Perf):
        #  * moe_expert_pad: experts dim padded to a multiple of the model
        #    axis -> "experts" rule fires -> expert-parallel layout
        #    (dispatch/undispatch become all-to-all, expert FFNs local);
        #  * moe_down_rs: shard the down-proj output d -> the partial-sum
        #    reduction becomes reduce-scatter instead of all-reduce.
        Ep = E + cfg.moe_expert_pad
        C = max(1, int(S * k / E * capacity_factor))
        flat_i = top_i.reshape(B, S * k)
        oh = jax.nn.one_hot(flat_i, Ep, dtype=jnp.int32)    # (B,S*k,Ep)
        pos = jnp.cumsum(oh, axis=1) - oh                   # pos in expert
        pos = (pos * oh).sum(-1)                            # (B,S*k)
        keep = pos < C
        pos_c = jnp.minimum(pos, C - 1)
        xs = jnp.repeat(h, k, axis=1)                       # (B,S*k,d)
        xs = xs * keep[..., None].astype(h.dtype)
        b_idx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S * k))
        xe = jnp.zeros((B, Ep, C, d), h.dtype).at[
            b_idx, flat_i, pos_c].add(xs)
        xe = ctx.constrain(xe, "batch", "experts", None, None)
        g = jax.nn.silu(jnp.einsum("becd,edf->becf", xe,
                                   p["w_gate"].astype(xe.dtype)))
        u = jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(xe.dtype))
        gu = ctx.constrain(g * u, "batch", "experts", None, "moe_ff")
        ye = jnp.einsum("becf,efd->becd", gu, p["w_down"].astype(xe.dtype))
        out_d = "moe_out" if cfg.moe_down_rs else None
        ye = ctx.constrain(ye, "batch", "experts", None, out_d)
        gathered = ye[b_idx, flat_i, pos_c]                 # (B,S*k,d)
        gathered = ctx.constrain(gathered, "batch", None, out_d)
        gathered = gathered * (keep[..., None]
                               * top_w.reshape(B, S * k)[..., None]
                               ).astype(ye.dtype)
        y = gathered.reshape(B, S, k, d).sum(2)
        y = ctx.constrain(y, "batch", "seq", out_d)
    y = ctx.constrain(y, "batch", "seq", "embed")

    if cfg.num_shared_experts:
        sg = jax.nn.silu(h @ p["sh_gate"].astype(h.dtype))
        su = h @ p["sh_up"].astype(h.dtype)
        sh = ctx.constrain(sg * su, "batch", "seq", "ff")
        y = y + sh @ p["sh_down"].astype(h.dtype)
        y = ctx.constrain(y, "batch", "seq", "embed")
    return x + y, aux * cfg.router_aux_loss_coef
