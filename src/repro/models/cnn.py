"""The paper's CNN workloads (VGG-A, OverFeat-FAST) in JAX, NHWC.

Forward conv can route through the Pallas direct-conv kernel (§2 adapted,
``use_pallas=True``) or lax.conv (XLA); both match ``kernels.ref.conv2d_ref``.
Layer specs come straight from ``configs/vgg_a.py`` / ``overfeat_fast.py`` so
the model, the Table-1 balance benchmark and the scaling benchmarks share one
source of truth.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import CNNConfig
from repro.core.params import Spec, init_tree
from repro.core.sharding import ShardingCtx
from repro.kernels import ops as kops, ref as kref


def _key(kind: str, i: int, part: str) -> str:
    """Zero-padded layer index so LEXICAL dict-key order (what jax.tree
    flattening sorts by) equals FORWARD layer order — conv2 must not sort
    after conv10, or the comm bucket plan (tree order) interleaves first-
    and last-layer leaves and the §3.1 overlap schedule degrades to
    everything-ready-last (see repro.comm.overlap)."""
    return f"{kind}{i:02d}_{part}"


def param_specs(cfg: CNNConfig) -> Dict[str, Spec]:
    sp: Dict[str, Spec] = {}
    for i, lyr in enumerate(cfg.layers):
        if lyr.kind == "conv":
            sp[_key("conv", i, "w")] = Spec(
                (lyr.kernel, lyr.kernel, lyr.ifm, lyr.ofm),
                ("kernel", "kernel", "embed", "ff"))
            sp[_key("conv", i, "b")] = Spec((lyr.ofm,), ("ff",),
                                            init="zeros")
        elif lyr.kind == "fc":
            sp[_key("fc", i, "w")] = Spec((lyr.ifm, lyr.ofm),
                                          ("embed", "ff"))
            sp[_key("fc", i, "b")] = Spec((lyr.ofm,), ("ff",), init="zeros")
    return sp


def init_params(cfg: CNNConfig, key: jax.Array):
    return init_tree(param_specs(cfg), key)


def forward(params, cfg: CNNConfig, x: jax.Array,
            ctx: ShardingCtx = ShardingCtx(),
            use_pallas: bool = False) -> jax.Array:
    """x: (N, H, W, 3) -> logits (N, num_classes)."""
    h = x
    for i, lyr in enumerate(cfg.layers):
        if lyr.kind == "conv":
            w = params[_key("conv", i, "w")]
            if use_pallas:
                h = kops.conv2d(h, w, stride=lyr.stride, padding=lyr.pad)
            else:
                h = kref.conv2d_ref(h, w, stride=lyr.stride, padding=lyr.pad)
            h = jax.nn.relu(h + params[_key("conv", i, "b")])
            h = ctx.constrain(h, "batch", None, None, "ff")
        elif lyr.kind == "pool":
            h = lax.reduce_window(h, -jnp.inf, lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
        elif lyr.kind == "fc":
            if h.ndim == 4:
                h = h.reshape(h.shape[0], -1)
            h = h @ params[_key("fc", i, "w")] \
                + params[_key("fc", i, "b")]
            last = (i == len(cfg.layers) - 1)
            if not last:
                h = jax.nn.relu(h)
    return h


def loss_fn(params, cfg: CNNConfig, batch: dict,
            ctx: ShardingCtx = ShardingCtx()) -> jax.Array:
    logits = forward(params, cfg, batch["images"], ctx)
    lf = logits.astype(jnp.float32)
    nll = jax.nn.logsumexp(lf, -1) - jnp.take_along_axis(
        lf, batch["labels"][:, None], axis=-1)[:, 0]
    return nll.mean()
