"""The paper's CNN workloads (VGG-A, OverFeat-FAST) in JAX, NHWC.

Forward conv can route through the Pallas direct-conv kernel (§2 adapted,
``use_pallas=True``) or lax.conv (XLA); both match ``kernels.ref.conv2d_ref``.
Layer specs come straight from ``configs/vgg_a.py`` / ``overfeat_fast.py`` so
the model, the Table-1 balance benchmark and the scaling benchmarks share one
source of truth.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import CNNConfig
from repro.core.params import Spec, init_tree
from repro.core.sharding import ShardingCtx
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def param_specs(cfg: CNNConfig) -> Dict[str, Spec]:
    sp: Dict[str, Spec] = {}
    for i, l in enumerate(cfg.layers):
        if l.kind == "conv":
            sp[f"conv{i}_w"] = Spec((l.kernel, l.kernel, l.ifm, l.ofm),
                                    ("kernel", "kernel", "embed", "ff"))
            sp[f"conv{i}_b"] = Spec((l.ofm,), ("ff",), init="zeros")
        elif l.kind == "fc":
            sp[f"fc{i}_w"] = Spec((l.ifm, l.ofm), ("embed", "ff"))
            sp[f"fc{i}_b"] = Spec((l.ofm,), ("ff",), init="zeros")
    return sp


def init_params(cfg: CNNConfig, key: jax.Array):
    return init_tree(param_specs(cfg), key)


def forward(params, cfg: CNNConfig, x: jax.Array,
            ctx: ShardingCtx = ShardingCtx(),
            use_pallas: bool = False) -> jax.Array:
    """x: (N, H, W, 3) -> logits (N, num_classes)."""
    h = x
    for i, l in enumerate(cfg.layers):
        if l.kind == "conv":
            w = params[f"conv{i}_w"]
            if use_pallas:
                h = kops.conv2d(h, w, stride=l.stride, padding=l.pad)
            else:
                h = kref.conv2d_ref(h, w, stride=l.stride, padding=l.pad)
            h = jax.nn.relu(h + params[f"conv{i}_b"])
            h = ctx.constrain(h, "batch", None, None, "ff")
        elif l.kind == "pool":
            h = lax.reduce_window(h, -jnp.inf, lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
        elif l.kind == "fc":
            if h.ndim == 4:
                h = h.reshape(h.shape[0], -1)
            h = h @ params[f"fc{i}_w"] + params[f"fc{i}_b"]
            last = (i == len(cfg.layers) - 1)
            if not last:
                h = jax.nn.relu(h)
    return h


def loss_fn(params, cfg: CNNConfig, batch: dict,
            ctx: ShardingCtx = ShardingCtx()) -> jax.Array:
    logits = forward(params, cfg, batch["images"], ctx)
    lf = logits.astype(jnp.float32)
    nll = jax.nn.logsumexp(lf, -1) - jnp.take_along_axis(
        lf, batch["labels"][:, None], axis=-1)[:, 0]
    return nll.mean()
