"""Shared transformer layers: norms, RoPE/M-RoPE, attention, MLPs.

Attention comes in three interchangeable implementations:
  * ``ref.attention_ref`` — the oracle (materializes scores);
  * ``chunked_attention`` — pure-JAX online-softmax over KV chunks; the
    default inside models: O(chunk) memory, lowers under GSPMD on any
    backend, flash-equivalent HLO structure for the roofline;
  * ``kernels.flash_attention`` — the Pallas TPU kernel (opt-in fast path).

All are tested against each other.  Layout is (B, S, H, D) throughout.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core.params import Spec
from repro.core.sharding import ShardingCtx

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * lax.rsqrt(var + eps) * w + b).astype(dt)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------
def _rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int."""
    D = x.shape[-1]
    freqs = _rope_freqs(D, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array,
                sections: Tuple[int, ...], theta: float) -> jax.Array:
    """Qwen2-VL M-RoPE: positions3 (B, S, 3) = (temporal, height, width);
    the D/2 frequency slots are split into ``sections`` (sum = D/2), each
    section rotated by its own position component."""
    D = x.shape[-1]
    assert sum(sections) == D // 2, (sections, D)
    freqs = _rope_freqs(D, theta)                       # (D/2,)
    # pick the position component per frequency slot
    comp = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(comp[None, None, :], positions3.shape[:2] + (D // 2,)),
        axis=-1)                                        # (B, S, D/2)
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked online-softmax attention (pure JAX, GSPMD-friendly)
# ---------------------------------------------------------------------------
def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      logit_softcap: float = 0.0,
                      scale: Optional[float] = None,
                      chunk: int = 1024) -> jax.Array:
    """Online-softmax attention, scanning KV chunks; (B,S,H,D) layout, GQA."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    chunk = min(chunk, Skv)
    if Skv % chunk:
        chunk = Skv  # fallback: single chunk
    n_chunks = Skv // chunk

    qf = q.astype(jnp.float32) * scale
    q_pos = (jnp.arange(Sq) + (Skv - Sq))[None, :, None]      # (1,Sq,1)

    kc = k.reshape(B, n_chunks, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        m, lsum, acc = carry
        ci, kb, vb = inp                                      # (B,chunk,Hkv,D)
        if g > 1:
            kb = jnp.repeat(kb, g, axis=2)
            vb = jnp.repeat(vb, g, axis=2)
        s = jnp.einsum("bqhd,bkhd->bqhk", qf, kb.astype(jnp.float32))
        if logit_softcap > 0:
            s = jnp.tanh(s / logit_softcap) * logit_softcap
        k_pos = (ci * chunk + jnp.arange(chunk))[None, None, None, :]
        mask = jnp.ones(s.shape[-1], bool)[None, None, None, :]
        if causal:
            mask = mask & (k_pos <= q_pos[..., None, :].transpose(0, 1, 3, 2))
        if window > 0:
            mask = mask & (k_pos > q_pos[..., None, :].transpose(0, 1, 3, 2)
                           - window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        lsum = lsum * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bqhk,bkhd->bqhd", p, vb.astype(jnp.float32))
        return (m_new, lsum, acc), None

    init = (jnp.full((B, Sq, Hq), NEG_INF, jnp.float32),
            jnp.zeros((B, Sq, Hq), jnp.float32),
            jnp.zeros((B, Sq, Hq, D), jnp.float32))
    (m, lsum, acc), _ = lax.scan(
        body, init, (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(lsum, 1e-30)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (param specs + apply), GQA/MQA/SWA/softcap/M-RoPE
# ---------------------------------------------------------------------------
def attn_specs(cfg: ModelConfig) -> dict:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    emb = "embed_fsdp" if cfg.fsdp else "embed"
    return {
        "wq": Spec((d, qd), (emb, "heads")),
        "wk": Spec((d, kvd), (emb, "kv_heads")),
        "wv": Spec((d, kvd), (emb, "kv_heads")),
        "wo": Spec((qd, d), ("heads", emb)),
        "norm": Spec((d,), ("embed",), init="zeros"),
    }


@dataclasses.dataclass(frozen=True)
class AttnCache:
    """Ring-buffered KV cache: capacity C = window (SWA) or full context."""
    k: jax.Array          # (B, C, Hkv, D) — keys stored post-RoPE
    v: jax.Array
    length: jax.Array     # () int32 — total tokens seen


def init_attn_cache(cfg: ModelConfig, batch: int, capacity: int,
                    dtype=jnp.bfloat16) -> AttnCache:
    shp = (batch, capacity, cfg.num_kv_heads, cfg.head_dim)
    return AttnCache(jnp.zeros(shp, dtype), jnp.zeros(shp, dtype),
                     jnp.zeros((), jnp.int32))


def attn_cache_axes(shape_kind: str = "default"):
    seq_ax = "cache_seq"
    return AttnCache(("batch", seq_ax, "kv_heads", "head_dim"),
                     ("batch", seq_ax, "kv_heads", "head_dim"),
                     ())


@dataclasses.dataclass(frozen=True)
class PagedKVState:
    """Block/paged KV cache: KV lives in fixed-size pages of a shared
    physical pool instead of one dense per-request ring buffer.

    pages_k/pages_v: (P, ps, Hkv, D) — the physical page pool (page 0 is
    the serving engine's reserved null page: writes from idle batch slots
    land there and are never attended).
    page_table:      (B, n) int32 — physical page per logical page; rows of
    idle slots point at the null page.
    lengths:         (B,) int32 — tokens already stored per request BEFORE
    the current decode token (same pre-increment convention as
    ``AttnCache.length``); position ``p`` lives in page ``p // ps`` at
    offset ``p % ps``.
    impl:            static pytree metadata selecting the attention math —
    ``"gather"`` (jnp page gather, the oracle path; runs anywhere) or
    ``"pallas"`` (``kernels.paged_attn``: scalar-prefetch page gather into
    VMEM, interpret mode off-TPU).
    """
    pages_k: jax.Array
    pages_v: jax.Array
    page_table: jax.Array
    lengths: jax.Array
    impl: str = "gather"


jax.tree_util.register_dataclass(
    PagedKVState,
    data_fields=["pages_k", "pages_v", "page_table", "lengths"],
    meta_fields=["impl"])


def init_paged_kv_state(cfg: ModelConfig, batch: int, num_pages: int,
                        page_size: int, pages_per_req: int,
                        dtype=jnp.bfloat16, impl: str = "gather",
                        ) -> PagedKVState:
    shp = (num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
    return PagedKVState(jnp.zeros(shp, dtype), jnp.zeros(shp, dtype),
                        jnp.zeros((batch, pages_per_req), jnp.int32),
                        jnp.zeros((batch,), jnp.int32), impl)


def paged_decode_attention_block(cache: PagedKVState, q: jax.Array,
                                 k_new: jax.Array, v_new: jax.Array, *,
                                 window: int, logit_softcap: float):
    """One decode token against the paged pool: write k/v at each request's
    next position (through its page table), then attend the valid set.
    q/k_new/v_new: (B, 1, H, D).  Returns (out (B, 1, Hq, D), new_cache)."""
    B = q.shape[0]
    ps = cache.pages_k.shape[1]
    pos = cache.lengths                                     # (B,)
    phys = jnp.take_along_axis(cache.page_table,
                               (pos // ps)[:, None], axis=1)[:, 0]
    off = pos % ps
    kp = cache.pages_k.at[phys, off].set(k_new[:, 0].astype(cache.pages_k.dtype))
    vp = cache.pages_v.at[phys, off].set(v_new[:, 0].astype(cache.pages_v.dtype))
    total = pos + 1                                         # valid counts
    if cache.impl == "pallas":
        from repro.kernels.paged_attn import paged_decode_attention
        out = paged_decode_attention(q[:, 0], kp, vp, cache.page_table,
                                     total, window=window,
                                     logit_softcap=logit_softcap)
    else:
        from repro.kernels.ref import paged_decode_attention_ref
        out = paged_decode_attention_ref(q[:, 0], kp, vp, cache.page_table,
                                         total, window=window,
                                         logit_softcap=logit_softcap)
    new_cache = PagedKVState(kp, vp, cache.page_table, total, cache.impl)
    return out[:, None], new_cache


def sharded_decode_attention(ctx: ShardingCtx, q: jax.Array,
                             cache: "AttnCache", k_new: jax.Array,
                             v_new: jax.Array, *, logit_softcap: float):
    """One-token attention over a SEQ-SHARDED ring-buffer cache, with
    explicit shard_map collectives — the paper's part-reduce pattern applied
    to attention partials.

    GSPMD's auto-partitioner all-gathers the whole cache for the softmax
    (measured: 2 x 1 GB f32 per layer on gemma2 decode_32k); here each
    shard computes its local (logits-max, exp-sum, weighted-V) partials and
    one tiny psum combines them: (B,H,D)+2x(B,H) floats instead.

    Each shard also performs the ring-buffer write locally iff it owns the
    slot.  Returns (out (B,1,Hq,D), new_cache).
    """
    from jax.sharding import PartitionSpec as P
    mesh = ctx.mesh
    rule = ctx.rules.rules.get("cache_seq")
    seq_axes = tuple(a for a in (rule or ()) if a in mesh.axis_names)
    n_shards = 1
    for a in seq_axes:
        n_shards *= mesh.shape[a]
    axis = seq_axes if len(seq_axes) > 1 else seq_axes[0]

    B, C, Hkv, D = cache.k.shape
    Hq = q.shape[2]
    g = Hq // Hkv
    Cs = C // n_shards
    scale = D ** -0.5
    batch_axes = tuple(a for a in ("pod", "data")
                       if a in mesh.axis_names and a not in seq_axes)
    bspec = (batch_axes if len(batch_axes) > 1 else
             (batch_axes[0] if batch_axes else None))
    if bspec is not None and B % max(
            1, int(np.prod([mesh.shape[a] for a in batch_axes]))) != 0:
        bspec = None

    def inner(q_, kc, vc, kn, vn, length):
        # q_: (B_loc,1,Hq,D) repl. over seq axes; kc/vc: (B_loc,Cs,Hkv,D)
        i = lax.axis_index(axis)
        slot = length % C
        local = slot - i * Cs
        own = (local >= 0) & (local < Cs)
        loc_c = jnp.clip(local, 0, Cs - 1)
        kc = jnp.where(own, lax.dynamic_update_slice(
            kc, kn.astype(kc.dtype), (0, loc_c, 0, 0)), kc)
        vc = jnp.where(own, lax.dynamic_update_slice(
            vc, vn.astype(vc.dtype), (0, loc_c, 0, 0)), vc)
        # local flash partials
        qf = q_[:, 0].astype(jnp.float32) * scale          # (B,Hq,D)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        if g > 1:
            kf = jnp.repeat(kf, g, axis=2)
            vf = jnp.repeat(vf, g, axis=2)
        s = jnp.einsum("bhd,bkhd->bhk", qf, kf)            # (B,Hq,Cs)
        if logit_softcap > 0:
            s = jnp.tanh(s / logit_softcap) * logit_softcap
        gidx = i * Cs + jnp.arange(Cs)
        valid = gidx[None, None, :] < jnp.minimum(length + 1, C)
        s = jnp.where(valid, s, NEG_INF)
        m_loc = s.max(-1)                                  # (B,Hq)
        m = lax.pmax(m_loc, axis)
        p = jnp.exp(s - m[..., None])
        denom = lax.psum(p.sum(-1), axis)                  # (B,Hq)
        o = lax.psum(jnp.einsum("bhk,bkhd->bhd", p, vf), axis)
        out = (o / jnp.maximum(denom, 1e-30)[..., None])[:, None]
        return out.astype(q_.dtype), kc, vc

    cache_spec = P(bspec, axis, None, None)
    io_spec = P(bspec, None, None, None)
    out, kc, vc = jax.shard_map(
        inner, mesh=mesh,
        in_specs=(io_spec, cache_spec, cache_spec, io_spec, io_spec, P()),
        out_specs=(io_spec, cache_spec, cache_spec),
        check_vma=False,
    )(q, cache.k, cache.v, k_new, v_new, cache.length)
    return out, AttnCache(kc, vc, cache.length + 1)


def attention_block(p: dict, x: jax.Array, cfg: ModelConfig,
                    ctx: ShardingCtx, positions: jax.Array, *,
                    window: int = 0,
                    cache: Optional[AttnCache] = None,
                    update_cache: bool = False):
    """Pre-norm attention.  Returns (residual_out, new_cache_or_None).

    Train/prefill: full-sequence chunked attention (+ cache write when
    ``update_cache``).  Decode (S==1 with cache): one-token attention against
    the ring buffer.
    """
    B, S, d = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = (h @ p["wq"].astype(h.dtype)).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = (h @ p["wk"].astype(h.dtype)).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = (h @ p["wv"].astype(h.dtype)).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = ctx.constrain(q, "batch", "seq", "heads", "head_dim")
    k = ctx.constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = ctx.constrain(v, "batch", "seq", "kv_heads", "head_dim")

    new_cache = None
    if isinstance(cache, PagedKVState):
        # ---- paged decode: write through the page table, gather pages ----
        assert S == 1, "paged KV cache is decode-only (S == 1)"
        out, new_cache = paged_decode_attention_block(
            cache, q, k, v, window=window,
            logit_softcap=cfg.attn_logit_softcap)
        out = out.reshape(B, S, cfg.q_dim)
        y = out @ p["wo"].astype(out.dtype)
        y = ctx.constrain(y, "batch", "seq", "embed")
        return x + y, new_cache
    if cache is not None and S == 1:
        # ---- decode: append to ring buffer, attend over it ----
        C = cache.k.shape[1]
        rule = ctx.rules.rules.get("cache_seq") if ctx.mesh is not None \
            else None
        seq_axes = tuple(a for a in (rule or ())
                         if ctx.mesh is not None
                         and a in ctx.mesh.axis_names)
        n_sh = 1
        for a in seq_axes:
            n_sh *= ctx.mesh.shape[a]
        if seq_axes and n_sh > 1 and C % n_sh == 0:
            # seq-sharded cache: explicit partial-softmax combine
            out, new_cache = sharded_decode_attention(
                ctx, q, cache, k, v,
                logit_softcap=cfg.attn_logit_softcap)
            out = out.reshape(B, S, cfg.q_dim)
            y = out @ p["wo"].astype(out.dtype)
            y = ctx.constrain(y, "batch", "seq", "embed")
            return x + y, new_cache
        slot = cache.length % C
        kc = lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                      (0, slot, 0, 0))
        vc = lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                      (0, slot, 0, 0))
        new_cache = AttnCache(kc, vc, cache.length + 1)
        from repro.kernels.ref import decode_attention_ref
        valid = jnp.minimum(cache.length + 1, C)
        out = decode_attention_ref(
            q, kc, vc, jnp.full((B,), valid, jnp.int32),
            window=window, logit_softcap=cfg.attn_logit_softcap)
    else:
        out = chunked_attention(
            q, k, v, causal=True, window=window,
            logit_softcap=cfg.attn_logit_softcap)
        if update_cache:
            # write the last min(S, C) tokens into the ring buffer so that
            # position p lands in slot p % C (decode continues the ring).
            assert cache is not None, "prefill needs an allocated cache"
            C = cache.k.shape[1]
            if S >= C:
                kw = jnp.roll(k[:, -C:], S % C, axis=1)
                vw = jnp.roll(v[:, -C:], S % C, axis=1)
                kc = kw.astype(cache.k.dtype)
                vc = vw.astype(cache.v.dtype)
            else:
                kc = lax.dynamic_update_slice(
                    cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0))
                vc = lax.dynamic_update_slice(
                    cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0))
            new_cache = AttnCache(kc, vc, jnp.asarray(S, jnp.int32))
    out = out.reshape(B, S, cfg.q_dim)
    y = out @ p["wo"].astype(out.dtype)
    y = ctx.constrain(y, "batch", "seq", "embed")
    return x + y, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_specs(cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    emb = "embed_fsdp" if cfg.fsdp else "embed"
    if cfg.mlp_kind in ("swiglu", "geglu"):
        return {
            "w_gate": Spec((d, ff), (emb, "ff")),
            "w_up": Spec((d, ff), (emb, "ff")),
            "w_down": Spec((ff, d), ("ff", emb)),
            "norm": Spec((d,), ("embed",), init="zeros"),
        }
    return {
        "w_up": Spec((d, ff), (emb, "ff")),
        "w_down": Spec((ff, d), ("ff", emb)),
        "norm": Spec((d,), ("embed",), init="zeros"),
    }


def mlp_block(p: dict, x: jax.Array, cfg: ModelConfig,
              ctx: ShardingCtx) -> jax.Array:
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    act = jax.nn.silu if cfg.mlp_kind == "swiglu" else (
        functools.partial(jax.nn.gelu, approximate=True))
    if cfg.mlp_kind in ("swiglu", "geglu"):
        u = act(h @ p["w_gate"].astype(h.dtype)) * (h @ p["w_up"].astype(h.dtype))
    else:
        u = act(h @ p["w_up"].astype(h.dtype))
    u = ctx.constrain(u, "batch", "seq", "ff")
    y = u @ p["w_down"].astype(u.dtype)
    y = ctx.constrain(y, "batch", "seq", "embed")
    return x + y
