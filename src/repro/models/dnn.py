"""CD-DNN (paper §5.4): 7x2048 fully-connected ASR acoustic model.

The paper's point with this network: all-FC topologies have far worse
comp-to-comm ratios than CNNs, so hybrid parallelism (not pure data
parallelism) is required — §3.2's rule 'ofm > minibatch => model parallel'
holds for every hidden layer here.  Our sharding rules put the 2048-wide
hidden dims on the 'model' axis accordingly.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import DNNConfig
from repro.core.params import Spec, init_tree
from repro.core.sharding import ShardingCtx


def param_specs(cfg: DNNConfig) -> Dict[str, Spec]:
    dims = [cfg.input_dim] + [cfg.hidden_dim] * cfg.num_hidden \
        + [cfg.output_dim]
    # layer-major zero-padded keys: jax flattens dicts in LEXICAL key
    # order, and the comm bucket plan follows tree order — "b0..bN, w0..wN"
    # would interleave every layer's bias away from its weight and break
    # the §3.1 backprop-readiness order (see models/cnn._key)
    sp = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        sp[f"fc{i:02d}_w"] = Spec((a, b), ("embed", "ff"))
        sp[f"fc{i:02d}_b"] = Spec((b,), ("ff",), init="zeros")
    return sp


def init_params(cfg: DNNConfig, key: jax.Array):
    return init_tree(param_specs(cfg), key)


def forward(params, cfg: DNNConfig, x: jax.Array,
            ctx: ShardingCtx = ShardingCtx()) -> jax.Array:
    h = x
    n_layers = cfg.num_hidden + 1
    for i in range(n_layers):
        h = h @ params[f"fc{i:02d}_w"] + params[f"fc{i:02d}_b"]
        if i < n_layers - 1:
            h = jax.nn.sigmoid(h)       # CD-DNN uses sigmoid hidden units
            h = ctx.constrain(h, "batch", "ff")
    return h


def loss_fn(params, cfg: DNNConfig, batch: dict,
            ctx: ShardingCtx = ShardingCtx()) -> jax.Array:
    logits = forward(params, cfg, batch["frames"], ctx)
    lf = logits.astype(jnp.float32)
    nll = jax.nn.logsumexp(lf, -1) - jnp.take_along_axis(
        lf, batch["senones"][:, None], axis=-1)[:, 0]
    return nll.mean()
