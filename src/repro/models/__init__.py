from repro.models import cnn, dnn, frontends, layers, moe, ssm, transformer  # noqa: F401
