"""Modality-frontend STUBS — the single allowed carve-out (see brief).

We do not implement a ViT or a conv audio codec.  ``input_specs`` (launch/
dryrun) supplies pre-computed patch/frame embeddings of the right shape; for
runnable examples and smoke tests these helpers synthesize deterministic
embeddings/token streams (including MusicGen's codebook delay pattern, which
is a data-layout property, not a codec property).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def vision_stub_embeds(key: jax.Array, batch: int, n_tokens: int,
                       d_model: int, dtype=jnp.float32) -> jax.Array:
    """Stand-in for ViT+projector output: (B, n_tokens, d_model)."""
    return jax.random.normal(key, (batch, n_tokens, d_model), dtype) * 0.02


def mrope_positions(batch: int, s_img: int, s_txt: int,
                    grid_w: int = 32) -> jax.Array:
    """Qwen2-VL M-RoPE positions (B, S, 3) = (t, h, w).
    Image patches: t=0, (h, w) from the patch grid; text tokens: all three
    components advance together starting after the image span."""
    hh = jnp.arange(s_img) // grid_w
    ww = jnp.arange(s_img) % grid_w
    img = jnp.stack([jnp.zeros(s_img, jnp.int32), hh, ww], axis=-1)
    start = jnp.maximum(hh[-1], ww[-1]) + 1 if s_img else 0
    txt1 = start + jnp.arange(s_txt)
    txt = jnp.stack([txt1, txt1, txt1], axis=-1)
    pos = jnp.concatenate([img, txt], axis=0).astype(jnp.int32)
    return jnp.broadcast_to(pos[None], (batch, s_img + s_txt, 3))


def audio_stub_embeds(key: jax.Array, batch: int, seq: int,
                      d_model: int, dtype=jnp.float32) -> jax.Array:
    """Stand-in for summed EnCodec codebook embeddings: (B, S, d_model)."""
    return jax.random.normal(key, (batch, seq, d_model), dtype) * 0.02


def delay_pattern(tokens: jax.Array, n_codebooks: int,
                  pad_id: int = 0) -> jax.Array:
    """MusicGen delay interleave: codebook k is shifted right by k steps.
    tokens: (B, S, K) -> delayed (B, S, K)."""
    B, S, K = tokens.shape
    assert K == n_codebooks
    cols = []
    for k in range(K):
        shifted = jnp.pad(tokens[:, : S - k, k], ((0, 0), (k, 0)),
                          constant_values=pad_id)
        cols.append(shifted)
    return jnp.stack(cols, axis=-1)
