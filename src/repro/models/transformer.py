"""Config-driven decoder assembly for all ten assigned architectures.

The layer stack is ``pattern_repeats`` x ``block_pattern`` (the repeating
heterogeneous unit: e.g. gemma2's (local, global), zamba2's 5x mamba +
shared-attention).  We ``lax.scan`` over the repeats with per-repeat params
stacked on a leading axis — HLO size and compile time are then independent
of depth, which matters when lowering 56-layer models for 512 devices.

Zamba2's shared attention block is weight-SHARED across repeats: its params
are not stacked; the scan body closes over them.

Caches (decode/prefill) mirror the same structure: a tuple (one entry per
pattern position) of per-repeat-stacked cache pytrees, scanned alongside the
params.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import (
    ATTN_GLOBAL,
    ATTN_LOCAL,
    BLOCK_MAMBA,
    BLOCK_MLSTM,
    BLOCK_SHARED_ATTN,
    BLOCK_SLSTM,
    ModelConfig,
)
from repro.core.params import Spec, axes_tree as _axes_tree, init_tree
from repro.core.sharding import ShardingCtx
from repro.models import layers, moe, ssm
from repro.models.layers import attention_block, mlp_block, rms_norm

# register cache dataclasses as pytrees
for _cls in (layers.AttnCache, ssm.MambaCache, ssm.MlstmCache, ssm.SlstmCache):
    try:
        jax.tree_util.register_dataclass(
            _cls, data_fields=[f for f in _cls.__dataclass_fields__],
            meta_fields=[])
    except ValueError:
        pass  # already registered


# ---------------------------------------------------------------------------
# per-block param specs
# ---------------------------------------------------------------------------
def _block_specs(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        sp = {"attn": layers.attn_specs(cfg)}
        if cfg.num_experts:
            sp["moe"] = moe.moe_specs(cfg)
        else:
            sp["mlp"] = layers.mlp_specs(cfg)
        return sp
    if kind == BLOCK_SHARED_ATTN:
        return {"attn": layers.attn_specs(cfg), "mlp": layers.mlp_specs(cfg)}
    if kind == BLOCK_MAMBA:
        return {"mamba": ssm.mamba_specs(cfg)}
    if kind == BLOCK_MLSTM:
        return {"mlstm": ssm.mlstm_specs(cfg)}
    if kind == BLOCK_SLSTM:
        return {"slstm": ssm.slstm_specs(cfg)}
    raise ValueError(kind)


def _stack_specs(sp, repeats: int):
    return jax.tree.map(
        lambda s: Spec((repeats,) + s.shape, (None,) + s.axes, s.init, s.scale),
        sp, is_leaf=lambda x: isinstance(x, Spec))


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.vocab_size
    specs: Dict[str, Any] = {
        "embed": Spec((V, d), ("vocab", "embed"), init="embed", scale=0.02),
        "final_norm": Spec((d,), ("embed",), init="zeros"),
    }
    blocks = []
    for kind in cfg.block_pattern:
        if kind == BLOCK_SHARED_ATTN:
            blocks.append({})   # shared: params live outside the stack
        else:
            blocks.append(_stack_specs(_block_specs(cfg, kind),
                                       cfg.pattern_repeats))
    specs["blocks"] = tuple(blocks)
    if BLOCK_SHARED_ATTN in cfg.block_pattern:
        specs["shared"] = _block_specs(cfg, BLOCK_SHARED_ATTN)
    if not cfg.tie_embeddings:
        specs["lm_head"] = Spec((d, V), ("embed", "vocab"))
    if cfg.num_codebooks:
        specs["codebook_heads"] = Spec((cfg.num_codebooks, d, V),
                                       ("codebooks", "embed", "vocab"))
    return specs


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32):
    return init_tree(param_specs(cfg), key, dtype)


def param_axes(cfg: ModelConfig):
    return _axes_tree(param_specs(cfg))


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def effective_window(cfg: ModelConfig, kind: str, long_ctx: bool) -> int:
    """Attention window per block kind; ``long_ctx`` swaps full attention for
    the documented sliding-window variant (DESIGN.md long_500k policy)."""
    if kind == ATTN_LOCAL:
        return cfg.sliding_window
    if kind in (ATTN_GLOBAL, BLOCK_SHARED_ATTN):
        return cfg.long_context_window if long_ctx else 0
    return 0


def init_caches(cfg: ModelConfig, batch: int, context_len: int,
                long_ctx: bool = False, dtype=jnp.bfloat16):
    """Tuple (per pattern entry) of per-repeat-stacked caches."""
    R = cfg.pattern_repeats

    def stack(make_one):
        ones = [make_one() for _ in range(R)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *ones)

    caches = []
    for kind in cfg.block_pattern:
        if kind in (ATTN_GLOBAL, ATTN_LOCAL, BLOCK_SHARED_ATTN):
            w = effective_window(cfg, kind, long_ctx)
            cap = min(w, context_len) if w else context_len
            caches.append(stack(
                lambda cap=cap: layers.init_attn_cache(cfg, batch, cap, dtype)))
        elif kind == BLOCK_MAMBA:
            caches.append(stack(lambda: ssm.init_mamba_cache(cfg, batch)))
        elif kind == BLOCK_MLSTM:
            caches.append(stack(lambda: ssm.init_mlstm_cache(cfg, batch)))
        elif kind == BLOCK_SLSTM:
            caches.append(stack(lambda: ssm.init_slstm_cache(cfg, batch)))
    return tuple(caches)


ATTN_KINDS = (ATTN_GLOBAL, ATTN_LOCAL, BLOCK_SHARED_ATTN)


def init_paged_caches(cfg: ModelConfig, batch: int, num_pages: int,
                      page_size: int, pages_per_req: int,
                      dtype=jnp.bfloat16, impl: str = "gather"):
    """Paged-decode caches: tuple (per pattern entry) of per-repeat-stacked
    :class:`~repro.models.layers.PagedKVState` — every (entry, repeat) layer
    owns its own physical page pool; the per-request page table and lengths
    are shared across layers (stacked so the scan can slice them).  Only
    attention block kinds are supported (the serving engine rejects
    SSM/hybrid archs before getting here)."""
    R = cfg.pattern_repeats

    def stack(make_one):
        ones = [make_one() for _ in range(R)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *ones)

    caches = []
    for kind in cfg.block_pattern:
        if kind not in ATTN_KINDS:
            raise ValueError(
                f"paged KV caches support attention blocks only, got {kind!r}")
        caches.append(stack(lambda: layers.init_paged_kv_state(
            cfg, batch, num_pages, page_size, pages_per_req, dtype, impl)))
    return tuple(caches)


def cache_axes(cfg: ModelConfig):
    out = []
    for kind in cfg.block_pattern:
        if kind in (ATTN_GLOBAL, ATTN_LOCAL, BLOCK_SHARED_ATTN):
            ax = layers.attn_cache_axes()
        elif kind == BLOCK_MAMBA:
            ax = ssm.mamba_cache_axes()
        elif kind == BLOCK_MLSTM:
            ax = ssm.mlstm_cache_axes()
        else:
            ax = ssm.slstm_cache_axes()
        out.append(jax.tree.map(
            lambda a: (None,) + a if isinstance(a, tuple) else (None,),
            ax, is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x)))
    return tuple(out)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _apply_block(kind: str, p, shared_p, x, cfg: ModelConfig,
                 ctx: ShardingCtx, positions, *, long_ctx: bool,
                 cache, update_cache: bool):
    aux = jnp.zeros((), jnp.float32)
    if kind in (ATTN_GLOBAL, ATTN_LOCAL, BLOCK_SHARED_ATTN):
        pp = shared_p if kind == BLOCK_SHARED_ATTN else p
        w = effective_window(cfg, kind, long_ctx)
        x, new_cache = attention_block(
            pp["attn"], x, cfg, ctx, positions, window=w, cache=cache,
            update_cache=update_cache)
        if "moe" in (pp or {}):
            x, aux = moe.moe_block(pp["moe"], x, cfg, ctx)
        else:
            x = mlp_block(pp["mlp"], x, cfg, ctx)
    elif kind == BLOCK_MAMBA:
        x, new_cache = ssm.mamba_block(p["mamba"], x, cfg, ctx, cache=cache)
    elif kind == BLOCK_MLSTM:
        x, new_cache = ssm.mlstm_block(p["mlstm"], x, cfg, ctx, cache=cache)
    elif kind == BLOCK_SLSTM:
        x, new_cache = ssm.slstm_block(p["slstm"], x, cfg, ctx, cache=cache)
    else:
        raise ValueError(kind)
    return x, aux, new_cache


def make_scan_body(cfg: ModelConfig, ctx: ShardingCtx, shared_p, positions, *,
                   long_ctx: bool, update_cache: bool, have_cache: bool):
    """The per-repeat scan body: one application of the block pattern.
    Exposed so launch/dryrun can lower a single unit separately (XLA cost
    analysis counts a while-loop body once; the dry-run corrects totals with
    ``full + (R-1) * unit``)."""

    def body(carry, xs):
        h, aux = carry
        if have_cache:
            block_params, block_caches = xs
        else:
            block_params, block_caches = xs, None
        new_caches = []
        for j, kind in enumerate(cfg.block_pattern):
            cache_j = block_caches[j] if have_cache else None
            h, aux_j, nc = _apply_block(
                kind, block_params[j], shared_p, h, cfg, ctx, positions,
                long_ctx=long_ctx, cache=cache_j, update_cache=update_cache)
            aux = aux + aux_j
            if have_cache:
                new_caches.append(nc if nc is not None else cache_j)
        if cfg.seq_shard_carry and h.shape[1] > 1:
            # Megatron-style sequence parallelism for the residual stream:
            # the remat-saved carry is stored seq-sharded on 'model'
            # (16x less HBM per saved layer input); blocks re-gather.
            h = ctx.constrain(h, "batch", "seq_res", "embed")
        return (h, aux), (tuple(new_caches) if have_cache else None)

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    elif cfg.remat == "block_dots":
        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return body


def forward(params, cfg: ModelConfig, ctx: ShardingCtx, *,
            tokens: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            positions: Optional[jax.Array] = None,
            caches=None, update_cache: bool = False,
            long_ctx: bool = False, return_hidden: bool = False):
    """Returns (logits, aux_loss, new_caches).

    ``tokens`` (B,S) and/or ``embeds`` (B,S_e,d) — for VLM the two are
    concatenated (vision first); for audio only embeds are used.
    ``positions``: (B,S) int or (B,S,3) for M-RoPE; derived if None.
    """
    emb_scale = jnp.asarray(cfg.d_model ** 0.5, jnp.float32)
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(jnp.bfloat16))
    if tokens is not None:
        te = jnp.take(params["embed"], tokens, axis=0) * emb_scale
        parts.append(te.astype(jnp.bfloat16))
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    B, S, _ = x.shape
    x = ctx.constrain(x, "batch", "seq", "embed")

    if positions is None:
        pos1 = jnp.broadcast_to(jnp.arange(S), (B, S))
        positions = (jnp.repeat(pos1[..., None], 3, axis=-1)
                     if cfg.mrope else pos1)

    shared_p = params.get("shared")
    have_cache = caches is not None
    aux0 = jnp.zeros((), jnp.float32)
    body = make_scan_body(cfg, ctx, shared_p, positions,
                          long_ctx=long_ctx, update_cache=update_cache,
                          have_cache=have_cache)

    xs = (params["blocks"], caches) if have_cache else params["blocks"]
    (x, aux), new_caches = lax.scan(body, (x, aux0), xs)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, aux, (new_caches if have_cache else None)
    if cfg.num_codebooks:
        logits = jnp.einsum("bsd,kdv->bskv", x,
                            params["codebook_heads"].astype(x.dtype))
    elif cfg.tie_embeddings:
        logits = x @ params["embed"].T.astype(x.dtype)
    else:
        logits = x @ params["lm_head"].astype(x.dtype)
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = jnp.tanh(logits / c) * c
    logits = ctx.constrain(logits, "batch", "seq", None, "vocab") \
        if cfg.num_codebooks else ctx.constrain(logits, "batch", "seq", "vocab")
    return logits, aux, (new_caches if have_cache else None)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def _ce(logits: jax.Array, labels: jax.Array, mask=None) -> jax.Array:
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def chunked_lm_loss(params, cfg: ModelConfig, ctx: ShardingCtx,
                    hidden: jax.Array, labels: jax.Array,
                    n_chunks: int) -> jax.Array:
    """CE computed over sequence chunks so the (B, S, V) f32 logits tensor
    is never materialized whole (perf knob ``loss_chunk``; the LM head is
    the biggest single activation for 128k–256k vocabularies)."""
    B, S, d = hidden.shape
    Sm1 = S - 1
    chunk = -(-Sm1 // n_chunks)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    total = jnp.zeros((), jnp.float32)
    for i in range(n_chunks):
        lo = i * chunk
        hi = min(lo + chunk, Sm1)
        if lo >= hi:
            break
        hc = hidden[:, lo:hi]
        logits = hc @ w.astype(hc.dtype)
        if cfg.final_logit_softcap:
            c = cfg.final_logit_softcap
            logits = jnp.tanh(logits / c) * c
        logits = ctx.constrain(logits, "batch", "seq", "vocab")
        lf = logits.astype(jnp.float32)
        # hidden positions lo..hi-1 predict tokens lo+1..hi
        nll = jax.nn.logsumexp(lf, -1) - jnp.take_along_axis(
            lf, labels[:, lo + 1:hi + 1, None], axis=-1)[..., 0]
        total = total + nll.sum()
    return total / (B * Sm1)


def lm_loss(params, cfg: ModelConfig, ctx: ShardingCtx, batch: dict):
    """Next-token CE for every family.  batch keys:
    tokens (B,S) [dense/moe/ssm/hybrid]; + patch_embeds for vlm;
    frame_embeds + codebook_labels (B,S,K) for audio."""
    if cfg.loss_chunk and cfg.frontend is None and not cfg.num_codebooks:
        hidden, aux, _ = forward(params, cfg, ctx, tokens=batch["tokens"],
                                 return_hidden=True)
        loss = chunked_lm_loss(params, cfg, ctx, hidden,
                               batch["tokens"], cfg.loss_chunk)
        return loss + aux
    if cfg.frontend == "audio":
        logits, aux, _ = forward(params, cfg, ctx,
                                 embeds=batch["frame_embeds"])
        labels = batch["codebook_labels"]                  # (B,S,K)
        loss = _ce(logits[:, :-1], labels[:, 1:])
        return loss + aux
    if cfg.frontend == "vision":
        logits, aux, _ = forward(params, cfg, ctx, tokens=batch["tokens"],
                                 embeds=batch["patch_embeds"],
                                 positions=batch.get("positions"))
        S_img = batch["patch_embeds"].shape[1]
        txt_logits = logits[:, S_img:-1]
        labels = batch["tokens"][:, 1:]
        loss = _ce(txt_logits, labels)
        return loss + aux
    logits, aux, _ = forward(params, cfg, ctx, tokens=batch["tokens"])
    loss = _ce(logits[:, :-1], batch["tokens"][:, 1:])
    return loss + aux
